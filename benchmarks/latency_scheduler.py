"""Scheduler real-time latency (paper Table II Time column): wall time of
one full scheduling decision (policy forward + greedy decode) across system
scales, on this host's CPU. Includes the fused policy_score kernel micro-
benchmark (interpret mode on CPU = correctness path, not TPU timing)."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, eval_instances, get_trained_policy
from repro.core.decode import greedy_decode
from repro.core.policy import corais_apply


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=800)
    ap.add_argument("--scales", type=str, default="5x50,10x100,30x400,50x800")
    args = ap.parse_args()
    params, state, cfg = get_trained_policy(5, 50, args.batches)

    for scale in args.scales.split(","):
        en, rn = map(int, scale.split("x"))
        inst = eval_instances(en, rn, 1)[0]
        jinst = jax.tree.map(jnp.asarray, inst)

        @jax.jit
        def decide(jinst):
            lp, _ = corais_apply(params, state, jinst, cfg.policy,
                                 training=False)
            return greedy_decode(lp)

        jax.block_until_ready(decide(jinst))  # compile
        reps = 20
        t0 = time.perf_counter()
        for _ in range(reps):
            out = decide(jinst)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / reps
        print(csv_line(f"latency/decision_EN{en}_RN{rn}", dt * 1e6,
                       f"ms={dt*1e3:.3f}"))


if __name__ == "__main__":
    main()
