"""Paper Fig. 7 — sampling-decode effect: more samples -> better gap at a
small (vectorized) time cost."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, eval_instances, get_trained_policy
from repro.core.decode import sampling_decode
from repro.core.heuristics import solve_ils
from repro.core.objective import makespan_np
from repro.core.policy import corais_apply


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--en", type=int, default=10)
    ap.add_argument("--rn", type=int, default=100)
    ap.add_argument("--instances", type=int, default=10)
    ap.add_argument("--batches", type=int, default=800)
    ap.add_argument("--samples", type=int, nargs="+",
                    default=[1, 10, 100, 1000])
    args = ap.parse_args()
    params, state, cfg = get_trained_policy(5, 50, args.batches)
    instances = eval_instances(args.en, args.rn, args.instances)
    refs = [makespan_np(i, solve_ils(i, budget_s=2.0, seed=0))
            for i in instances]

    @jax.jit
    def forward(jinst):
        lp, _ = corais_apply(params, state, jinst, cfg.policy, training=False)
        return lp

    for n in args.samples:
        decode = jax.jit(lambda jinst, lp, key, n=n:
                         sampling_decode(key, jinst, lp, n))
        gaps, times = [], []
        key = jax.random.PRNGKey(0)
        for inst, ref in zip(instances, refs):
            jinst = jax.tree.map(jnp.asarray, inst)
            lp = forward(jinst)
            key, sub = jax.random.split(key)
            jax.block_until_ready(decode(jinst, lp, sub))  # warm
            t0 = time.perf_counter()
            assign, _ = decode(jinst, lp, sub)
            assign = np.asarray(jax.block_until_ready(assign))
            times.append(time.perf_counter() - t0)
            gaps.append(makespan_np(inst, assign) / max(ref, 1e-9))
        print(csv_line(f"fig7/EN{args.en}_RN{args.rn}/samples_{n}",
                       float(np.mean(times)) * 1e6,
                       f"gap={float(np.mean(gaps)):.4f}"))


if __name__ == "__main__":
    main()
