"""Roofline table (task spec g): renders results/dryrun.json into the
per-(arch x shape) three-term table; optionally re-runs selected cells with
a variant config for the §Perf hillclimb.

    python -m benchmarks.roofline_run                 # print table
    python -m benchmarks.roofline_run --csv           # bench CSV lines
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import RESULTS, csv_line

DRYRUN = os.path.join(RESULTS, "dryrun.json")


def load(path=DRYRUN, mesh="single", variant="baseline"):
    with open(path) as f:
        rows = json.load(f)
    return [r for r in rows
            if r["mesh"] == mesh and r.get("variant", "baseline") == variant]


def fmt_table(rows):
    out = ["arch                 shape        comp_s   mem_s    coll_s   "
           "dominant    useful  bound_s"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "skipped":
            out.append(f"{r['arch']:20s} {r['shape']:12s} "
                       f"SKIPPED ({r['reason'][:48]})")
            continue
        if r["status"] != "ok":
            out.append(f"{r['arch']:20s} {r['shape']:12s} FAILED")
            continue
        t = r["terms"]
        out.append(
            f"{r['arch']:20s} {r['shape']:12s} "
            f"{t['compute_s']:8.4f} {t['memory_s']:8.4f} "
            f"{t['collective_s']:8.4f} {t['dominant']:10s} "
            f"{t['useful_flop_ratio']:6.3f} {t['bound_s']:8.4f}")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    if not os.path.exists(DRYRUN):
        print("roofline/none,0.0,run `python -m repro.launch.dryrun --all` first")
        return
    rows = load(mesh=args.mesh, variant=args.variant)
    if args.csv:
        for r in rows:
            if r["status"] != "ok":
                continue
            t = r["terms"]
            print(csv_line(
                f"roofline/{r['arch']}/{r['shape']}/{args.mesh}",
                t["bound_s"] * 1e6,
                f"dominant={t['dominant']};compute_s={t['compute_s']:.5f};"
                f"memory_s={t['memory_s']:.5f};"
                f"collective_s={t['collective_s']:.5f};"
                f"useful={t['useful_flop_ratio']:.3f}"))
    else:
        print(fmt_table(rows))


if __name__ == "__main__":
    main()
