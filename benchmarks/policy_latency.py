"""Real-time decision latency of the unified policy inference stack.

The paper's headline claim is millisecond-level scheduling regardless of
system scale; this benchmark measures it directly, per decision *path*.
Cells (schema corais.policy_latency.v2) are keyed by
(backend, Q, Z, stage, decode):

  stage=decision — one full scheduling decision (encode + eq 16-17 head +
      greedy decode) through the compile-once serving path
      (``make_decision_fn``), for every score backend (``xla`` einsum head,
      ``ref`` pure-jnp oracle, ``pallas`` fused kernel — interpret mode
      off-TPU) and decode route:
        decode=host  — materialize the (Z, Q) log-probs, argmax
        decode=fused — argmax inside the scoring kernel; (Z, Q) is never
                       materialized (kernels/policy_score.py)
      Reports mean / p50 / p95 / p99 wall latency over ``--reps`` calls,
      one-off compile time, and (``--batch``) vmapped throughput.

  stage=head — the decode head in isolation (encoder outputs precomputed):
      the serving-loop cost the fused decode actually removes.
        decode=host  — pallas score kernel + device->host fetch of the
                       (Z, Q) matrix + np.argmax on the host
        decode=fused — fused decode kernel (k=1, unnormalized) + a (Z,)
                       int32 fetch
      The headline comparison: fused p95 must beat host p95 ~2x at the
      paper's top scale (Q=100, Z=1000) on the same machine.

``--fastpath`` additionally drives :class:`repro.serving.DecisionFastPath`
over every padding bucket against explicit p50/p95/p99 SLOs and writes the
pass/fail table to results/slo_report.json (uploaded as a CI artifact;
informational — the hard CI gate is check_latency_drift.py).

Run:  PYTHONPATH=src python benchmarks/policy_latency.py
      PYTHONPATH=src python benchmarks/policy_latency.py \\
          --backends xla,pallas --scales 10x100,100x1000 --batch 16
      PYTHONPATH=src python benchmarks/policy_latency.py --smoke --fastpath
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import InstanceConfig, generate_batch, generate_instance
from repro.core.inference import make_decision_fn, policy_decide
from repro.core.policy import (PolicyConfig, corais_encode, corais_init,
                               list_score_backends)
from repro.serving.fastpath import (DEFAULT_BUCKETS, DecisionFastPath,
                                    SLOSpec, evaluate_slo)

REPORT_SCHEMA = "corais.policy_latency.v2"
SLO_SCHEMA = "corais.slo_report.v1"
#: paper scales and beyond: Table II tops out at Q=10, Z=100
DEFAULT_QS = (5, 10, 50, 100)
DEFAULT_ZS = (20, 100, 500, 1000)
#: default serving SLO (ms) for the fast-path section; override per run
DEFAULT_SLO = (25.0, 50.0, 100.0)


def _percentiles(times_s: list) -> dict:
    t = np.asarray(times_s) * 1e3
    return {
        "mean_ms": float(t.mean()),
        "p50_ms": float(np.percentile(t, 50)),
        "p95_ms": float(np.percentile(t, 95)),
        "p99_ms": float(np.percentile(t, 99)),
        "max_ms": float(t.max()),
    }


def bench_cell(params, state, pcfg: PolicyConfig, backend: str, q: int,
               z: int, *, decode: str = "host", batch: int, reps: int,
               seed: int = 999) -> dict:
    """One (backend, Q, Z, decision, decode) cell: single-decision latency
    + batched throughput on freshly generated instances of that scale."""
    fused = decode == "fused"
    rng = np.random.default_rng(seed)
    icfg = InstanceConfig(num_edges=q, num_requests=z)
    inst = jax.tree.map(jnp.asarray, generate_instance(rng, icfg))
    key = jax.random.PRNGKey(0)

    # the exact compile-once path the serving controller / fast path runs
    # (fused serving skips the argmax-invariant log-softmax normalizer)
    decide = make_decision_fn(params, state, pcfg, mode="greedy",
                              backend=backend, fused_decode=fused,
                              normalize=not fused)

    t0 = time.perf_counter()
    jax.block_until_ready(decide(inst, key))
    compile_s = time.perf_counter() - t0

    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(decide(inst, key))
        times.append(time.perf_counter() - t0)
    single = _percentiles(times)
    single["compile_s"] = compile_s

    cell = {"backend": backend, "num_edges": q, "num_requests": z,
            "stage": "decision", "decode": decode, "single": single}

    if batch > 0:
        binst = jax.tree.map(jnp.asarray, generate_batch(rng, icfg, batch))
        keys = jax.random.split(key, batch)
        vdecide = jax.jit(jax.vmap(
            lambda i, k: policy_decide(k, params, state, i, pcfg,
                                       mode="greedy", backend=backend,
                                       fused_decode=fused,
                                       normalize=not fused)))
        jax.block_until_ready(vdecide(binst, keys))  # compile
        btimes = []
        for _ in range(max(1, reps // 2)):
            t0 = time.perf_counter()
            jax.block_until_ready(vdecide(binst, keys))
            btimes.append(time.perf_counter() - t0)
        wall = float(np.mean(btimes))
        cell["batched"] = {
            "batch": batch,
            "wall_ms": wall * 1e3,
            "decisions_per_s": batch / wall,
            "requests_per_s": batch * z / wall,
        }
    return cell


def bench_head_cell(params, state, pcfg: PolicyConfig, q: int, z: int, *,
                    decode: str, reps: int, seed: int = 999) -> dict:
    """One (pallas, Q, Z, head, decode) cell: the decode head in isolation,
    encoder outputs precomputed and resident on device.

    host  = pallas score kernel -> fetch the full (Z, Q) matrix -> np.argmax
    fused = fused decode kernel -> fetch (Z,) winner indices

    Both ends with a host-side numpy assignment, because that is what the
    serving loop hands to dispatch — the fused row's win is the (Z, Q)
    materialization + transfer + host scan it never does."""
    from repro.kernels import ops

    rng = np.random.default_rng(seed)
    icfg = InstanceConfig(num_edges=q, num_requests=z)
    inst = jax.tree.map(jnp.asarray, generate_instance(rng, icfg))
    c, h, _ = corais_encode(params, state, inst, pcfg)
    c, h = jax.block_until_ready((c, h))
    wx, wy = params["w_px"], params["w_py"]
    mask = inst["edge_mask"]
    clip = pcfg.tanh_clip

    if decode == "host":
        def step():
            lp = ops.policy_score(c, h, wx, wy, mask, tanh_clip=clip)
            return np.argmax(np.asarray(lp), axis=-1)
    else:
        def step():
            ti, _ = ops.policy_score_decode(c, h, wx, wy, mask,
                                            tanh_clip=clip, k=1,
                                            normalize=False)
            return np.asarray(ti)[:, 0]

    t0 = time.perf_counter()
    step()
    compile_s = time.perf_counter() - t0
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        step()
        times.append(time.perf_counter() - t0)
    single = _percentiles(times)
    single["compile_s"] = compile_s
    return {"backend": "pallas", "num_edges": q, "num_requests": z,
            "stage": "head", "decode": decode, "single": single}


def _fmt_cell(cell: dict) -> str:
    s = cell["single"]
    line = (f"  {cell['backend']:7s} {cell['stage']:8s} "
            f"{cell['decode']:5s} Q={cell['num_edges']:4d} "
            f"Z={cell['num_requests']:5d} mean={s['mean_ms']:8.3f}ms "
            f"p95={s['p95_ms']:8.3f}ms p99={s['p99_ms']:8.3f}ms")
    b = cell.get("batched")
    if b:
        line += (f"  batched[{b['batch']}]={b['decisions_per_s']:8.1f} dec/s "
                 f"{b['requests_per_s']:10.0f} req/s")
    return line


def run(backends, scales, *, d_model: int, batch: int, reps: int,
        decodes=("host", "fused"), head_scales=(), seed: int = 0,
        verbose: bool = True) -> dict:
    pcfg = PolicyConfig(d_model=d_model)
    params, state = corais_init(jax.random.PRNGKey(seed), pcfg)
    cells = []
    for backend in backends:
        for q, z in scales:
            for decode in decodes:
                cell = bench_cell(params, state, pcfg, backend, q, z,
                                  decode=decode, batch=batch, reps=reps)
                cells.append(cell)
                if verbose:
                    print(_fmt_cell(cell))
    for q, z in head_scales:
        for decode in ("host", "fused"):
            cell = bench_head_cell(params, state, pcfg, q, z, decode=decode,
                                   reps=reps)
            cells.append(cell)
            if verbose:
                print(_fmt_cell(cell))
    return {
        "schema": REPORT_SCHEMA,
        "config": {
            "backends": list(backends),
            "scales": [list(s) for s in scales],
            "head_scales": [list(s) for s in head_scales],
            "decodes": list(decodes),
            "d_model": d_model, "batch": batch, "reps": reps,
            "device": jax.devices()[0].platform,
            "pallas_interpret": jax.default_backend() != "tpu",
        },
        "cells": cells,
    }


def run_fastpath(*, d_model: int, reps: int, slo: SLOSpec,
                 buckets=DEFAULT_BUCKETS, seed: int = 0,
                 verbose: bool = True) -> dict:
    """Drive the online fast path over every padding bucket against the SLO
    contract; returns the corais.slo_report.v1 payload."""
    pcfg = PolicyConfig(d_model=d_model)
    params, state = corais_init(jax.random.PRNGKey(seed), pcfg)
    paths = []
    for bq, bz in buckets:
        fp = DecisionFastPath(params, state, pcfg, buckets=((bq, bz),))
        fp.warmup()
        rng_seed = 1000 + bq
        insts = [
            {k: np.asarray(v) for k, v in generate_instance(
                np.random.default_rng(rng_seed + i),
                InstanceConfig(num_edges=bq, num_requests=bz)).items()}
            for i in range(max(3, reps))
        ]
        spec = SLOSpec(slo.p50_ms, slo.p95_ms, slo.p99_ms,
                       name=f"fastpath-{bq}x{bz}")
        report = evaluate_slo(fp, insts, spec)
        paths.append(report)
        if verbose:
            mark = "PASS" if report["pass"] else "FAIL"
            print(f"  fastpath Q={bq:4d} Z={bz:5d} "
                  f"p50={report['p50_ms']:8.3f}/{spec.p50_ms:g}ms "
                  f"p95={report['p95_ms']:8.3f}/{spec.p95_ms:g}ms "
                  f"p99={report['p99_ms']:8.3f}/{spec.p99_ms:g}ms  {mark}")
    return {
        "schema": SLO_SCHEMA,
        "config": {
            "d_model": d_model, "reps": reps,
            "slo_ms": {"p50": slo.p50_ms, "p95": slo.p95_ms,
                       "p99": slo.p99_ms},
            "buckets": [list(b) for b in buckets],
            "device": jax.devices()[0].platform,
            "pallas_interpret": jax.default_backend() != "tpu",
        },
        "paths": paths,
        "pass": all(p["pass"] for p in paths),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backends", default="xla,ref,pallas",
                    help=f"comma list from: {','.join(list_score_backends())}")
    ap.add_argument("--scales", default=None,
                    help="comma list of QxZ (default: full paper matrix "
                         f"{'x'.join(map(str, DEFAULT_QS))} x "
                         f"{'x'.join(map(str, DEFAULT_ZS))})")
    ap.add_argument("--head-scales", default="100x1000",
                    help="comma list of QxZ for isolated head cells "
                         "('' disables)")
    ap.add_argument("--decodes", default="host,fused",
                    help="decision decode routes to time")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8,
                    help="batched-throughput width (0 disables)")
    ap.add_argument("--reps", type=int, default=10)
    ap.add_argument("--fastpath", action="store_true",
                    help="also drive the serving fast path against SLOs "
                         "and write results/slo_report.json")
    ap.add_argument("--slo", default=",".join(map(str, DEFAULT_SLO)),
                    help="fast-path SLO as p50,p95,p99 in ms")
    ap.add_argument("--smoke", action="store_true",
                    help="CI cell: tiny model, small scales, all backends")
    ap.add_argument("--out", default=None,
                    help="report path (default results/policy_latency.json)")
    ap.add_argument("--slo-out", default=None,
                    help="SLO report path (default results/slo_report.json)")
    args = ap.parse_args()

    if args.smoke:
        backends = list_score_backends()
        scales = [(5, 20), (10, 50)]
        head_scales = [(10, 50)]
        buckets = ((5, 20), (10, 50))
        d_model, batch, reps = 32, 4, 3
    else:
        backends = args.backends.split(",")
        if args.scales:
            scales = [tuple(map(int, s.split("x")))
                      for s in args.scales.split(",")]
        else:
            scales = [(q, z) for q in DEFAULT_QS for z in DEFAULT_ZS]
        head_scales = ([tuple(map(int, s.split("x")))
                        for s in args.head_scales.split(",")]
                       if args.head_scales else [])
        buckets = DEFAULT_BUCKETS
        d_model, batch, reps = args.d_model, args.batch, args.reps
    decodes = tuple(args.decodes.split(","))

    print(f"== policy decision latency: {len(backends)} backends x "
          f"{len(scales)} scales x {len(decodes)} decodes "
          f"(d_model={d_model}) ==")
    report = run(backends, scales, d_model=d_model, batch=batch, reps=reps,
                 decodes=decodes, head_scales=head_scales)

    out = args.out or os.path.join(os.path.dirname(__file__), "..",
                                   "results", "policy_latency.json")
    os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
    with open(out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"== report written to {os.path.abspath(out)} ==")

    if args.fastpath:
        p50, p95, p99 = (float(x) for x in args.slo.split(","))
        print(f"== serving fast path vs SLO p50<{p50:g}ms p95<{p95:g}ms "
              f"p99<{p99:g}ms ==")
        slo_report = run_fastpath(d_model=d_model, reps=reps,
                                  slo=SLOSpec(p50, p95, p99),
                                  buckets=buckets)
        slo_out = args.slo_out or os.path.join(
            os.path.dirname(__file__), "..", "results", "slo_report.json")
        os.makedirs(os.path.dirname(os.path.abspath(slo_out)), exist_ok=True)
        with open(slo_out, "w") as f:
            json.dump(slo_report, f, indent=2, sort_keys=True)
        print(f"== SLO report ({'PASS' if slo_report['pass'] else 'FAIL'}) "
              f"written to {os.path.abspath(slo_out)} ==")


if __name__ == "__main__":
    main()
