"""Real-time decision latency of the unified policy inference stack.

The paper's headline claim is millisecond-level scheduling regardless of
system scale; this benchmark measures it directly. For every score backend
(``xla`` einsum head, ``ref`` pure-jnp oracle, ``pallas`` fused kernel —
interpret mode off-TPU, so CPU numbers for pallas are a correctness path,
not kernel speed) and every (Q edges, Z requests) scale it times

  * single  — one full scheduling decision (encode + eq 16-17 score +
              greedy decode) on a compiled fixed-shape instance: mean /
              p50 / p95 wall latency over ``--reps`` calls, plus the
              one-off compile time, and
  * batched — the same decision vmapped over ``--batch`` instances:
              decisions/sec and scheduled requests/sec.

Writes a JSON report (schema corais.policy_latency.v1) next to the other
benchmark artifacts.

Run:  PYTHONPATH=src python benchmarks/policy_latency.py
      PYTHONPATH=src python benchmarks/policy_latency.py \\
          --backends xla,pallas --scales 10x100,100x1000 --batch 16
      PYTHONPATH=src python benchmarks/policy_latency.py --smoke   # CI cell
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import InstanceConfig, generate_batch, generate_instance
from repro.core.inference import make_decision_fn, policy_decide
from repro.core.policy import (PolicyConfig, corais_init,
                               list_score_backends)

REPORT_SCHEMA = "corais.policy_latency.v1"
#: paper scales and beyond: Table II tops out at Q=10, Z=100
DEFAULT_QS = (5, 10, 50, 100)
DEFAULT_ZS = (20, 100, 500, 1000)


def _percentiles(times_s: list) -> dict:
    t = np.asarray(times_s) * 1e3
    return {
        "mean_ms": float(t.mean()),
        "p50_ms": float(np.percentile(t, 50)),
        "p95_ms": float(np.percentile(t, 95)),
        "max_ms": float(t.max()),
    }


def bench_cell(params, state, pcfg: PolicyConfig, backend: str, q: int,
               z: int, *, batch: int, reps: int, seed: int = 999) -> dict:
    """One (backend, Q, Z) cell: single-decision latency + batched
    throughput on freshly generated instances of that exact scale."""
    rng = np.random.default_rng(seed)
    icfg = InstanceConfig(num_edges=q, num_requests=z)
    inst = jax.tree.map(jnp.asarray, generate_instance(rng, icfg))
    key = jax.random.PRNGKey(0)

    # the exact compile-once path the serving controller runs
    decide = make_decision_fn(params, state, pcfg, mode="greedy",
                              backend=backend)

    t0 = time.perf_counter()
    jax.block_until_ready(decide(inst, key))
    compile_s = time.perf_counter() - t0

    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(decide(inst, key))
        times.append(time.perf_counter() - t0)
    single = _percentiles(times)
    single["compile_s"] = compile_s

    cell = {"backend": backend, "num_edges": q, "num_requests": z,
            "single": single}

    if batch > 0:
        binst = jax.tree.map(jnp.asarray, generate_batch(rng, icfg, batch))
        keys = jax.random.split(key, batch)
        vdecide = jax.jit(jax.vmap(
            lambda i, k: policy_decide(k, params, state, i, pcfg,
                                       mode="greedy", backend=backend)))
        jax.block_until_ready(vdecide(binst, keys))  # compile
        btimes = []
        for _ in range(max(1, reps // 2)):
            t0 = time.perf_counter()
            jax.block_until_ready(vdecide(binst, keys))
            btimes.append(time.perf_counter() - t0)
        wall = float(np.mean(btimes))
        cell["batched"] = {
            "batch": batch,
            "wall_ms": wall * 1e3,
            "decisions_per_s": batch / wall,
            "requests_per_s": batch * z / wall,
        }
    return cell


def run(backends, scales, *, d_model: int, batch: int, reps: int,
        seed: int = 0, verbose: bool = True) -> dict:
    pcfg = PolicyConfig(d_model=d_model)
    params, state = corais_init(jax.random.PRNGKey(seed), pcfg)
    cells = []
    for backend in backends:
        for q, z in scales:
            cell = bench_cell(params, state, pcfg, backend, q, z,
                              batch=batch, reps=reps)
            cells.append(cell)
            if verbose:
                s, b = cell["single"], cell.get("batched")
                line = (f"  {backend:7s} Q={q:4d} Z={z:5d} "
                        f"mean={s['mean_ms']:8.3f}ms p95={s['p95_ms']:8.3f}ms")
                if b:
                    line += (f"  batched[{b['batch']}]="
                             f"{b['decisions_per_s']:8.1f} dec/s "
                             f"{b['requests_per_s']:10.0f} req/s")
                print(line)
    return {
        "schema": REPORT_SCHEMA,
        "config": {
            "backends": list(backends),
            "scales": [list(s) for s in scales],
            "d_model": d_model, "batch": batch, "reps": reps,
            "device": jax.devices()[0].platform,
            "pallas_interpret": jax.default_backend() != "tpu",
        },
        "cells": cells,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backends", default="xla,ref,pallas",
                    help=f"comma list from: {','.join(list_score_backends())}")
    ap.add_argument("--scales", default=None,
                    help="comma list of QxZ (default: full paper matrix "
                         f"{'x'.join(map(str, DEFAULT_QS))} x "
                         f"{'x'.join(map(str, DEFAULT_ZS))})")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8,
                    help="batched-throughput width (0 disables)")
    ap.add_argument("--reps", type=int, default=10)
    ap.add_argument("--smoke", action="store_true",
                    help="CI cell: tiny model, small scales, all backends")
    ap.add_argument("--out", default=None,
                    help="report path (default results/policy_latency.json)")
    args = ap.parse_args()

    if args.smoke:
        backends = list_score_backends()
        scales = [(5, 20), (10, 50)]
        d_model, batch, reps = 32, 4, 3
    else:
        backends = args.backends.split(",")
        if args.scales:
            scales = [tuple(map(int, s.split("x")))
                      for s in args.scales.split(",")]
        else:
            scales = [(q, z) for q in DEFAULT_QS for z in DEFAULT_ZS]
        d_model, batch, reps = args.d_model, args.batch, args.reps

    print(f"== policy decision latency: {len(backends)} backends x "
          f"{len(scales)} scales (d_model={d_model}) ==")
    report = run(backends, scales, d_model=d_model, batch=batch, reps=reps)

    out = args.out or os.path.join(os.path.dirname(__file__), "..",
                                   "results", "policy_latency.json")
    os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
    with open(out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"== report written to {os.path.abspath(out)} ==")


if __name__ == "__main__":
    main()
