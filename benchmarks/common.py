"""Shared benchmark utilities: cached policy training + method suites.

Scale note (documented deviation, DESIGN.md §3): the paper trains 40k
batches of 128 instances on 2x2080Ti. This container is one CPU core, so
benchmark policies train a few hundred-to-thousand batches at lr 3e-4
(instead of 1e-5) on the same instance distribution; the qualitative
ordering (CoRaiS ~ REF << Random/Local, real-time decisions) is what the
reproduction checks. ``--full`` raises the budget.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.core import InstanceConfig, PolicyConfig, RLConfig
from repro.core.train import train
from repro.optim import AdamConfig, adam_init

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
POLICY_DIM = 128  # benchmark-scale policy (paper-faithful 256 via --full)


def rl_config(en: int, rn: int, batches: int, d_model: int = POLICY_DIM,
              lr: float = 3e-4) -> RLConfig:
    return RLConfig(
        policy=PolicyConfig(d_model=d_model),
        instance=InstanceConfig(num_edges=en, num_requests=rn),
        batch_size=32,
        num_samples=32,
        lr=lr,
        num_batches=batches,
        seed=0,
    )


def get_trained_policy(en: int = 5, rn: int = 50, batches: int = 800,
                       d_model: int = POLICY_DIM, verbose: bool = True):
    """Train (or load cached) a CoRaiS policy for scale (EN, RN)."""
    cfg = rl_config(en, rn, batches, d_model)
    tag = f"policy_en{en}_rn{rn}_d{d_model}_b{batches}"
    ckpt = Checkpointer(os.path.join(RESULTS, tag), every=10**9,
                        async_save=False)
    from repro.core.policy import corais_init
    template = jax.eval_shape(
        lambda: corais_init(jax.random.PRNGKey(cfg.seed), cfg.policy))
    opt_template = jax.eval_shape(
        lambda: adam_init(template[0], AdamConfig(lr=cfg.lr)))
    restored = ckpt.restore_latest({"params": template[0],
                                    "state": template[1],
                                    "opt_state": opt_template})
    if restored is not None:
        if verbose:
            print(f"# loaded cached policy {tag}")
        return restored["tree"]["params"], restored["tree"]["state"], cfg

    t0 = time.time()
    cb = (lambda m: print(f"#   batch {m['batch']} cost {m['cost_mean']:.3f}")) \
        if verbose else None
    params, state, opt_state, hist = train(cfg, callback=cb)
    if verbose:
        print(f"# trained {batches} batches in {time.time()-t0:.0f}s "
              f"(cost {hist[0]['cost_mean']:.3f} -> {hist[-1]['cost_mean']:.3f})")
    ckpt.save(batches, {"params": params, "state": state,
                        "opt_state": opt_state})
    ckpt.wait()
    return params, state, cfg


def get_temporal_policy(en: int = 5, batches: int = 200,
                        d_model: int = POLICY_DIM,
                        scenario_name: str = "uniform_iid",
                        verbose: bool = True):
    """Train (or load cached) a CoRaiS policy with temporal REINFORCE on
    whole engine rollouts (core.train.temporal_train) — the counterpart of
    :func:`get_trained_policy`'s static i.i.d. snapshots, for the
    policy-vs-baseline rollout comparison."""
    from repro.core.policy import corais_init
    from repro.core.train import TemporalRLConfig, temporal_train
    from repro.serving.engine import EngineConfig

    cfg = TemporalRLConfig(
        policy=PolicyConfig(d_model=d_model),
        engine=EngineConfig(num_edges=en),
        scenario=scenario_name,
        batch_size=8,
        lr=3e-4,
        num_batches=batches,
        seed=0,
        # scanned-epoch trainer: episodes drawn in-jit, 25 updates per
        # dispatch, metrics drained (and logged) once per epoch
        device_episodes=True,
        epoch_len=25,
    )
    tag = f"policy_temporal_en{en}_d{d_model}_b{batches}_{scenario_name}"
    ckpt = Checkpointer(os.path.join(RESULTS, tag), every=10**9,
                        async_save=False)
    template = jax.eval_shape(
        lambda: corais_init(jax.random.PRNGKey(cfg.seed), cfg.policy))
    restored = ckpt.restore_latest({"params": template[0],
                                    "state": template[1]})
    if restored is not None:
        if verbose:
            print(f"# loaded cached temporal policy {tag}")
        return restored["tree"]["params"], restored["tree"]["state"], cfg

    t0 = time.time()
    cb = (lambda m: print(f"#   epoch to batch {m['batch']} "
                          f"cost {m['cost_mean']:.3f}")) if verbose else None
    params, state, _, hist = temporal_train(cfg, callback=cb)
    if verbose:
        print(f"# temporal-trained {batches} batches in {time.time()-t0:.0f}s "
              f"(cost {hist[0]['cost_mean']:.3f} -> {hist[-1]['cost_mean']:.3f})")
    ckpt.save(batches, {"params": params, "state": state})
    ckpt.wait()
    return params, state, cfg


def get_resilient_policy(en: int = 5, batches: int = 300,
                         d_model: int = POLICY_DIM,
                         scenario_name: str = "chaos-rolling-failure",
                         slo: float = 3.0, slo_penalty: float = 10.0,
                         verbose: bool = True):
    """Train (or load cached) the admission head of a CoRaiS policy on
    fault-injected rollouts of a chaos scenario — the policy-with-admission
    column of the resilience fault matrix.

    The dispatch weights warm-start from the static-trained policy
    (:func:`get_trained_policy`) and stay frozen
    (``TemporalRLConfig(freeze_dispatch=True)``): container-scale
    episode-REINFORCE at batch 8 is noisy enough to destroy a good
    dispatch policy, and the fault matrix should measure what admission
    *adds* on identical dispatch, not dispatch-training budget. Only the
    admit head (fresh, near-admit-all bias) trains, against episode cost
    ``mean_response + slo_penalty * slo_violation_frac`` where sheds and
    drops count as violations — shed-everything costs ``slo_penalty``
    flat and loses to serving what fits."""
    from repro.core.policy import corais_init
    from repro.core.train import TemporalRLConfig, temporal_train
    from repro.serving.engine import EngineConfig

    # admit_bias 1.0 (not the registry default 2.0): the episode-level
    # REINFORCE signal moves logits slowly, and eval thresholds at 0 —
    # starting closer to the boundary lets thresholded shedding emerge
    # within a container-scale budget. lr is high because only the small
    # admit head trains.
    cfg = TemporalRLConfig(
        policy=PolicyConfig(d_model=d_model, admit_head=True,
                            admit_bias=1.0),
        # overload scenarios outrun the default 16-wide admission queue
        engine=EngineConfig(num_edges=en, max_per_round=64),
        scenario=scenario_name,
        batch_size=8,
        lr=1e-3,
        num_batches=batches,
        seed=0,
        admission=True,
        slo=slo,
        slo_penalty=slo_penalty,
        freeze_dispatch=True,
        device_episodes=True,
        epoch_len=25,
    )
    tag = (f"policy_resilient_admit_en{en}_d{d_model}_b{batches}_"
           f"{scenario_name}")
    ckpt = Checkpointer(os.path.join(RESULTS, tag), every=10**9,
                        async_save=False)
    template = jax.eval_shape(
        lambda: corais_init(jax.random.PRNGKey(cfg.seed), cfg.policy))
    restored = ckpt.restore_latest({"params": template[0],
                                    "state": template[1]})
    if restored is not None:
        if verbose:
            print(f"# loaded cached resilient policy {tag}")
        return restored["tree"]["params"], restored["tree"]["state"], cfg

    sparams, sstate, _ = get_trained_policy(en, 50, 800, d_model=d_model,
                                            verbose=verbose)
    params, state = corais_init(jax.random.PRNGKey(cfg.seed), cfg.policy)
    params = dict(sparams, admit=params["admit"])
    state = sstate

    t0 = time.time()
    cb = (lambda m: print(f"#   epoch to batch {m['batch']} "
                          f"cost {m['cost_mean']:.3f} "
                          f"shed {m['shed']:.1f}")) if verbose else None
    params, state, _, hist = temporal_train(cfg, params=params, state=state,
                                            callback=cb)
    if verbose:
        print(f"# resilient-trained (admit head) {batches} batches in "
              f"{time.time()-t0:.0f}s "
              f"(cost {hist[0]['cost_mean']:.3f} -> {hist[-1]['cost_mean']:.3f})")
    ckpt.save(batches, {"params": params, "state": state})
    ckpt.wait()
    return params, state, cfg


def get_cloud_policy(en: int = 5, batches: int = 300,
                     d_model: int = POLICY_DIM,
                     scenario_name: str = "cloud-cache-churn",
                     deadline_penalty: float = 8.0, verbose: bool = True):
    """Train (or load cached) the deadline/cache-aware CoRaiS policy for an
    edge-cloud scenario — the ``batched-corais-cloud`` column of the
    scenario sweep.

    Tier features are on (``PolicyConfig(tier_features=True)``: per-node
    tier + cache occupancy, per-request slack / priority / cached-bit) and
    the episode cost adds ``deadline_penalty * deadline_miss_frac``, so
    temporal REINFORCE on the scenario's own rollouts (temporal_train
    threads the registered CloudSpec/CacheSpec into the engine) trains
    dispatch to trade response time against deadline misses with the cache
    and WAN-RTT state visible.

    The dispatch weights warm-start from the static-trained flat-tier
    policy: the extra tier/deadline rows of the edge/request projections
    start at zero, so at batch 0 the policy scores nodes exactly like the
    cache-oblivious ``batched-corais`` column and training only has to
    learn what the new features add."""
    import jax.numpy as jnp

    from repro.core.policy import EDGE_FEATURES, REQ_FEATURES, corais_init
    from repro.core.train import TemporalRLConfig, temporal_train
    from repro.serving.engine import EngineConfig

    cfg = TemporalRLConfig(
        policy=PolicyConfig(d_model=d_model, tier_features=True),
        # deadline-heavy scenarios burst past the default admission width
        engine=EngineConfig(num_edges=en, max_per_round=64),
        scenario=scenario_name,
        batch_size=8,
        lr=1e-3,
        num_batches=batches,
        seed=0,
        deadline_penalty=deadline_penalty,
        device_episodes=True,
        epoch_len=25,
    )
    tag = f"policy_cloud_en{en}_d{d_model}_b{batches}_{scenario_name}"
    ckpt = Checkpointer(os.path.join(RESULTS, tag), every=10**9,
                        async_save=False)
    template = jax.eval_shape(
        lambda: corais_init(jax.random.PRNGKey(cfg.seed), cfg.policy))
    restored = ckpt.restore_latest({"params": template[0],
                                    "state": template[1]})
    if restored is not None:
        if verbose:
            print(f"# loaded cached cloud policy {tag}")
        return restored["tree"]["params"], restored["tree"]["state"], cfg

    sparams, sstate, _ = get_trained_policy(en, 50, 800, d_model=d_model,
                                            verbose=verbose)
    fresh, _ = corais_init(jax.random.PRNGKey(cfg.seed), cfg.policy)
    params = dict(sparams)
    for key, base in (("edge_proj", EDGE_FEATURES),
                      ("req_proj", REQ_FEATURES)):
        w = jnp.zeros_like(fresh[key]["w"]).at[:base].set(sparams[key]["w"])
        params[key] = {"w": w, "b": sparams[key]["b"]}
    state = sstate

    t0 = time.time()
    cb = (lambda m: print(f"#   epoch to batch {m['batch']} "
                          f"cost {m['cost_mean']:.3f} "
                          f"dl_miss {m.get('deadline_miss_frac', 0.0):.3f}")) \
        if verbose else None
    params, state, _, hist = temporal_train(cfg, params=params, state=state,
                                            callback=cb)
    if verbose:
        print(f"# cloud-trained {batches} batches in {time.time()-t0:.0f}s "
              f"(cost {hist[0]['cost_mean']:.3f} -> {hist[-1]['cost_mean']:.3f})")
    ckpt.save(batches, {"params": params, "state": state})
    ckpt.wait()
    return params, state, cfg


def eval_instances(en: int, rn: int, n: int, seed: int = 999):
    rng = np.random.default_rng(seed)
    from repro.core import generate_instance
    return [generate_instance(rng, InstanceConfig(num_edges=en, num_requests=rn))
            for _ in range(n)]


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
