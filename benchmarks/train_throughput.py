"""Temporal-RL training throughput: host loop vs scanned epoch vs sharded.

Measures *updates (batches) per second* and *episode-rounds per second*
(batches/s x batch_size x num_rounds) for the three execution paths of the
temporal REINFORCE trainer on the same scenario:

* ``host-loop`` — the pre-epoch trainer semantics: one jitted update per
  batch, episodes materialized by the host numpy sampler each batch,
  faults attached on host, and a blocking ``float(loss)`` sync after every
  update (the dispatch bubble the scanned path removes).
* ``scan-epoch`` — :func:`repro.core.train.make_temporal_epoch_step`: K
  updates per dispatch under one ``lax.scan``, episodes and faults drawn
  in-jit by the device sampler, metrics stacked on device and drained once
  per epoch.
* ``sharded`` — the same epoch step shard_map'd over the ``("fleet",)``
  device mesh (batch axis data-parallel, pmean-averaged grads). Skipped
  with a note when only one device is visible — launch through
  ``HOST_DEVICES=8 benchmarks/run_hw.sh train_throughput`` to force a
  host mesh (single-core containers then record *parity*, not speedup:
  8 virtual devices share one core).

Timing is steady-state: every mode runs one untimed warmup dispatch
(compilation + first materialization), then the measured window, closed
with a single ``block_until_ready``. The host-side episode sampling is
*inside* the measured window for every mode — that asymmetry (numpy
sampler on host vs jax sampler in-jit) is precisely what the benchmark
exists to show, and is why the chaos scenario (rate 180, faulted) is the
headline cell: its host materialization cost dominates the host loop.

Run:  PYTHONPATH=src python benchmarks/train_throughput.py --smoke
      PYTHONPATH=src python benchmarks/train_throughput.py
      HOST_DEVICES=8 benchmarks/run_hw.sh train_throughput --smoke \\
          --out results/train_throughput_smoke.json
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PolicyConfig
from repro.core.policy import corais_init
from repro.core.train import (TemporalRLConfig, _cluster_seeds,
                              _element_keys, make_temporal_epoch_step,
                              make_temporal_train_step,
                              resolve_temporal_config)
from repro.optim import AdamConfig, adam_init
from repro.resilience import faults as faults_lib
from repro.serving import engine as engine_lib
from repro.serving.engine import EngineConfig
from repro.workloads import materialize_round_batch, scenario

REPORT_SCHEMA = "corais.train_throughput.v1"
HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT_OUT = os.path.join(HERE, "..", "results", "train_throughput.json")

_ARRIVAL_SALT = 0xA7
_FAULT_SEED_SALT = 0xFA


def build_cfg(name: str, *, batch_size: int, num_rounds: int,
              epoch_len: int) -> TemporalRLConfig:
    width = 64 if name.startswith("chaos") else 16
    return TemporalRLConfig(
        policy=PolicyConfig(d_model=32, ff_hidden=64, edge_layers=1,
                            request_layers=1, norm="layer"),
        engine=EngineConfig(num_edges=5, num_rounds=num_rounds,
                            max_per_round=width),
        scenario=name, batch_size=batch_size, lr=3e-4, seed=0,
        device_episodes=True, epoch_len=epoch_len)


def bench_host_loop(cfg: TemporalRLConfig, *, updates: int,
                    warmup: int) -> dict:
    """Pre-epoch trainer semantics: host episodes + per-batch sync."""
    cfg, fspec = resolve_temporal_config(cfg)
    ecfg = cfg.engine
    wl = scenario(cfg.scenario)
    key = jax.random.PRNGKey(cfg.seed)
    params, state = corais_init(jax.random.split(key)[1], cfg.policy)
    opt = adam_init(params, AdamConfig(lr=cfg.lr))
    step_fn, _ = make_temporal_train_step(cfg)

    def one(b, params, opt):
        sim0 = engine_lib.init_batch(ecfg, _cluster_seeds(cfg, b))
        arrivals = materialize_round_batch(
            wl, ecfg.num_edges, ecfg.num_rounds, ecfg.round_interval,
            cfg.batch_size,
            base_seed=int(np.random.default_rng(
                (cfg.seed, _ARRIVAL_SALT, b)).integers(0, 2**31 - 1)),
            max_per_round=ecfg.max_per_round, overflow="clip")
        if fspec is not None:
            arrivals = faults_lib.attach_fault_batch(
                arrivals, fspec, ecfg.num_edges,
                seeds=np.random.default_rng(
                    (cfg.seed, _FAULT_SEED_SALT, b)).integers(
                        0, 2**31 - 1, size=cfg.batch_size))
        skeys = _element_keys(key, b, cfg.batch_size)
        params, opt, metrics = step_fn(
            params, state, opt, jax.tree.map(jnp.asarray, sim0),
            jax.tree.map(jnp.asarray, arrivals), skeys)
        float(metrics["loss"])       # the per-batch blocking sync
        return params, opt

    for b in range(warmup):
        params, opt = one(b, params, opt)
    t0 = time.perf_counter()
    for b in range(warmup, warmup + updates):
        params, opt = one(b, params, opt)
    jax.block_until_ready(params)
    return {"wall_s": time.perf_counter() - t0, "updates": updates}


def bench_epoch(cfg: TemporalRLConfig, *, updates: int, warmup: int,
                mesh=None) -> dict:
    """Scanned-epoch path (optionally shard_map'd over ``mesh``)."""
    cfg, _ = resolve_temporal_config(cfg)
    ecfg = cfg.engine
    key = jax.random.PRNGKey(cfg.seed)
    params, state = corais_init(jax.random.split(key)[1], cfg.policy)
    opt = adam_init(params, AdamConfig(lr=cfg.lr))
    step_fn, _ = make_temporal_epoch_step(cfg, mesh=mesh)
    K = max(1, cfg.epoch_len)

    def chunk(b0, k, params, opt):
        bs = list(range(b0, b0 + k))
        stacks = [engine_lib.init_batch(ecfg, _cluster_seeds(cfg, bi))
                  for bi in bs]
        sim0 = {key_: jnp.asarray(np.stack([s[key_] for s in stacks]))
                for key_ in stacks[0]}
        ekeys = jnp.stack([_element_keys(key, bi, cfg.batch_size)
                           for bi in bs])
        params, opt, metrics = step_fn(params, state, opt, sim0, ekeys)
        return params, opt, metrics

    b = 0
    for _ in range(max(1, (warmup + K - 1) // K)):
        params, opt, metrics = chunk(b, K, params, opt)
        b += K
    jax.block_until_ready(params)
    n_chunks = (updates + K - 1) // K
    done = 0
    t0 = time.perf_counter()
    for _ in range(n_chunks):
        params, opt, metrics = chunk(b, K, params, opt)
        b += K
        done += K
    jax.block_until_ready((params, metrics))
    return {"wall_s": time.perf_counter() - t0, "updates": done}


def run_cell(mode: str, cfg: TemporalRLConfig, *, updates: int, warmup: int,
             mesh=None) -> dict:
    if mode == "host-loop":
        res = bench_host_loop(cfg, updates=updates, warmup=warmup)
    else:
        res = bench_epoch(cfg, updates=updates, warmup=warmup, mesh=mesh)
    bps = res["updates"] / res["wall_s"]
    return {
        "mode": mode, "scenario": cfg.scenario,
        "batch_size": cfg.batch_size, "num_rounds": cfg.engine.num_rounds,
        "epoch_len": max(1, cfg.epoch_len) if mode != "host-loop" else 1,
        "updates": res["updates"], "wall_s": round(res["wall_s"], 4),
        "batches_per_sec": round(bps, 4),
        "episode_rounds_per_sec": round(
            bps * cfg.batch_size * cfg.engine.num_rounds, 2),
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--scenarios", default="uniform_iid,chaos-rolling-failure")
    ap.add_argument("--modes", default="host-loop,scan-epoch,sharded")
    ap.add_argument("--updates", type=int, default=24,
                    help="measured updates per (mode, scenario) cell")
    ap.add_argument("--warmup", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--epoch-len", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="CI preset: fewer updates/rounds, same cell grid")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    if args.smoke:
        args.updates, args.warmup = 6, 2
        args.rounds, args.epoch_len = 6, 3

    modes = [m.strip() for m in args.modes.split(",") if m.strip()]
    mesh = None
    if "sharded" in modes:
        if len(jax.devices()) > 1:
            from repro.launch.mesh import make_fleet_mesh
            mesh = make_fleet_mesh()
            shards = int(np.prod(list(mesh.devices.shape)))
            if args.batch_size % shards:
                raise SystemExit(f"--batch-size {args.batch_size} must "
                                 f"divide over {shards} devices")
        else:
            print("note: single device visible — skipping 'sharded' "
                  "(use HOST_DEVICES=8 benchmarks/run_hw.sh ...)")
            modes = [m for m in modes if m != "sharded"]

    cells = []
    for name in [s.strip() for s in args.scenarios.split(",") if s.strip()]:
        cfg = build_cfg(name, batch_size=args.batch_size,
                        num_rounds=args.rounds, epoch_len=args.epoch_len)
        for mode in modes:
            cell = run_cell(mode, cfg, updates=args.updates,
                            warmup=args.warmup,
                            mesh=mesh if mode == "sharded" else None)
            cells.append(cell)
            print(f"  {mode:10s} {name:22s} "
                  f"{cell['batches_per_sec']:8.3f} batches/s "
                  f"{cell['episode_rounds_per_sec']:10.1f} ep-rounds/s "
                  f"({cell['updates']} updates in {cell['wall_s']:.2f}s)")
    by = {(c["scenario"],): {} for c in cells}
    for c in cells:
        by[(c["scenario"],)][c["mode"]] = c["batches_per_sec"]
    for (name,), d in by.items():
        if "host-loop" in d and "scan-epoch" in d:
            print(f"  scan-epoch speedup over host-loop ({name}): "
                  f"{d['scan-epoch'] / d['host-loop']:.2f}x")

    report = {
        "schema": REPORT_SCHEMA,
        "smoke": bool(args.smoke),
        "devices": len(jax.devices()),
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "cells": cells,
    }
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"report written to {os.path.abspath(args.out)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
