"""Guard training throughput in CI: scanned-epoch regression tripwire.

Compares per-(mode, scenario, batch, rounds) batches/sec from a fresh
``train_throughput.py --smoke`` report against the committed baseline
(``benchmarks/train_throughput_baseline.json``) and exits non-zero when
any cell got slower than ``baseline / --factor`` (default 4x). Like
``check_latency_drift.py``, the generous factor absorbs runner variance —
this catches order-of-magnitude regressions (the epoch scan silently
falling back to per-update dispatch, device episode generation dropping
back to host numpy, a retrace per chunk), not percent-level noise.

Baseline cells missing from the fresh report fail by default (a dropped
mode or renamed scenario would otherwise pass forever); pass
``--allow-missing`` during an intentional grid shrink. Report cells with
no baseline (e.g. the sharded mode on a runner with more devices) are
printed and skipped.

Run:  HOST_DEVICES=8 benchmarks/run_hw.sh train_throughput --smoke \\
          --out results/train_throughput_smoke.json
      PYTHONPATH=src python benchmarks/check_train_throughput.py

Refresh the committed baseline after an intentional change:

      PYTHONPATH=src python benchmarks/check_train_throughput.py \\
          --write-baseline
"""
from __future__ import annotations

import argparse
import json
import os

BASELINE_SCHEMA = "corais.train_throughput_baseline.v1"
REPORT_SCHEMA = "corais.train_throughput.v1"
HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT_REPORT = os.path.join(HERE, "..", "results",
                              "train_throughput_smoke.json")
DEFAULT_BASELINE = os.path.join(HERE, "train_throughput_baseline.json")


def _key(cell: dict) -> tuple:
    return (cell["mode"], cell["scenario"], int(cell["batch_size"]),
            int(cell["num_rounds"]))


def load_report_cells(path: str) -> dict:
    with open(path) as f:
        report = json.load(f)
    if report.get("schema") != REPORT_SCHEMA:
        raise SystemExit(f"error: {path} is not a {REPORT_SCHEMA} report")
    return {_key(c): float(c["batches_per_sec"]) for c in report["cells"]}


def write_baseline(report_path: str, baseline_path: str) -> None:
    cells = load_report_cells(report_path)
    payload = {
        "schema": BASELINE_SCHEMA,
        "source_report": os.path.basename(report_path),
        "cells": [{"mode": m, "scenario": s, "batch_size": b,
                   "num_rounds": r, "batches_per_sec": v}
                  for (m, s, b, r), v in sorted(cells.items())],
    }
    with open(baseline_path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"baseline written to {os.path.abspath(baseline_path)} "
          f"({len(cells)} cells)")


def check(report_path: str, baseline_path: str, *, factor: float,
          allow_missing: bool = False) -> int:
    with open(baseline_path) as f:
        baseline = json.load(f)
    if baseline.get("schema") != BASELINE_SCHEMA:
        print(f"error: {baseline_path} is not a {BASELINE_SCHEMA} file")
        return 2
    base = {_key(c): float(c["batches_per_sec"]) for c in baseline["cells"]}
    current = load_report_cells(report_path)
    common = sorted(set(base) & set(current))
    if not common:
        print("error: no overlapping (mode, scenario, batch, rounds) cells "
              "between report and baseline — regenerate one of them")
        return 2

    failures = []
    for key in common:
        limit = base[key] / factor
        status = "ok" if current[key] >= limit else "SLOWDOWN"
        if status != "ok":
            failures.append(key)
        m, s, b, r = key
        print(f"  {m:10s} {s:22s} B={b:3d} R={r:3d} "
              f"{current[key]:8.3f} b/s  baseline={base[key]:8.3f}  "
              f"floor={limit:8.3f}  {status}")
    for m, s, b, r in sorted(set(current) - set(base)):
        print(f"  {m:10s} {s:22s} B={b:3d} R={r:3d} "
              f"(no baseline cell, skipped)")
    missing = sorted(set(base) - set(current))
    for m, s, b, r in missing:
        print(f"  {m:10s} {s:22s} B={b:3d} R={r:3d} "
              f"(baseline cell MISSING from report)")
    if failures:
        print(f"FAIL: {len(failures)}/{len(common)} cells slower than "
              f"baseline/{factor:.1f}")
        return 1
    if missing and not allow_missing:
        print(f"FAIL: {len(missing)} baseline cell(s) missing from the "
              f"report — regenerate it over the full grid or pass "
              f"--allow-missing for an intentional shrink")
        return 1
    print(f"OK: {len(common)} cells within {factor:.1f}x of baseline"
          + (f" ({len(missing)} missing allowed)" if missing else ""))
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--report", default=DEFAULT_REPORT)
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--factor", type=float, default=4.0)
    ap.add_argument("--allow-missing", action="store_true")
    ap.add_argument("--write-baseline", action="store_true")
    args = ap.parse_args()
    if args.write_baseline:
        write_baseline(args.report, args.baseline)
        return 0
    return check(args.report, args.baseline, factor=args.factor,
                 allow_missing=args.allow_missing)


if __name__ == "__main__":
    raise SystemExit(main())
