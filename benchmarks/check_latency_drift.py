"""Guard the paper's real-time claim in CI: p95 decision-latency drift.

Compares the per-(backend, Q, Z) single-decision p95 from a fresh
``policy_latency.py`` report against the committed baseline
(``benchmarks/policy_latency_baseline.json``) and exits non-zero when any
cell regressed beyond ``--factor`` (default 4x, with a ``--floor-ms``
absolute floor so microsecond-level cells don't trip on scheduler noise).
The generous factor absorbs machine-to-machine variance — the check is a
drift tripwire for order-of-magnitude regressions (an accidentally
un-jitted path, a fused kernel falling back to per-request Python), not a
microbenchmark.

Run:  PYTHONPATH=src python benchmarks/policy_latency.py --smoke
      PYTHONPATH=src python benchmarks/check_latency_drift.py

Refresh the committed baseline after an intentional perf change:

      PYTHONPATH=src python benchmarks/check_latency_drift.py \\
          --write-baseline
"""
from __future__ import annotations

import argparse
import json
import os
import sys

BASELINE_SCHEMA = "corais.policy_latency_baseline.v1"
HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT_REPORT = os.path.join(HERE, "..", "results", "policy_latency.json")
DEFAULT_BASELINE = os.path.join(HERE, "policy_latency_baseline.json")


def _cell_key(cell: dict) -> tuple:
    return (cell["backend"], int(cell["num_edges"]),
            int(cell["num_requests"]))


def load_report_cells(path: str) -> dict:
    """{(backend, Q, Z): p95_ms} from a corais.policy_latency.v1 report."""
    with open(path) as f:
        report = json.load(f)
    return {_cell_key(c): float(c["single"]["p95_ms"])
            for c in report["cells"]}


def write_baseline(report_path: str, baseline_path: str) -> None:
    cells = load_report_cells(report_path)
    payload = {
        "schema": BASELINE_SCHEMA,
        "source_report": os.path.basename(report_path),
        "cells": [{"backend": b, "num_edges": q, "num_requests": z,
                   "p95_ms": p95}
                  for (b, q, z), p95 in sorted(cells.items())],
    }
    with open(baseline_path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"baseline written to {os.path.abspath(baseline_path)} "
          f"({len(cells)} cells)")


def check(report_path: str, baseline_path: str, *, factor: float,
          floor_ms: float) -> int:
    with open(baseline_path) as f:
        baseline = json.load(f)
    if baseline.get("schema") != BASELINE_SCHEMA:
        print(f"error: {baseline_path} is not a {BASELINE_SCHEMA} file")
        return 2
    base = {_cell_key(c): float(c["p95_ms"]) for c in baseline["cells"]}
    current = load_report_cells(report_path)
    common = sorted(set(base) & set(current))
    if not common:
        print("error: no overlapping (backend, Q, Z) cells between report "
              "and baseline — regenerate one of them")
        return 2

    failures = []
    for key in common:
        limit = max(floor_ms, factor * base[key])
        status = "ok" if current[key] <= limit else "DRIFT"
        if status == "DRIFT":
            failures.append(key)
        b, q, z = key
        print(f"  {b:7s} Q={q:4d} Z={z:5d} p95={current[key]:8.3f}ms "
              f"baseline={base[key]:8.3f}ms limit={limit:8.3f}ms {status}")
    skipped = sorted(set(current) - set(base))
    for b, q, z in skipped:
        print(f"  {b:7s} Q={q:4d} Z={z:5d} (no baseline cell, skipped)")
    if failures:
        print(f"FAIL: {len(failures)}/{len(common)} cells regressed beyond "
              f"{factor:.1f}x baseline (floor {floor_ms:.1f}ms)")
        return 1
    print(f"OK: {len(common)} cells within {factor:.1f}x of baseline")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--report", default=DEFAULT_REPORT,
                    help="fresh policy_latency.py report to check")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed baseline JSON")
    ap.add_argument("--factor", type=float, default=4.0,
                    help="allowed p95 multiple over baseline")
    ap.add_argument("--floor-ms", type=float, default=1.0,
                    help="cells under this absolute p95 never fail")
    ap.add_argument("--write-baseline", action="store_true",
                    help="distill --report into --baseline and exit")
    args = ap.parse_args()

    if args.write_baseline:
        write_baseline(args.report, args.baseline)
        return
    sys.exit(check(args.report, args.baseline, factor=args.factor,
                   floor_ms=args.floor_ms))


if __name__ == "__main__":
    main()
