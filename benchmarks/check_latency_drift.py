"""Guard the paper's real-time claim in CI: p95 decision-latency drift.

Compares the per-(backend, Q, Z, stage, decode) single-decision p95 from a
fresh ``policy_latency.py`` report against the committed baseline
(``benchmarks/policy_latency_baseline.json``) and exits non-zero when any
cell regressed beyond ``--factor`` (default 4x, with a ``--floor-ms``
absolute floor so microsecond-level cells don't trip on scheduler noise).
The generous factor absorbs machine-to-machine variance — the check is a
drift tripwire for order-of-magnitude regressions (an accidentally
un-jitted path, a fused kernel falling back to per-request Python, the
fused decode silently materializing (Z, Q) again), not a microbenchmark.

Reads both report schemas: corais.policy_latency.v1 cells (no stage/decode
fields) key as (backend, Q, Z, "decision", "host"), so a v2 report checks
cleanly against a v1 baseline and vice versa.

``--slo-report results/slo_report.json`` additionally prints the fast-path
SLO pass/fail table (informational: SLO targets are machine-dependent wall
clocks, so the table is surfaced as a CI artifact rather than a gate; the
gate is the drift factor above).

Run:  PYTHONPATH=src python benchmarks/policy_latency.py --smoke
      PYTHONPATH=src python benchmarks/check_latency_drift.py

Refresh the committed baseline after an intentional perf change:

      PYTHONPATH=src python benchmarks/check_latency_drift.py \\
          --write-baseline
"""
from __future__ import annotations

import argparse
import json
import os
import sys

BASELINE_SCHEMA = "corais.policy_latency_baseline.v2"
#: accepted on read; v1 cells default stage=decision, decode=host
LEGACY_BASELINE_SCHEMAS = ("corais.policy_latency_baseline.v1",)
HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT_REPORT = os.path.join(HERE, "..", "results", "policy_latency.json")
DEFAULT_BASELINE = os.path.join(HERE, "policy_latency_baseline.json")
DEFAULT_SLO_REPORT = os.path.join(HERE, "..", "results", "slo_report.json")


def _cell_key(cell: dict) -> tuple:
    return (cell["backend"], int(cell["num_edges"]),
            int(cell["num_requests"]), cell.get("stage", "decision"),
            cell.get("decode", "host"))


def load_report_cells(path: str) -> dict:
    """{(backend, Q, Z, stage, decode): p95_ms} from a policy_latency
    report (v1 or v2)."""
    with open(path) as f:
        report = json.load(f)
    return {_cell_key(c): float(c["single"]["p95_ms"])
            for c in report["cells"]}


def write_baseline(report_path: str, baseline_path: str) -> None:
    cells = load_report_cells(report_path)
    payload = {
        "schema": BASELINE_SCHEMA,
        "source_report": os.path.basename(report_path),
        "cells": [{"backend": b, "num_edges": q, "num_requests": z,
                   "stage": stage, "decode": decode, "p95_ms": p95}
                  for (b, q, z, stage, decode), p95 in sorted(cells.items())],
    }
    with open(baseline_path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"baseline written to {os.path.abspath(baseline_path)} "
          f"({len(cells)} cells)")


def print_slo_table(slo_path: str) -> None:
    """Informational fast-path SLO table from a corais.slo_report.v1 file."""
    with open(slo_path) as f:
        report = json.load(f)
    print(f"fast-path SLO table ({slo_path}):")
    for p in report["paths"]:
        mark = "PASS" if p["pass"] else "FAIL"
        print(f"  {p['name']:22s} "
              f"p50={p['p50_ms']:8.3f}/{p['p50_slo_ms']:g}ms "
              f"p95={p['p95_ms']:8.3f}/{p['p95_slo_ms']:g}ms "
              f"p99={p['p99_ms']:8.3f}/{p['p99_slo_ms']:g}ms  {mark}")
    overall = "PASS" if report.get("pass") else "FAIL"
    print(f"  overall: {overall} (informational — not a CI gate)")


def check(report_path: str, baseline_path: str, *, factor: float,
          floor_ms: float, allow_missing: bool = False) -> int:
    with open(baseline_path) as f:
        baseline = json.load(f)
    schema = baseline.get("schema")
    if schema != BASELINE_SCHEMA and schema not in LEGACY_BASELINE_SCHEMAS:
        print(f"error: {baseline_path} is not a {BASELINE_SCHEMA} file "
              f"(or legacy {', '.join(LEGACY_BASELINE_SCHEMAS)})")
        return 2
    base = {_cell_key(c): float(c["p95_ms"]) for c in baseline["cells"]}
    current = load_report_cells(report_path)
    common = sorted(set(base) & set(current))
    if not common:
        print("error: no overlapping (backend, Q, Z, stage, decode) cells "
              "between report and baseline — regenerate one of them")
        return 2

    failures = []
    for key in common:
        limit = max(floor_ms, factor * base[key])
        status = "ok" if current[key] <= limit else "DRIFT"
        if status == "DRIFT":
            failures.append(key)
        b, q, z, stage, decode = key
        print(f"  {b:7s} {stage:8s} {decode:5s} Q={q:4d} Z={z:5d} "
              f"p95={current[key]:8.3f}ms baseline={base[key]:8.3f}ms "
              f"limit={limit:8.3f}ms {status}")
    skipped = sorted(set(current) - set(base))
    for b, q, z, stage, decode in skipped:
        print(f"  {b:7s} {stage:8s} {decode:5s} Q={q:4d} Z={z:5d} "
              f"(no baseline cell, skipped)")
    # Baseline cells the fresh report never measured are a silent hole in
    # the gate (a renamed backend or dropped grid point would pass forever),
    # so they fail by default; --allow-missing opts out during intentional
    # grid shrinks.
    missing = sorted(set(base) - set(current))
    for b, q, z, stage, decode in missing:
        print(f"  {b:7s} {stage:8s} {decode:5s} Q={q:4d} Z={z:5d} "
              f"(baseline cell MISSING from report)")
    if failures:
        print(f"FAIL: {len(failures)}/{len(common)} cells regressed beyond "
              f"{factor:.1f}x baseline (floor {floor_ms:.1f}ms)")
        return 1
    if missing and not allow_missing:
        print(f"FAIL: {len(missing)} baseline cell(s) missing from the "
              f"report — regenerate it over the full grid or pass "
              f"--allow-missing for an intentional shrink")
        return 1
    print(f"OK: {len(common)} cells within {factor:.1f}x of baseline"
          + (f" ({len(missing)} missing cell(s) allowed)" if missing else ""))
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--report", default=DEFAULT_REPORT,
                    help="fresh policy_latency.py report to check")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed baseline JSON")
    ap.add_argument("--factor", type=float, default=4.0,
                    help="allowed p95 multiple over baseline")
    ap.add_argument("--floor-ms", type=float, default=1.0,
                    help="cells under this absolute p95 never fail")
    ap.add_argument("--write-baseline", action="store_true",
                    help="distill --report into --baseline and exit")
    ap.add_argument("--allow-missing", action="store_true",
                    help="do not fail when baseline cells are absent from "
                         "the report (intentional grid shrink)")
    ap.add_argument("--slo-report", nargs="?", const=DEFAULT_SLO_REPORT,
                    default=None,
                    help="also print the fast-path SLO table from this "
                         "slo_report.json (informational)")
    args = ap.parse_args()

    if args.write_baseline:
        write_baseline(args.report, args.baseline)
        return
    if args.slo_report and os.path.exists(args.slo_report):
        print_slo_table(args.slo_report)
    sys.exit(check(args.report, args.baseline, factor=args.factor,
                   floor_ms=args.floor_ms, allow_missing=args.allow_missing))


if __name__ == "__main__":
    main()
