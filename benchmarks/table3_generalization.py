"""Paper Table III — generalization: a policy trained on a small scale is
applied, unchanged, to larger systems. The padded-instance design means the
same jitted forward serves any (EN, RN) below the pad."""
from __future__ import annotations

import argparse

from benchmarks.common import csv_line, eval_instances, get_trained_policy
from repro.core.evaluate import evaluate_methods, standard_method_suite


def run(train_scale=(5, 50), test_scales=((10, 100), (15, 150)),
        n_instances=10, batches=800, ref_budget=2.0, verbose=True):
    params, state, cfg = get_trained_policy(*train_scale, batches,
                                            verbose=verbose)
    rows = []
    for en, rn in test_scales:
        instances = eval_instances(en, rn, n_instances)
        methods = standard_method_suite(params, state, cfg.policy,
                                        ref_budget_s=ref_budget,
                                        random_ns=(100,),
                                        sample_ns=(1000,))
        ref = f"ILS({ref_budget}s)"
        results = evaluate_methods(instances, methods, reference=ref)
        for name, r in results.items():
            rows.append(csv_line(
                f"table3/train{train_scale[0]}x{train_scale[1]}"
                f"/test{en}x{rn}/{name}",
                r.mean_time_s * 1e6,
                f"gap={r.mean_gap:.4f};cost={r.mean_cost:.4f}"))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--instances", type=int, default=10)
    ap.add_argument("--batches", type=int, default=800)
    args = ap.parse_args()
    for row in run(n_instances=args.instances, batches=args.batches):
        print(row)


if __name__ == "__main__":
    main()
