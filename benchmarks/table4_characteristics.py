"""Paper Table IV / Figs 8-10 — characteristic validation.

LB (load balancing): homogeneous edges, equal backlogs, all requests at
edge A -> expect near-equal per-edge request counts.
WP (workload perception): homogeneous edges, edge A has the largest
backlog -> expect n_A smallest.
HA (heterogeneity awareness): heterogeneous speeds E>D>C>B>A with equalized
backlog response times -> expect faster edges serve more.

Reports per-edge EReqN (mean executed requests) and LCost (mean response
time of that edge) over many sampled decisions from the trained policy.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, get_trained_policy
from repro.core.decode import sampling_decode
from repro.core.objective import per_edge_times
from repro.core.policy import corais_apply


def _base_instance(q=5, z=50):
    coords = np.stack([np.linspace(0.1, 0.9, q), np.full(q, 0.5)], -1)
    w = np.linalg.norm(coords[:, None] - coords[None], axis=-1)
    return {
        "edge_coords": coords.astype(np.float32),
        "phi": np.tile(np.array([[0.5, 0.05]], np.float32), (q, 1)),
        "replicas": np.full(q, 2.0, np.float32),
        "workload": np.zeros((q, 3), np.float32),
        "w": w.astype(np.float32),
        "ct": np.float32(1.0),
        "req_src": np.zeros(z, np.int32),  # all submitted to edge A
        "req_size": np.full(z, 0.5, np.float32),
        "edge_mask": np.ones(q, bool),
        "req_mask": np.ones(z, bool),
    }


def scenario(kind: str, q=5, z=50):
    inst = _base_instance(q, z)
    if kind == "LB":
        inst["workload"][:, 0] = 2.0  # same backlogs everywhere
    elif kind == "WP":
        # same hardware, edge A much more loaded
        inst["workload"][:, 0] = np.linspace(4.0, 1.0, q)
    elif kind == "HA":
        # speeds E > D > C > B > A; backlog response times equalized
        speeds = np.linspace(1.0, 0.2, q)  # phi slope: smaller = faster
        inst["phi"] = np.stack([speeds, np.full(q, 0.02)], -1).astype(np.float32)
        inst["workload"][:, 0] = 2.0
    return inst


def run(kind: str, params, state, pcfg, trials=200, sample_n=128, z=50):
    inst = scenario(kind, z=z)
    jinst = jax.tree.map(jnp.asarray, inst)
    lp, _ = corais_apply(params, state, jinst, pcfg, training=False)
    counts = np.zeros(5)
    costs = np.zeros(5)
    key = jax.random.PRNGKey(0)

    @jax.jit
    def one(key):
        assign, _ = sampling_decode(key, jinst, lp, sample_n)
        t = per_edge_times(jinst, assign)["T"]
        cnt = jnp.sum(jax.nn.one_hot(assign, 5), axis=0)
        return cnt, t

    for _ in range(trials):
        key, sub = jax.random.split(key)
        cnt, t = one(sub)
        counts += np.asarray(cnt)
        costs += np.asarray(t)
    return counts / trials, costs / trials


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=100)
    ap.add_argument("--batches", type=int, default=800)
    args = ap.parse_args()
    params, state, cfg = get_trained_policy(5, 50, args.batches)
    for kind in ("LB", "WP", "HA"):
        ereqn, lcost = run(kind, params, state, cfg.policy, trials=args.trials)
        for i, label in enumerate("ABCDE"):
            print(csv_line(f"table4/{kind}/edge_{label}", 0.0,
                           f"EReqN={ereqn[i]:.2f};LCost={lcost[i]:.3f}"))


if __name__ == "__main__":
    main()
