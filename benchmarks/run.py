"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Default budget suits one CPU core
(~10-15 min incl. one cached policy training); ``--full`` expands to all
paper scales + ablations.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batches", type=int, default=800)
    ap.add_argument("--skip-tables", action="store_true",
                    help="only roofline + latency (no policy training)")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    t0 = time.time()

    if not args.skip_tables:
        from benchmarks import (fig7_sampling, latency_scheduler,
                                table2_conventional, table3_generalization,
                                table4_characteristics)
        scales = ([(5, 50), (10, 50), (5, 100), (10, 100)]
                  if args.full else [(5, 50)])
        for en, rn in scales:
            for row in table2_conventional.run(
                    en, rn, n_instances=20 if not args.full else 50,
                    batches=args.batches, include_ablations=args.full,
                    verbose=False):
                print(row)
        for row in table3_generalization.run(batches=args.batches,
                                             verbose=False):
            print(row)
        sys.argv = ["table4", "--batches", str(args.batches),
                    "--trials", "100"]
        table4_characteristics.main()
        sys.argv = ["fig7", "--batches", str(args.batches),
                    "--instances", "8"]
        fig7_sampling.main()
        sys.argv = ["latency", "--batches", str(args.batches)]
        latency_scheduler.main()

    from benchmarks import roofline_run
    sys.argv = ["roofline", "--csv"]
    roofline_run.main()

    print(f"# benchmarks completed in {time.time()-t0:.0f}s",
          file=sys.stderr)


if __name__ == "__main__":
    main()
