"""Paper Table II — conventional test: methods on the training scale.

Gap is relative to the strongest offline reference available in this
container (ILS with a wall-clock budget; Gurobi replaced — DESIGN.md §3).
Output: one CSV row per method: name,us_per_call,derived(gap etc).
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import csv_line, eval_instances, get_trained_policy
from repro.core.evaluate import evaluate_methods, standard_method_suite
from repro.core.policy import PolicyConfig


def run(en=5, rn=50, n_instances=20, batches=800, ref_budget=1.0,
        sample_ns=(100, 1000), include_ablations=False, verbose=True):
    params, state, cfg = get_trained_policy(en, rn, batches, verbose=verbose)
    instances = eval_instances(en, rn, n_instances)
    methods = standard_method_suite(params, state, cfg.policy,
                                    ref_budget_s=ref_budget,
                                    sample_ns=sample_ns)
    if include_ablations:
        from benchmarks.common import rl_config
        from repro.core.ablations import variant_config
        from repro.core.evaluate import _policy_method
        from repro.core.train import train
        for variant in ("fc1", "fc2", "fc3"):
            vcfg = rl_config(en, rn, batches)
            vcfg = type(vcfg)(**{**vcfg.__dict__,
                                 "policy": variant_config(vcfg.policy, variant)})
            vp, vs, _, _ = train(vcfg)
            methods[f"{variant.upper()}-CoRaiS(greedy)"] = _policy_method(
                vp, vs, vcfg.policy, "greedy", 0, seed=0)
    ref = f"ILS({ref_budget}s)"
    results = evaluate_methods(instances, methods, reference=ref)
    rows = []
    for name, r in results.items():
        rows.append(csv_line(
            f"table2/EN{en}_RN{rn}/{name}", r.mean_time_s * 1e6,
            f"gap={r.mean_gap:.4f};cost={r.mean_cost:.4f}"))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all four paper scales + ablations")
    ap.add_argument("--instances", type=int, default=20)
    ap.add_argument("--batches", type=int, default=800)
    args = ap.parse_args()
    scales = [(5, 50), (10, 50), (5, 100), (10, 100)] if args.full else [(5, 50)]
    for en, rn in scales:
        for row in run(en, rn, args.instances, args.batches,
                       include_ablations=args.full):
            print(row)


if __name__ == "__main__":
    main()
