#!/bin/bash
# Hardware-tuned launcher for the latency/throughput benchmarks.
#
# Reproducible wall-clock numbers need a pinned allocator and XLA host
# configuration, not just a jitted function: glibc malloc fragments under
# jax's large transient buffers (tcmalloc keeps p99 flat), XLA's host
# platform defaults to one device regardless of cores, and TF's C++ logging
# can dominate microsecond-scale timing loops. This wrapper pins all three,
# then dispatches to a benchmark module.
#
# Usage:
#   benchmarks/run_hw.sh policy_latency [args...]
#   benchmarks/run_hw.sh policy_latency --smoke --fastpath
#   benchmarks/run_hw.sh rollout_throughput [args...]
#   HOST_DEVICES=4 benchmarks/run_hw.sh policy_latency ...
set -euo pipefail

HERE="$(cd "$(dirname "$0")" && pwd)"
REPO="$(dirname "$HERE")"

if [ $# -lt 1 ]; then
  echo "usage: $0 <benchmark-module> [args...]" >&2
  echo "  e.g.: $0 policy_latency --smoke --fastpath" >&2
  exit 2
fi
BENCH="$1"
shift
if [ ! -f "$HERE/$BENCH.py" ]; then
  echo "error: unknown benchmark '$BENCH' (no $HERE/$BENCH.py)" >&2
  exit 2
fi

# tcmalloc: flat allocation latency under repeated large activations; the
# report threshold silences its large-alloc warnings inside timing loops.
# Gate on presence — the stock image may not ship it.
for so in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
          /usr/lib/libtcmalloc.so.4 \
          /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4; do
  if [ -f "$so" ]; then
    export LD_PRELOAD="$so${LD_PRELOAD:+:$LD_PRELOAD}"
    export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000
    break
  fi
done
if [ -z "${LD_PRELOAD:-}" ]; then
  echo "note: tcmalloc not found, running with glibc malloc" >&2
fi

# quiet the C++ backend: stray WARNING lines serialize stderr inside the
# timed region on some platforms
export TF_CPP_MIN_LOG_LEVEL=${TF_CPP_MIN_LOG_LEVEL:-4}

# deterministic f32 default sizes for every benchmark artifact
export JAX_DEFAULT_DTYPE_BITS=${JAX_DEFAULT_DTYPE_BITS:-32}

# multi-device host benchmarking (rollout sharding experiments): expose N
# virtual host devices. Must be set before jax initializes — which is why
# this lives in the launcher, not the benchmark.
if [ -n "${HOST_DEVICES:-}" ]; then
  export XLA_FLAGS="--xla_force_host_platform_device_count=${HOST_DEVICES} ${XLA_FLAGS:-}"
fi

export PYTHONPATH="$REPO/src${PYTHONPATH:+:$PYTHONPATH}"
exec python "$HERE/$BENCH.py" "$@"
