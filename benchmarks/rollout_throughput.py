"""Rollout throughput: event-driven oracle vs array-native batched engine.

Measures simulated *request-rounds per second* (requests simulated x
scheduling rounds / wall time) for the same scenario on both engines. The
event-driven ``MultiEdgeSim`` pays Python heap events and per-round numpy
scheduling for one instance at a time; the batched engine jits one
``step_round`` and vmaps it over an instance axis, so throughput scales
with batch. The acceptance bar this reports against: >= 10x at batch >= 64
on the default scenario.

``--fleet 1,2,4,8`` additionally runs the fleet-sharded rollout
(:mod:`repro.serving.fleet`) at each shard count on a ``("fleet",)`` device
mesh and reports the scaling curve (request-rounds/s per shard count,
speedup vs 1 shard, Zipf placement imbalance and cross-shard transfer
accounting). Shard counts beyond 1 need real or forced host devices —
launch through benchmarks/run_hw.sh with HOST_DEVICES set.

Run:  PYTHONPATH=src python benchmarks/rollout_throughput.py
      PYTHONPATH=src python benchmarks/rollout_throughput.py \\
          --rounds 4 --batch 8            # CI smoke
      PYTHONPATH=src python benchmarks/rollout_throughput.py \\
          --batch 1,8,64,256 --backend greedy
      HOST_DEVICES=8 benchmarks/run_hw.sh rollout_throughput \\
          --fleet 1,2,4,8 --fleet-batch 64
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.serving import (ASSIGN_FNS, CentralController, EngineConfig,
                           MultiEdgeSim, SimConfig, init_batch, make_rollout,
                           resolve_assign_fn, summarize)
from repro.workloads import materialize_round_batch, scenario

REPORT_SCHEMA = "corais.rollout_throughput.v1"

#: heuristic backends only: this benchmark pairs each engine backend with
#: the event-driven controller by name, and the policy factory needs
#: trained params (see benchmarks/policy_latency.py for policy timing)
BACKENDS = sorted(k for k, v in ASSIGN_FNS.items()
                  if not getattr(v, "_assign_factory", False))


def bench_event_sim(name: str, backend: str, num_edges: int, rounds: int,
                    interval: float, seed: int, repeat: int) -> dict:
    """One event-driven run per repeat; returns the best wall time."""
    walls, submitted, completed = [], 0, 0
    for r in range(repeat):
        cc = CentralController(scheduler=backend)
        sim = MultiEdgeSim(
            SimConfig(num_edges=num_edges, round_interval=interval,
                      seed=seed, exec_noise=0.0), cc)
        t0 = time.perf_counter()
        m = sim.drive(scenario(name), until=rounds * interval,
                      run_until=1e5, seed=seed)
        walls.append(time.perf_counter() - t0)
        submitted, completed = m["submitted"], m["completed"]
    wall = min(walls)
    request_rounds = submitted * rounds
    return {
        "wall_s": wall,
        "requests": submitted,
        "completed": completed,
        "request_rounds": request_rounds,
        "request_rounds_per_s": request_rounds / max(wall, 1e-12),
    }


def bench_engine(name: str, backend: str, num_edges: int, rounds: int,
                 interval: float, seed: int, batch: int, repeat: int) -> dict:
    arrivals = materialize_round_batch(
        scenario(name), num_edges, rounds, interval, batch, base_seed=seed)
    cfg = EngineConfig(num_edges=num_edges, num_rounds=rounds,
                       round_interval=interval,
                       max_per_round=arrivals["mask"].shape[-1])
    state0 = init_batch(cfg, range(seed, seed + batch))
    keys = jax.random.split(jax.random.PRNGKey(seed), batch)
    run = make_rollout(cfg, resolve_assign_fn(backend), batch=True)

    t0 = time.perf_counter()
    jax.block_until_ready(run(state0, arrivals, keys))
    compile_s = time.perf_counter() - t0
    walls = []
    final = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        final, _infos = run(state0, arrivals, keys)
        jax.block_until_ready(final)
        walls.append(time.perf_counter() - t0)
    wall = min(walls)
    m = summarize(final)
    request_rounds = m["submitted"] * rounds
    return {
        "batch": batch,
        "wall_s": wall,
        "compile_s": compile_s,
        "requests": m["submitted"],
        "completed": m["completed"],
        "request_rounds": request_rounds,
        "request_rounds_per_s": request_rounds / max(wall, 1e-12),
    }


def bench_fleet(name: str, backend: str, num_edges: int, rounds: int,
                interval: float, seed: int, batch: int, shards: int,
                skew: float, repeat: int) -> dict:
    """One fleet-sharded rollout at ``shards`` shards: Zipf-partitioned
    placement, shard_map rollout, psum-reduced summary partials."""
    from repro.launch.mesh import make_fleet_mesh
    from repro.serving import (apply_partition, fleet_summary,
                               make_fleet_rollout, zipf_partition)

    mesh = make_fleet_mesh(shards)
    arrivals = materialize_round_batch(
        scenario(name), num_edges, rounds, interval, batch, base_seed=seed)
    cfg = EngineConfig(num_edges=num_edges, num_rounds=rounds,
                       round_interval=interval,
                       max_per_round=arrivals["mask"].shape[-1])
    part = zipf_partition(batch, shards, skew=skew, seed=seed)
    states = apply_partition(part, init_batch(cfg, range(seed, seed + batch)))
    arrivals = apply_partition(part, arrivals)
    keys = apply_partition(
        part, np.asarray(jax.random.split(jax.random.PRNGKey(seed), batch)))
    displaced = part.placed_displaced
    run = make_fleet_rollout(cfg, resolve_assign_fn(backend), mesh)

    t0 = time.perf_counter()
    jax.block_until_ready(run(states, arrivals, keys, displaced))
    compile_s = time.perf_counter() - t0
    walls, partials = [], None
    for _ in range(repeat):
        t0 = time.perf_counter()
        partials = run(states, arrivals, keys, displaced)
        jax.block_until_ready(partials)
        walls.append(time.perf_counter() - t0)
    wall = min(walls)
    m = fleet_summary(partials)
    request_rounds = m["submitted"] * rounds
    return {
        "shards": shards,
        "batch": batch,
        "wall_s": wall,
        "compile_s": compile_s,
        "requests": m["submitted"],
        "completed": m["completed"],
        "request_rounds": request_rounds,
        "request_rounds_per_s": request_rounds / max(wall, 1e-12),
        "cross_shard_transferred": m["cross_shard_transferred"],
        "intra_fleet_transferred": m["intra_fleet_transferred"],
        "cross_shard_frac": m["cross_shard_frac"],
        "imbalance": part.imbalance_report(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="uniform_iid")
    ap.add_argument("--backend", default="greedy", choices=BACKENDS)
    ap.add_argument("--edges", type=int, default=5)
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--interval", type=float, default=0.25)
    ap.add_argument("--batch", default="1,8,64",
                    help="comma list of engine batch sizes")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeat", type=int, default=3)
    ap.add_argument("--fleet", default=None,
                    help="comma list of fleet shard counts (e.g. 1,2,4,8); "
                         "runs the sharded rollout scaling curve. Counts > 1 "
                         "need host devices: HOST_DEVICES=8 "
                         "benchmarks/run_hw.sh rollout_throughput ...")
    ap.add_argument("--fleet-batch", type=int, default=64,
                    help="instance batch for the fleet scaling curve")
    ap.add_argument("--fleet-skew", type=float, default=0.9,
                    help="Zipf skew of the fleet home-shard draw")
    ap.add_argument("--out", default=None,
                    help="report path (default results/rollout_throughput.json)")
    args = ap.parse_args()
    batches = [int(b) for b in str(args.batch).split(",")]
    fleet_shards = ([int(s) for s in str(args.fleet).split(",")]
                    if args.fleet else [])
    if fleet_shards and max(fleet_shards) > len(jax.devices()):
        raise SystemExit(
            f"--fleet {args.fleet} needs {max(fleet_shards)} device(s) but "
            f"only {len(jax.devices())} visible; launch through "
            f"HOST_DEVICES={max(fleet_shards)} benchmarks/run_hw.sh")

    print(f"== rollout throughput: scenario={args.scenario} "
          f"backend={args.backend} rounds={args.rounds} ==")
    event = bench_event_sim(args.scenario, args.backend, args.edges,
                            args.rounds, args.interval, args.seed, args.repeat)
    print(f"  event-driven       {event['request_rounds_per_s']:12.0f} "
          f"req-rounds/s  ({event['requests']} requests, "
          f"{event['wall_s'] * 1e3:.1f} ms)")

    engine_rows = []
    for batch in batches:
        row = bench_engine(args.scenario, args.backend, args.edges,
                           args.rounds, args.interval, args.seed, batch,
                           args.repeat)
        row["speedup_vs_event"] = (row["request_rounds_per_s"]
                                   / max(event["request_rounds_per_s"], 1e-12))
        engine_rows.append(row)
        print(f"  engine (batch={batch:4d}) {row['request_rounds_per_s']:12.0f} "
              f"req-rounds/s  ({row['requests']} requests, "
              f"{row['wall_s'] * 1e3:.1f} ms, {row['speedup_vs_event']:.1f}x)")

    fleet_rows = []
    for shards in fleet_shards:
        row = bench_fleet(args.scenario, args.backend, args.edges,
                          args.rounds, args.interval, args.seed,
                          args.fleet_batch, shards, args.fleet_skew,
                          args.repeat)
        row["speedup_vs_1shard"] = (
            row["request_rounds_per_s"]
            / max(fleet_rows[0]["request_rounds_per_s"], 1e-12)
            if fleet_rows else 1.0)
        fleet_rows.append(row)
        imb = row["imbalance"]
        print(f"  fleet ({shards:2d} shard{'s' if shards > 1 else ' '}, "
              f"batch={row['batch']}) {row['request_rounds_per_s']:12.0f} "
              f"req-rounds/s  ({row['wall_s'] * 1e3:.1f} ms, "
              f"{row['speedup_vs_1shard']:.2f}x vs 1 shard, "
              f"home imbalance {imb['home_imbalance']:.2f}, "
              f"{imb['displaced_instances']} displaced, "
              f"cross-shard {row['cross_shard_transferred']})")

    report = {
        "schema": REPORT_SCHEMA,
        "config": {
            "scenario": args.scenario, "backend": args.backend,
            "num_edges": args.edges, "rounds": args.rounds,
            "interval": args.interval, "seed": args.seed,
            "repeat": args.repeat, "batches": batches,
            "fleet_shards": fleet_shards, "fleet_batch": args.fleet_batch,
            "fleet_skew": args.fleet_skew,
        },
        "event_sim": event,
        "engine": engine_rows,
        "fleet": fleet_rows,
    }
    out = args.out or os.path.join(os.path.dirname(__file__), "..",
                                   "results", "rollout_throughput.json")
    os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
    with open(out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"== report written to {os.path.abspath(out)} ==")


if __name__ == "__main__":
    main()
