"""Scenario sweep: every registered workload scenario x scheduler backend.

Drives :meth:`MultiEdgeSim.drive` with each named scenario from the
workload registry against each scheduler backend and writes a JSON report
(per-cell completion/latency/decision metrics plus a per-scenario winner).
This is the scenario-diversity counterpart of the paper's Table II, which
only covers the i.i.d. uniform regime.

Run:  PYTHONPATH=src python benchmarks/scenario_sweep.py
      PYTHONPATH=src python benchmarks/scenario_sweep.py \\
          --backends greedy,local,random,corais --batches 800
      PYTHONPATH=src python benchmarks/scenario_sweep.py \\
          --backends greedy,batched-greedy,batched-local
      # policy-vs-baseline rollout comparison on paired engine episodes:
      PYTHONPATH=src python benchmarks/scenario_sweep.py \\
          --backends batched-local,batched-greedy,batched-corais,batched-corais-temporal

``corais`` trains (or loads a cached) policy via benchmarks.common first;
the heuristic backends need no training and finish in seconds. A
``batched-*`` backend runs the same scenario through the array-native
engine (repro.serving.engine, online phi fitting on) instead of the
event-driven simulator — same cluster seed and arrival stream, so its cells
are directly comparable to the event-driven columns.
``batched-corais-temporal`` selects the temporal policy (REINFORCE on
whole engine rollouts) instead of the static-trained one, so its column
against ``batched-corais`` / ``batched-greedy`` / ``batched-local`` is the
ROADMAP's policy-vs-baseline rollout benchmark.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.serving import (ASSIGN_FNS, CentralController, EngineConfig,
                           MultiEdgeSim, SimConfig, init_batch,
                           make_rollout, resolve_assign_fn, summarize)
from repro.workloads import list_scenarios, materialize_round_batch, scenario

REPORT_SCHEMA = "corais.scenario_sweep.v1"


def _make_controller(backend: str, num_edges: int, batches: int,
                     z_pad: int) -> CentralController:
    if backend in ("corais", "corais-sample"):
        from benchmarks.common import get_trained_policy
        params, state, cfg = get_trained_policy(num_edges, 50, batches,
                                                verbose=False)
        return CentralController(scheduler=backend, policy_params=params,
                                 policy_state=state, policy_cfg=cfg.policy,
                                 z_pad=z_pad)
    return CentralController(scheduler=backend)


#: batched-* inner names that resolve to a trained policy AssignFn:
#: static-trained (paper §IV-B i.i.d. snapshots) greedy/sampling decode,
#: and the temporal policy trained on whole engine rollouts — the
#: policy-vs-baseline rollout comparison runs these against batched-greedy
#: / batched-local on paired episodes.
POLICY_BACKENDS = ("corais", "corais-sample", "corais-temporal", "policy")


def _engine_assign_fn(inner: str, num_edges: int, batches: int):
    if inner in POLICY_BACKENDS:
        if inner == "corais-temporal":
            from benchmarks.common import get_temporal_policy
            params, state, cfg = get_temporal_policy(num_edges, batches,
                                                     verbose=False)
            mode = "greedy"
        else:
            from benchmarks.common import get_trained_policy
            params, state, cfg = get_trained_policy(num_edges, 50, batches,
                                                    verbose=False)
            mode = "sample" if inner == "corais-sample" else "greedy"
        return resolve_assign_fn("policy", params=params, policy_state=state,
                                 policy_cfg=cfg.policy, mode=mode)
    try:
        return resolve_assign_fn(inner)
    except ValueError:
        known = sorted(set(ASSIGN_FNS) - {"policy"}) + list(POLICY_BACKENDS)
        raise ValueError(
            f"no batched-engine backend {inner!r}; supported: "
            f"{', '.join('batched-' + k for k in known)}") from None


def _run_batched(backend: str, name: str, *, num_edges: int, until: float,
                 seed: int, batches: int) -> dict:
    """One batched-engine cell (batch of 1 rollout, paired with the
    event-driven cells by seed and arrival stream)."""
    inner = backend.split("-", 1)[1]
    interval = SimConfig().round_interval
    rounds = max(1, int(round(until / interval)))
    arrivals = materialize_round_batch(scenario(name), num_edges, rounds,
                                       interval, 1, base_seed=seed)
    cfg = EngineConfig(num_edges=num_edges, num_rounds=rounds,
                       round_interval=interval, learn_phi=True,
                       max_per_round=arrivals["mask"].shape[-1])
    state0 = init_batch(cfg, [seed])
    run = make_rollout(cfg, _engine_assign_fn(inner, num_edges, batches),
                       batch=True)
    keys = jax.random.split(jax.random.PRNGKey(seed), 1)
    jax.block_until_ready(run(state0, arrivals, keys))  # compile
    t0 = time.time()
    final, _ = run(state0, arrivals, keys)
    jax.block_until_ready(final)
    m = summarize(final)
    m["wall_s"] = time.time() - t0
    m["decision_rounds"] = rounds
    m["decision_mean_s"] = m["wall_s"] / rounds   # whole-round proxy: the
    m["decision_p95_s"] = m["decision_mean_s"]    # jitted rollout does not
    m["decision_max_s"] = m["decision_mean_s"]    # isolate decode time
    m["scheduler_decision_s"] = m["decision_mean_s"]
    m["engine"] = "batched"
    return m


def run_sweep(scenarios: list[str], backends: list[str], *, num_edges: int = 5,
              until: float = 3.0, horizon: float = 400.0, seed: int = 0,
              batches: int = 800, verbose: bool = True) -> dict:
    for backend in backends:  # fail fast, before any cell is computed
        if backend.startswith("batched-"):
            inner = backend.split("-", 1)[1]
            if inner not in ASSIGN_FNS and inner not in POLICY_BACKENDS:
                _engine_assign_fn(inner, num_edges, batches)  # raises
    cells = {}
    winners = {}
    for name in scenarios:
        cells[name] = {}
        for backend in backends:
            if backend.startswith("batched-"):
                m = _run_batched(backend, name, num_edges=num_edges,
                                 until=until, seed=seed, batches=batches)
            else:
                cc = _make_controller(backend, num_edges, batches, z_pad=256)
                sim = MultiEdgeSim(SimConfig(num_edges=num_edges, seed=seed),
                                   cc)
                t0 = time.time()
                m = sim.drive(scenario(name), until=until, run_until=horizon)
                m["wall_s"] = time.time() - t0
            m["per_edge_completed"] = {str(k): v for k, v
                                       in m.get("per_edge_completed",
                                                {}).items()}
            cells[name][backend] = m
            if verbose:
                print(f"  {name:20s} {backend:12s} completed="
                      f"{m['completed']:4d}/{m['submitted']:<4d} "
                      f"mean={m.get('mean_response', 0):7.3f} "
                      f"p95={m.get('p95_response', 0):7.3f} "
                      f"dec_mean={m['decision_mean_s'] * 1e3:6.2f}ms")
        ok = {b: r for b, r in cells[name].items()
              if r["completed"] == r["submitted"] and r["completed"] > 0}
        if ok:
            winners[name] = min(ok, key=lambda b: ok[b]["mean_response"])
            if verbose:
                print(f"  {name:20s} -> best mean response: {winners[name]}")
    return {
        "schema": REPORT_SCHEMA,
        "config": {"num_edges": num_edges, "until": until,
                   "horizon": horizon, "seed": seed,
                   "scenarios": scenarios, "backends": backends},
        "results": cells,
        "winners": winners,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", default="all",
                    help="comma list, or 'all' for the full registry")
    ap.add_argument("--backends", default="greedy,local,random")
    ap.add_argument("--edges", type=int, default=5)
    ap.add_argument("--until", type=float, default=3.0,
                    help="arrival window (workload horizon)")
    ap.add_argument("--horizon", type=float, default=400.0,
                    help="simulation end time (lets late arrivals drain)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batches", type=int, default=800,
                    help="training budget when a corais backend is requested")
    ap.add_argument("--out", default=None,
                    help="report path (default results/scenario_sweep.json)")
    args = ap.parse_args()

    names = (list(list_scenarios()) if args.scenarios == "all"
             else args.scenarios.split(","))
    backends = args.backends.split(",")
    print(f"== scenario sweep: {len(names)} scenarios x "
          f"{len(backends)} backends ==")
    report = run_sweep(names, backends, num_edges=args.edges,
                       until=args.until, horizon=args.horizon,
                       seed=args.seed, batches=args.batches)

    out = args.out or os.path.join(os.path.dirname(__file__), "..",
                                   "results", "scenario_sweep.json")
    os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
    with open(out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"== report written to {os.path.abspath(out)} ==")


if __name__ == "__main__":
    main()
