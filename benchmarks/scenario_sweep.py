"""Scenario sweep: every registered workload scenario x scheduler backend.

Drives :meth:`MultiEdgeSim.drive` with each named scenario from the
workload registry against each scheduler backend and writes a JSON report
(per-cell completion/latency/decision metrics plus a per-scenario winner).
This is the scenario-diversity counterpart of the paper's Table II, which
only covers the i.i.d. uniform regime.

Run:  PYTHONPATH=src python benchmarks/scenario_sweep.py
      PYTHONPATH=src python benchmarks/scenario_sweep.py \\
          --backends greedy,local,random,corais --batches 800
      PYTHONPATH=src python benchmarks/scenario_sweep.py \\
          --backends greedy,batched-greedy,batched-local
      # policy-vs-baseline rollout comparison on paired engine episodes:
      PYTHONPATH=src python benchmarks/scenario_sweep.py \\
          --backends batched-local,batched-greedy,batched-corais,batched-corais-temporal

``corais`` trains (or loads a cached) policy via benchmarks.common first;
the heuristic backends need no training and finish in seconds. A
``batched-*`` backend runs the same scenario through the array-native
engine (repro.serving.engine, online phi fitting on) instead of the
event-driven simulator — same cluster seed and arrival stream, so its cells
are directly comparable to the event-driven columns.
``batched-corais-temporal`` selects the temporal policy (REINFORCE on
whole engine rollouts) instead of the static-trained one, so its column
against ``batched-corais`` / ``batched-greedy`` / ``batched-local`` is the
ROADMAP's policy-vs-baseline rollout benchmark.

Chaos scenarios (``chaos-*``, any scenario registered with a FaultSpec)
run fault-injected: batched cells fold the materialized fault trajectory
into the arrival batch (``resilience.faults.attach_fault_batch``),
event-driven cells schedule the identical fail/recover/straggle timeline
into the heap (``schedule_into_sim``), and every cell reports shed rate
and SLO-violation fraction next to the response percentiles. The extra
fault-matrix column is ``batched-corais-admit``: the static-trained
CoRaiS dispatch plus an admission head trained per scenario on
fault-injected episodes (dispatch frozen during that training, so
against ``batched-corais`` the column isolates what learned admission
adds under overload and failures).

  # resilience fault matrix (writes results/chaos_sweep.json):
  PYTHONPATH=src python benchmarks/scenario_sweep.py --chaos

Edge-cloud scenarios (``cloud-*``, any scenario registered with a
CloudSpec) run with the elastic cloud tier and per-edge service caches
threaded into both engines, and their cells carry deadline-miss /
cache-hit / cloud-offload columns plus a per-scenario deadline winner.
The extra column is ``batched-corais-cloud``: the tier-feature policy
temporal-trained against deadline misses on the miss-heavy
cloud-cache-churn scenario (benchmarks.common.get_cloud_policy) and
reused unchanged on every other scenario, so against ``batched-corais``
(cache-oblivious dispatch) and ``batched-greedy`` it isolates what the
deadline/cache/tier features buy:

  PYTHONPATH=src python benchmarks/scenario_sweep.py \\
      --scenarios cloud-cache-churn,cloud-burst-offload \\
      --backends batched-greedy,batched-corais,batched-corais-cloud
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# `python benchmarks/scenario_sweep.py` puts benchmarks/ (not the repo
# root) on sys.path; the lazy `benchmarks.common` imports below need the
# root on it to resolve the package.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

import jax

from repro.resilience import faults as faults_lib
from repro.serving import (ASSIGN_FNS, CentralController, EngineConfig,
                           MultiEdgeSim, SimConfig, init_batch,
                           make_rollout, resolve_assign_fn, summarize)
from repro.workloads import (list_scenarios, materialize_round_batch,
                             materialize_rounds, scenario,
                             scenario_cloud_spec, scenario_fault_spec)

REPORT_SCHEMA = "corais.scenario_sweep.v3"
DEFAULT_SLO = 3.0  # response-time SLO for the fault-matrix columns


def _make_controller(backend: str, num_edges: int, batches: int,
                     z_pad: int) -> CentralController:
    if backend in ("corais", "corais-sample"):
        from benchmarks.common import get_trained_policy
        params, state, cfg = get_trained_policy(num_edges, 50, batches,
                                                verbose=False)
        return CentralController(scheduler=backend, policy_params=params,
                                 policy_state=state, policy_cfg=cfg.policy,
                                 z_pad=z_pad)
    return CentralController(scheduler=backend)


#: batched-* inner names that resolve to a trained policy AssignFn:
#: static-trained (paper §IV-B i.i.d. snapshots) greedy/sampling decode,
#: the temporal policy trained on whole engine rollouts (the
#: policy-vs-baseline rollout comparison against batched-greedy /
#: batched-local on paired episodes), and corais-admit — the same
#: static-trained dispatch plus an admission head trained per scenario on
#: fault-injected episodes (frozen dispatch, so the column isolates what
#: admission adds). corais-cloud is the deadline/cache-aware variant:
#: tier features on, temporal-trained against deadline misses on
#: cloud-cache-churn (benchmarks.common.get_cloud_policy, one shared
#: column), so on cloud-* scenarios its cell against batched-corais
#: isolates what the tier/cache/deadline features buy over the
#: cache-oblivious dispatch.
POLICY_BACKENDS = ("corais", "corais-sample", "corais-temporal", "policy",
                   "corais-admit", "corais-cloud")


def _engine_assign_fn(inner: str, num_edges: int, batches: int,
                      scenario_name: str = "uniform_iid"):
    if inner in POLICY_BACKENDS:
        admission = False
        if inner == "corais-admit":
            from benchmarks.common import get_resilient_policy
            admission = True
            params, state, cfg = get_resilient_policy(
                num_edges, scenario_name=scenario_name,
                slo=DEFAULT_SLO, verbose=False)
            mode = "greedy"
        elif inner == "corais-cloud":
            # one shared column: the policy temporal-trained on
            # cloud-cache-churn (the miss-heavy scenario), reused on the
            # other scenarios so its cloud-burst-offload cell doubles as
            # a generalization check rather than retraining per scenario.
            # Sampled decode: episode REINFORCE trains the stochastic
            # policy, and per-round queue depth is not a request feature,
            # so argmax herds a round's identical-looking requests onto
            # one node — sampling realizes the load-spreading mixture the
            # training signal actually scored.
            from benchmarks.common import get_cloud_policy
            params, state, cfg = get_cloud_policy(num_edges, verbose=False)
            mode = "sample"
        elif inner == "corais-temporal":
            from benchmarks.common import get_temporal_policy
            params, state, cfg = get_temporal_policy(num_edges, batches,
                                                     verbose=False)
            mode = "greedy"
        else:
            from benchmarks.common import get_trained_policy
            params, state, cfg = get_trained_policy(num_edges, 50, batches,
                                                    verbose=False)
            mode = "sample" if inner == "corais-sample" else "greedy"
        return resolve_assign_fn("policy", params=params, policy_state=state,
                                 policy_cfg=cfg.policy, mode=mode,
                                 admission=admission)
    try:
        return resolve_assign_fn(inner)
    except ValueError:
        known = sorted(set(ASSIGN_FNS) - {"policy"}) + list(POLICY_BACKENDS)
        raise ValueError(
            f"no batched-engine backend {inner!r}; supported: "
            f"{', '.join('batched-' + k for k in known)}") from None


def _run_batched(backend: str, name: str, *, num_edges: int, until: float,
                 seed: int, batches: int, slo: float = DEFAULT_SLO) -> dict:
    """One batched-engine cell (batch of 1 rollout, paired with the
    event-driven cells by seed and arrival stream). Scenarios registered
    with a FaultSpec run fault-injected, and their cells carry the shed /
    SLO columns of the fault matrix."""
    inner = backend.split("-", 1)[1]
    interval = SimConfig().round_interval
    rounds = max(1, int(round(until / interval)))
    arrivals = materialize_round_batch(scenario(name), num_edges, rounds,
                                       interval, 1, base_seed=seed)
    fspec = scenario_fault_spec(name)
    if fspec is not None:
        arrivals = faults_lib.attach_fault_batch(arrivals, fspec, num_edges,
                                                 seeds=[seed])
    cloud, cache = scenario_cloud_spec(name)
    cfg = EngineConfig(num_edges=num_edges, num_rounds=rounds,
                       round_interval=interval, learn_phi=True,
                       max_per_round=arrivals["mask"].shape[-1],
                       cloud=cloud, cache=cache)
    state0 = init_batch(cfg, [seed])
    run = make_rollout(cfg, _engine_assign_fn(inner, num_edges, batches, name),
                       batch=True)
    keys = jax.random.split(jax.random.PRNGKey(seed), 1)
    jax.block_until_ready(run(state0, arrivals, keys))  # compile
    t0 = time.time()
    final, _ = run(state0, arrivals, keys)
    jax.block_until_ready(final)
    m = summarize(final, slo=slo if fspec is not None else None)
    m["wall_s"] = time.time() - t0
    m["decision_rounds"] = rounds
    m["decision_mean_s"] = m["wall_s"] / rounds   # whole-round proxy: the
    m["decision_p95_s"] = m["decision_mean_s"]    # jitted rollout does not
    m["decision_max_s"] = m["decision_mean_s"]    # isolate decode time
    m["scheduler_decision_s"] = m["decision_mean_s"]
    m["engine"] = "batched"
    return m


def _run_event_driven(backend: str, name: str, *, num_edges: int,
                      until: float, horizon: float, seed: int, batches: int,
                      slo: float = DEFAULT_SLO) -> dict:
    """One event-driven cell. On a fault scenario, the same materialized
    fail/recover/straggle timeline the batched cells fold into their
    arrival batch is scheduled into the heap, so the columns stay paired."""
    cc = _make_controller(backend, num_edges, batches, z_pad=256)
    cloud, cache = scenario_cloud_spec(name)
    sim = MultiEdgeSim(SimConfig(num_edges=num_edges, seed=seed,
                                 cloud=cloud, cache=cache), cc)
    interval = sim.cfg.round_interval
    fspec = scenario_fault_spec(name)
    if fspec is not None:
        rounds = max(1, int(round(until / interval)))
        ev = faults_lib.materialize_faults(fspec, num_edges, rounds,
                                          seed=seed)
        jit = None
        if fspec.jitter_sigma:
            # size the shared per-rid jitter table off the identical
            # arrival stream the batched cells materialize
            probe = materialize_rounds(scenario(name), num_edges, rounds,
                                       interval, seed=seed,
                                       max_per_round=256)
            n_rid = (int(probe["rid"].max()) + 1 if probe["mask"].any()
                     else 1)
            jit = faults_lib.jitter_table(fspec, n_rid, seed=seed)
        faults_lib.schedule_into_sim(sim, ev, interval, jit)
    t0 = time.time()
    m = sim.drive(scenario(name), until=until, run_until=horizon)
    m["wall_s"] = time.time() - t0
    if fspec is not None:
        resp = [r.finish_time - r.submit_time
                for e in sim.edges for r in e.completed]
        viol = sum(1 for r in resp if r > slo) \
            + (m["submitted"] - m["completed"])
        m["shed_requests"] = 0  # the event sim has no admission control
        m["shed_rate"] = 0.0
        m["slo"] = float(slo)
        m["slo_violation_frac"] = viol / max(m["submitted"], 1)
    return m


def run_sweep(scenarios: list[str], backends: list[str], *, num_edges: int = 5,
              until: float = 3.0, horizon: float = 400.0, seed: int = 0,
              batches: int = 800, slo: float = DEFAULT_SLO,
              verbose: bool = True) -> dict:
    for backend in backends:  # fail fast, before any cell is computed
        if backend.startswith("batched-"):
            inner = backend.split("-", 1)[1]
            if inner not in ASSIGN_FNS and inner not in POLICY_BACKENDS:
                _engine_assign_fn(inner, num_edges, batches)  # raises
    cells = {}
    winners = {}
    slo_winners = {}
    deadline_winners = {}
    for name in scenarios:
        cells[name] = {}
        fspec = scenario_fault_spec(name)
        for backend in backends:
            if backend.startswith("batched-"):
                m = _run_batched(backend, name, num_edges=num_edges,
                                 until=until, seed=seed, batches=batches,
                                 slo=slo)
            else:
                m = _run_event_driven(backend, name, num_edges=num_edges,
                                      until=until, horizon=horizon,
                                      seed=seed, batches=batches, slo=slo)
            # every cell — batched summarize/partials_to_summary and the
            # event sim's metrics() — now returns the full canonical
            # SUMMARY_KEYS schema, so the report indexes keys directly
            # instead of defaulting the ones an engine used to omit
            m["per_edge_completed"] = {str(k): v for k, v
                                       in m["per_edge_completed"].items()}
            cells[name][backend] = m
            if verbose:
                line = (f"  {name:20s} {backend:12s} completed="
                        f"{m['completed']:4d}/{m['submitted']:<4d} "
                        f"mean={m['mean_response']:7.3f} "
                        f"p95={m['p95_response']:7.3f} "
                        f"dec_mean={m['decision_mean_s'] * 1e3:6.2f}ms")
                if "slo_violation_frac" in m:
                    line += (f" shed={m['shed_rate']:5.3f} "
                             f"slo_viol={m['slo_violation_frac']:5.3f}")
                if m["deadline_total"]:
                    line += (f" dl_miss={m['deadline_miss_frac']:5.3f} "
                             f"cache_hit={m['cache_hit_rate']:5.3f} "
                             f"cloud={m['cloud_offload_frac']:5.3f}")
                print(line)
        # fault-free scenarios rank complete runs by mean response; fault
        # scenarios admit shed/dropped load, so rank everything that
        # completed work (and additionally by SLO-violation fraction)
        ok = {b: r for b, r in cells[name].items()
              if r["completed"] > 0
              and (fspec is not None or r["completed"] == r["submitted"])}
        if ok:
            winners[name] = min(ok, key=lambda b: ok[b]["mean_response"])
            if verbose:
                print(f"  {name:20s} -> best mean response: {winners[name]}")
        slo_ok = {b: r for b, r in ok.items() if "slo_violation_frac" in r}
        if slo_ok:
            slo_winners[name] = min(
                slo_ok, key=lambda b: (slo_ok[b]["slo_violation_frac"],
                                       slo_ok[b]["mean_response"]))
            if verbose:
                print(f"  {name:20s} -> best SLO violation:  "
                      f"{slo_winners[name]}")
        # deadline-carrying scenarios (cloud-*) additionally rank by
        # deadline-miss fraction — the edge-cloud counterpart of the SLO
        # column, ties broken by mean response
        dl_ok = {b: r for b, r in cells[name].items()
                 if r["completed"] > 0 and r["deadline_total"] > 0}
        if dl_ok:
            deadline_winners[name] = min(
                dl_ok, key=lambda b: (dl_ok[b]["deadline_miss_frac"],
                                      dl_ok[b]["mean_response"]))
            if verbose:
                print(f"  {name:20s} -> best deadline miss:  "
                      f"{deadline_winners[name]}")
    return {
        "schema": REPORT_SCHEMA,
        "config": {"num_edges": num_edges, "until": until,
                   "horizon": horizon, "seed": seed, "slo": slo,
                   "scenarios": scenarios, "backends": backends},
        "results": cells,
        "winners": winners,
        "slo_winners": slo_winners,
        "deadline_winners": deadline_winners,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", default="all",
                    help="comma list, or 'all' for the full registry")
    ap.add_argument("--backends", default="greedy,local,random")
    ap.add_argument("--edges", type=int, default=5)
    ap.add_argument("--until", type=float, default=3.0,
                    help="arrival window (workload horizon)")
    ap.add_argument("--horizon", type=float, default=400.0,
                    help="simulation end time (lets late arrivals drain)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batches", type=int, default=None,
                    help="training budget when a corais backend is requested "
                         "(default 800; the corais-admit head has its own "
                         "fixed budget, see benchmarks.common."
                         "get_resilient_policy)")
    ap.add_argument("--slo", type=float, default=DEFAULT_SLO,
                    help="response-time SLO for the fault-matrix columns")
    ap.add_argument("--chaos", action="store_true",
                    help="resilience fault matrix: default to the fault-"
                         "injected scenarios and the admission-policy / "
                         "dispatch-policy / greedy / local columns, writing "
                         "results/chaos_sweep.json")
    ap.add_argument("--out", default=None,
                    help="report path (default results/scenario_sweep.json; "
                         "results/chaos_sweep.json under --chaos)")
    args = ap.parse_args()

    if args.chaos:
        default_scenarios = [n for n in list_scenarios()
                             if scenario_fault_spec(n) is not None]
        default_backends = ("batched-corais-admit,batched-corais,"
                            "batched-greedy,batched-local")
        default_out, default_batches = "chaos_sweep.json", 800
    else:
        default_scenarios = list(list_scenarios())
        default_backends = None
        default_out, default_batches = "scenario_sweep.json", 800

    names = (default_scenarios if args.scenarios == "all"
             else args.scenarios.split(","))
    backends_arg = args.backends
    if args.chaos and backends_arg == ap.get_default("backends"):
        backends_arg = default_backends
    backends = backends_arg.split(",")
    batches = args.batches if args.batches is not None else default_batches
    print(f"== scenario sweep: {len(names)} scenarios x "
          f"{len(backends)} backends ==")
    report = run_sweep(names, backends, num_edges=args.edges,
                       until=args.until, horizon=args.horizon,
                       seed=args.seed, batches=batches, slo=args.slo)

    out = args.out or os.path.join(os.path.dirname(__file__), "..",
                                   "results", default_out)
    os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
    with open(out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"== report written to {os.path.abspath(out)} ==")


if __name__ == "__main__":
    main()
