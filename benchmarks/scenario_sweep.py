"""Scenario sweep: every registered workload scenario x scheduler backend.

Drives :meth:`MultiEdgeSim.drive` with each named scenario from the
workload registry against each scheduler backend and writes a JSON report
(per-cell completion/latency/decision metrics plus a per-scenario winner).
This is the scenario-diversity counterpart of the paper's Table II, which
only covers the i.i.d. uniform regime.

Run:  PYTHONPATH=src python benchmarks/scenario_sweep.py
      PYTHONPATH=src python benchmarks/scenario_sweep.py \\
          --backends greedy,local,random,corais --batches 800

``corais`` trains (or loads a cached) policy via benchmarks.common first;
the heuristic backends need no training and finish in seconds.
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.serving import CentralController, MultiEdgeSim, SimConfig
from repro.workloads import list_scenarios, scenario

REPORT_SCHEMA = "corais.scenario_sweep.v1"


def _make_controller(backend: str, num_edges: int, batches: int,
                     z_pad: int) -> CentralController:
    if backend in ("corais", "corais-sample"):
        from benchmarks.common import get_trained_policy
        params, state, cfg = get_trained_policy(num_edges, 50, batches,
                                                verbose=False)
        return CentralController(scheduler=backend, policy_params=params,
                                 policy_state=state, policy_cfg=cfg.policy,
                                 z_pad=z_pad)
    return CentralController(scheduler=backend)


def run_sweep(scenarios: list[str], backends: list[str], *, num_edges: int = 5,
              until: float = 3.0, horizon: float = 400.0, seed: int = 0,
              batches: int = 800, verbose: bool = True) -> dict:
    cells = {}
    winners = {}
    for name in scenarios:
        cells[name] = {}
        for backend in backends:
            cc = _make_controller(backend, num_edges, batches, z_pad=256)
            sim = MultiEdgeSim(SimConfig(num_edges=num_edges, seed=seed), cc)
            t0 = time.time()
            m = sim.drive(scenario(name), until=until, run_until=horizon)
            m["wall_s"] = time.time() - t0
            m["per_edge_completed"] = {str(k): v for k, v
                                       in m.get("per_edge_completed",
                                                {}).items()}
            cells[name][backend] = m
            if verbose:
                print(f"  {name:20s} {backend:12s} completed="
                      f"{m['completed']:4d}/{m['submitted']:<4d} "
                      f"mean={m.get('mean_response', 0):7.3f} "
                      f"p95={m.get('p95_response', 0):7.3f} "
                      f"dec_mean={m['decision_mean_s'] * 1e3:6.2f}ms")
        ok = {b: r for b, r in cells[name].items()
              if r["completed"] == r["submitted"] and r["completed"] > 0}
        if ok:
            winners[name] = min(ok, key=lambda b: ok[b]["mean_response"])
            if verbose:
                print(f"  {name:20s} -> best mean response: {winners[name]}")
    return {
        "schema": REPORT_SCHEMA,
        "config": {"num_edges": num_edges, "until": until,
                   "horizon": horizon, "seed": seed,
                   "scenarios": scenarios, "backends": backends},
        "results": cells,
        "winners": winners,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", default="all",
                    help="comma list, or 'all' for the full registry")
    ap.add_argument("--backends", default="greedy,local,random")
    ap.add_argument("--edges", type=int, default=5)
    ap.add_argument("--until", type=float, default=3.0,
                    help="arrival window (workload horizon)")
    ap.add_argument("--horizon", type=float, default=400.0,
                    help="simulation end time (lets late arrivals drain)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batches", type=int, default=800,
                    help="training budget when a corais backend is requested")
    ap.add_argument("--out", default=None,
                    help="report path (default results/scenario_sweep.json)")
    args = ap.parse_args()

    names = (list(list_scenarios()) if args.scenarios == "all"
             else args.scenarios.split(","))
    backends = args.backends.split(",")
    print(f"== scenario sweep: {len(names)} scenarios x "
          f"{len(backends)} backends ==")
    report = run_sweep(names, backends, num_edges=args.edges,
                       until=args.until, horizon=args.horizon,
                       seed=args.seed, batches=args.batches)

    out = args.out or os.path.join(os.path.dirname(__file__), "..",
                                   "results", "scenario_sweep.json")
    os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
    with open(out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"== report written to {os.path.abspath(out)} ==")


if __name__ == "__main__":
    main()
