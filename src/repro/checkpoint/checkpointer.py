"""Fault-tolerant checkpointing for arbitrary pytrees.

Properties needed at cluster scale, all implemented here:

* **Atomicity** — write to ``<dir>.tmp`` then ``os.replace``; a preempted
  writer never corrupts the latest checkpoint.
* **Async** — ``save`` returns immediately; serialization runs on a
  background thread (device->host copy happens synchronously, cheap next to
  serialization+IO). ``wait()`` joins before exit.
* **Keep-K retention** + a ``LATEST`` pointer file for O(1) discovery.
* **Elastic restore** — arrays are stored unsharded (host-gathered) with a
  manifest of logical paths; ``restore`` accepts a ``shardings`` pytree and
  lays the values out on ANY mesh, so a job can resume on a different pod
  count after a failure (DESIGN.md §2 fault tolerance).
* **Data-pipeline state** — any JSON-serializable ``extras`` (e.g.
  SyntheticTokens.state_dict) ride along, making resume exactly-once.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out, treedef


def save_pytree(tree, directory: str, extras: Optional[dict] = None) -> None:
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat, _ = _flatten_with_paths(tree)
    manifest = []
    arrays = {}
    for i, (key, leaf) in enumerate(flat):
        name = f"arr_{i}"
        arrays[name] = np.asarray(jax.device_get(leaf))
        manifest.append({"key": key, "name": name,
                         "dtype": str(arrays[name].dtype),
                         "shape": list(arrays[name].shape)})
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"leaves": manifest, "extras": extras or {}}, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.replace(tmp, directory)


def restore_pytree(template, directory: str, shardings=None):
    """Restore into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching pytree of
    NamedSharding for elastic placement on the current mesh."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(directory, "arrays.npz"))
    by_key = {e["key"]: data[e["name"]] for e in manifest["leaves"]}
    flat, treedef = _flatten_with_paths(template)
    leaves = []
    for key, leaf in flat:
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = by_key[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    else:
        tree = jax.tree.map(jax.device_put, tree)
    return tree, manifest.get("extras", {})


class Checkpointer:
    """Async keep-K checkpoint manager with preemption-safe resume."""

    def __init__(self, root: str, every: int = 100, keep: int = 3,
                 async_save: bool = True):
        self.root = root
        self.every = max(every, 1)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.every == 0

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:010d}")

    def save(self, step: int, tree, extras: Optional[dict] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save_pytree(host_tree, self._dir(step), extras)
            with open(os.path.join(self.root, "LATEST.tmp"), "w") as f:
                f.write(str(step))
            os.replace(os.path.join(self.root, "LATEST.tmp"),
                       os.path.join(self.root, "LATEST"))
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.root, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return int(f.read().strip())

    def restore_latest(self, template, shardings=None):
        step = self.latest_step()
        if step is None:
            return None
        tree, extras = restore_pytree(template, self._dir(step), shardings)
        return {"step": step, "tree": tree, "extras": extras}

    def _gc(self) -> None:
        dirs = sorted(d for d in os.listdir(self.root) if d.startswith("step_"))
        for d in dirs[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.root, d), ignore_errors=True)
