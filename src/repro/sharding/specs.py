"""PartitionSpec rules for every parameter / optimizer / input / cache leaf.

Strategy (DESIGN.md §5): FSDP over the ``data`` axis + tensor parallelism
over ``model``; batch over ("pod", "data"); KV caches shard sequence over
``model`` (flash-decode style — works for any kv_head count); MoE experts
replicated on the expert dim, TP on d_ff, FSDP on d_model.

Every rule checks divisibility against the actual mesh and falls back to
replication for a non-dividing dim, so a single rule set serves all 10
architectures (e.g. hymba's 25 heads shard via the flattened H*hd = 1600
projection dim, which *is* divisible).
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


def mesh_axes(mesh: Mesh, layout: str = "tp") -> dict:
    """layout="tp" (default): batch over data(+pod), TP over model.
    layout="dp": every axis is data parallelism — weights FSDP-sharded over
    all axes and batch over all axes; zero per-layer TP collectives. The
    right choice for small models where TP=16 is all overhead (§Perf)."""
    names = mesh.axis_names
    if layout == "dp":
        allax = tuple(names)
        return {"dp": allax, "fsdp": allax, "tp": None}
    pod_dp = ("pod", "data") if "pod" in names else ("data",)
    if layout == "tp-serve":
        # Serving layout: weights TP-sharded only, REPLICATED over data —
        # no per-step FSDP all-gathers (the dominant decode collective;
        # EXPERIMENTS.md §Perf). Requires params/tp_size to fit HBM.
        return {"dp": pod_dp, "fsdp": None, "tp": "model"}
    return {"dp": pod_dp, "fsdp": "data", "tp": "model"}


def _axsize(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _fit(mesh: Mesh, dim: int, axis):
    """axis if it divides dim else None (replicate)."""
    return axis if axis is not None and dim % _axsize(mesh, axis) == 0 else None


def _param_rule(path: str, shape: Tuple[int, ...], mesh: Mesh, ax: dict) -> P:
    fsdp, tp = ax["fsdp"], ax["tp"]
    nd = len(shape)

    def spec(*entries):
        # pad with None for unhandled leading dims (the scan-stacked L axis)
        pad = (None,) * (nd - len(entries))
        fitted = tuple(_fit(mesh, shape[len(pad) + i], a) for i, a in enumerate(entries))
        return P(*(pad + fitted))

    if "embed" in path and "dec_pos" not in path:
        return spec(tp, fsdp)
    if "lm_head" in path:
        return spec(fsdp, tp)
    if "dec_pos" in path:
        return P(*(None,) * nd)
    # attention projections (2-D weights, flattened head dims)
    attn_tp = tp if ax.get("shard_heads", True) else None
    if path.endswith("wq") or path.endswith("wk") or path.endswith("wv"):
        return spec(fsdp, attn_tp)
    if path.endswith("wo") and ("attn" in path or "xattn" in path):
        return spec(attn_tp, fsdp)
    # MoE
    if "moe" in path:
        if "router" in path:
            return spec(fsdp, None)
        if path.endswith("wg") or path.endswith("wu"):
            return spec(None, fsdp, tp)
        if path.endswith("wo"):
            return spec(None, tp, fsdp)
    # dense MLP
    if path.endswith("wg") or path.endswith("wu") or path.endswith("wi"):
        return spec(fsdp, tp)
    if path.endswith("wo") or path.endswith("mlp.wo"):
        return spec(tp, fsdp)
    # SSM
    if "in_proj" in path:
        return spec(fsdp, tp)
    if "x_proj" in path:
        return spec(tp, None)
    if "dt_proj" in path:
        return spec(None, tp)
    if "out_proj" in path:
        return spec(tp, fsdp)
    if "conv_w" in path:
        return spec(tp, None)
    if any(k in path for k in ("conv_b", "dt_bias", "A_log")) or path.endswith("D"):
        return spec(tp) if nd >= 1 else P()
    # norms / small leaves: replicated
    return P(*(None,) * nd)


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return ".".join(parts)


def param_specs(param_tree, cfg: ModelConfig, mesh: Mesh):
    """NamedSharding tree matching an (eval_shape'd) parameter tree."""
    ax = mesh_axes(mesh, getattr(cfg, "layout", "tp"))
    ax["shard_heads"] = getattr(cfg, "shard_heads", True)

    def leaf(path, x):
        return NamedSharding(mesh, _param_rule(_path_str(path), x.shape, mesh, ax))

    return jax.tree_util.tree_map_with_path(leaf, param_tree)


def opt_state_specs(opt_tree, param_spec_tree, cfg: ModelConfig, mesh: Mesh):
    """Optimizer slots: adam m/v mirror the param specs; adafactor vr/vc
    drop the factored dim from the parent's spec; scalars replicate."""
    ax = mesh_axes(mesh, getattr(cfg, "layout", "tp"))
    ax["shard_heads"] = getattr(cfg, "shard_heads", True)

    def leaf(path, x):
        ps = _path_str(path)
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        base = _param_rule(_strip_slot(ps), x.shape, mesh, ax)
        return NamedSharding(mesh, base)

    def dispatch(path, x):
        ps = _path_str(path)
        if ps.endswith("vr") or ps.endswith("vc"):
            # Factored slots are rank-reduced and tiny relative to adam m/v;
            # shard the largest dim over fsdp when it divides, else replicate.
            if x.ndim >= 1:
                last = _fit(mesh, x.shape[-1], ax["fsdp"])
                return NamedSharding(mesh, P(*(None,) * (x.ndim - 1), last))
            return NamedSharding(mesh, P())
        return leaf(path, x)

    return jax.tree_util.tree_map_with_path(dispatch, opt_tree)


def _strip_slot(path: str) -> str:
    for slot in (".m.", ".v."):
        if slot in path:
            _, _, rest = path.partition(slot)
            return rest
    for suffix in (".vr", ".vc", ".v"):
        if path.endswith(suffix):
            path = path[: -len(suffix)]
    for prefix in ("m.", "v."):
        if path.startswith(prefix):
            path = path[len(prefix):]
    return path


# ---------------------------------------------------------------------------
# inputs / cache
# ---------------------------------------------------------------------------


def _batch_axes_for(mesh: Mesh, ax: dict, b: int):
    dp = ax["dp"]
    return dp if b % _axsize(mesh, dp) == 0 else None


def batch_specs(batch_tree, cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    ax = mesh_axes(mesh, getattr(cfg, "layout", "tp"))
    dp = _batch_axes_for(mesh, ax, shape.global_batch)

    def leaf(path, x):
        ps = _path_str(path)
        if ps.endswith("positions") and x.ndim >= 2 and x.shape[0] == 3:
            rest = (None,) * (x.ndim - 2)
            return NamedSharding(mesh, P(None, dp, *rest))
        rest = (None,) * (x.ndim - 1)
        return NamedSharding(mesh, P(dp, *rest))

    return jax.tree_util.tree_map_with_path(leaf, batch_tree)


def cache_specs(cache_tree, cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """KV caches: (L, B, W, KV, hd) -> P(None, dp, tp, None, None): batch
    over data, *sequence* over model (flash-decode). SSM states: d_inner
    over model. Falls back to replication on non-dividing dims (B=1)."""
    ax = mesh_axes(mesh, getattr(cfg, "layout", "tp"))
    dp = _batch_axes_for(mesh, ax, shape.global_batch)
    tp = ax["tp"]

    def leaf(path, x):
        ps = _path_str(path)
        if ps.endswith(".k") or ps.endswith(".v"):
            w = x.shape[2]
            seq_ax = _fit(mesh, w, tp)
            return NamedSharding(mesh, P(None, dp, seq_ax, None, None))
        if ps.endswith(".h"):  # (L, B, d_inner, N)
            return NamedSharding(mesh, P(None, dp, _fit(mesh, x.shape[2], tp), None))
        if ps.endswith(".conv"):  # (L, B, K-1, d_inner)
            return NamedSharding(mesh, P(None, dp, None, _fit(mesh, x.shape[3], tp)))
        if ps.endswith("slot_pos"):  # (B, W)
            return NamedSharding(mesh, P(dp, _fit(mesh, x.shape[1], tp)))
        if ps.endswith("enc_out"):  # (B, S_enc, D)
            return NamedSharding(mesh, P(dp, None, None))
        if ps.endswith("pos"):
            return NamedSharding(mesh, P(dp))
        rest = (None,) * (x.ndim - 1)
        return NamedSharding(mesh, P(dp, *rest))

    return jax.tree_util.tree_map_with_path(leaf, cache_tree)


def replicated(tree, mesh: Mesh):
    return jax.tree.map(lambda x: NamedSharding(mesh, P(*(None,) * x.ndim)), tree)


# ---------------------------------------------------------------------------
# rollout-engine fleet sharding (repro.serving.fleet)
# ---------------------------------------------------------------------------


def _leading_axis_spec(x, axis) -> P:
    nd = getattr(x, "ndim", 0)
    if nd == 0:
        raise ValueError(
            "fleet sharding needs a leading instance axis on every leaf; "
            "got a scalar — batch the pytree first (engine.init_batch / "
            "workloads.materialize_round_batch)")
    return P(axis, *(None,) * (nd - 1))


def engine_state_specs(state, axis: str = "fleet"):
    """``shard_map`` PartitionSpecs for a batched engine ``SimState`` pytree
    (:func:`repro.serving.engine.init_batch`): every leaf carries a leading
    (B,) instance axis — shard it over ``axis`` and replicate everything
    trailing. Instances are independent clusters, so per-instance state
    never crosses shards; only summary partials do (via psum in
    ``serving.fleet``)."""
    return jax.tree.map(lambda x: _leading_axis_spec(x, axis), state)


def arrival_specs(arrivals, axis: str = "fleet"):
    """PartitionSpecs for batched (B, R, A) arrival tensors
    (:func:`repro.workloads.batch.materialize_round_batch`) — and for any
    other per-instance leading-axis input of a fleet rollout ((B, 2) PRNG
    keys, (B,) displacement flags): shard the instance axis, replicate the
    rest."""
    return jax.tree.map(lambda x: _leading_axis_spec(x, axis), arrivals)
