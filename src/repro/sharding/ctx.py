"""Activation-sharding context.

Model code stays shard-agnostic; the launchers install a context and the
model calls :func:`constrain` at a handful of boundaries (embed output,
residual stream, logits). Outside a context every call is a no-op, so unit
tests and single-device smoke runs never touch mesh machinery.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

_TLS = threading.local()


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    mesh: jax.sharding.Mesh
    dp_axes: Tuple[str, ...]        # batch axes, e.g. ("data",) or ("pod","data")
    tp_axis: str = "model"
    fsdp_axis: str = "data"
    seq_shard: bool = False         # sequence parallelism on the residual
    batch_divisible: bool = True    # False when global batch < dp size

    @property
    def dp(self):
        return self.dp_axes if self.batch_divisible else None


def current() -> Optional[ShardCtx]:
    return getattr(_TLS, "ctx", None)


@contextlib.contextmanager
def use_sharding(ctx: ShardCtx):
    prev = current()
    _TLS.ctx = ctx
    try:
        yield ctx
    finally:
        _TLS.ctx = prev


def _spec_for(kind: str, ctx: ShardCtx, ndim: int) -> P:
    dp = ctx.dp
    seq = ctx.tp_axis if ctx.seq_shard else None
    if kind == "residual":        # (B, S, D)
        return P(dp, seq, None)
    if kind == "tokens":          # (B, S)
        return P(dp, None)
    if kind == "logits":          # (B, S, V) or (B, V)
        if ndim == 2:
            return P(dp, ctx.tp_axis)
        return P(dp, None, ctx.tp_axis)
    if kind == "decode_x":        # (B, D)
        return P(dp, None)
    raise ValueError(kind)


def constrain(x, kind: str):
    ctx = current()
    if ctx is None:
        return x
    spec = _spec_for(kind, ctx, x.ndim)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
