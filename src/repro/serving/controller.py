"""Central controller (paper Fig. 2): snapshot -> schedule -> dispatch.

Scheduling backends: the trained CoRaiS policy (greedy or sampling decode,
optionally with the fused in-kernel decode — ``fused_decode=True`` — which
never materializes the per-round (Z, Q) log-prob matrix), the heuristics
(local / random / greedy insertion), or the ILS reference. The controller
is scheduler-agnostic: every backend consumes the same frozen instance
produced by core.state.snapshot_instance, so swapping the paper's learned
scheduler against baselines is a one-line config change. For the
latency-bound serving loop proper, see :mod:`repro.serving.fastpath`
(bucketed compile-once decisions, double-buffered staging, SLO checks).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.heuristics import solve_greedy, solve_local, solve_random
from repro.core.inference import DecisionSpec, make_decision_fn
from repro.core.policy import PolicyConfig
from repro.core.state import QueuedRequest, snapshot_instance
from repro.serving.topology import nearest_alive_edge

SchedulerChoice = ("corais", "corais-sample", "greedy", "local", "random", "ils")


@dataclasses.dataclass
class CentralController:
    scheduler: str = "greedy"
    policy_params: Optional[dict] = None
    policy_state: Optional[dict] = None
    policy_cfg: Optional[PolicyConfig] = None
    sample_n: int = 128
    seed: int = 0
    # pad snapshots so the jitted policy sees a constant shape
    q_pad: int = 0
    z_pad: int = 64
    # decode inside the scoring kernel (never materialize (Z, Q)); with
    # sampling, draw from the kernel's top-``num_candidates`` set
    # (None: all edges — exact eq-19 distribution)
    fused_decode: bool = False
    num_candidates: Optional[int] = None
    # full decode configuration in one value; overrides the per-field knobs
    # above when set (see repro.core.inference.DecisionSpec)
    decision: Optional[DecisionSpec] = None

    def __post_init__(self):
        self._key = jax.random.PRNGKey(self.seed)
        self._decide = None
        self.last_decision_time = 0.0

    def decision_spec(self) -> DecisionSpec:
        """The DecisionSpec this controller schedules with — ``decision``
        verbatim when given, else assembled from the legacy per-field
        knobs (scheduler name picks the decode mode)."""
        if self.decision is not None:
            return self.decision
        mode = "sample" if self.scheduler == "corais-sample" else "greedy"
        return DecisionSpec(mode=mode, num_samples=self.sample_n,
                            fused_decode=self.fused_decode,
                            num_candidates=self.num_candidates)

    def _policy_assign(self, inst) -> np.ndarray:
        if self._decide is None:
            # shared decision path (core.inference): compile once against
            # the padded snapshot shape, reuse every round
            self._decide = make_decision_fn(
                self.policy_params, self.policy_state, self.policy_cfg,
                self.decision_spec())
        jinst = jax.tree.map(jnp.asarray, inst)
        self._key, sub = jax.random.split(self._key)
        assign = self._decide(jinst, sub)
        return np.asarray(jax.block_until_ready(assign))

    def schedule(self, edges, pending: Sequence[QueuedRequest], w: np.ndarray,
                 ct: float) -> list[tuple[QueuedRequest, int]]:
        """Returns [(request, execution_edge)] for this round (CC step iv)."""
        if not pending:
            return []
        alive = [e for e in edges if e.alive]
        alive_ids = [e.edge_id for e in alive]
        id_map = {aid: i for i, aid in enumerate(alive_ids)}
        w_alive = w[np.ix_(alive_ids, alive_ids)]
        # remap request sources onto the alive-edge index space; a request
        # from a dead edge is re-homed at the *nearest* alive edge (its data
        # must be re-sent from there), not silently at alive index 0, which
        # would bias every transfer-distance cost
        alive_flags = np.zeros(w.shape[0], bool)
        for e in edges:
            alive_flags[e.edge_id] = e.alive
        remapped = []
        for r in pending:
            rr = dataclasses.replace(r)
            src = r.source_edge
            if src not in id_map:
                src = nearest_alive_edge(w, src, alive_flags)
            rr.source_edge = id_map[src]
            remapped.append(rr)
        zp = max(self.z_pad, len(remapped))
        qp = max(self.q_pad, len(alive))
        inst = snapshot_instance([e.state for e in alive], remapped, w_alive,
                                 ct, q_pad=qp, z_pad=zp, w_global=w)
        t0 = time.perf_counter()
        if self.scheduler in ("corais", "corais-sample"):
            assign = self._policy_assign(inst)
        elif self.scheduler == "greedy":
            assign = solve_greedy(inst)
        elif self.scheduler == "local":
            assign = solve_local(inst)
        elif self.scheduler == "random":
            assign = solve_random(inst, 100, seed=self.seed)
        elif self.scheduler == "ils":
            from repro.core.heuristics import solve_ils
            assign = solve_ils(inst, budget_s=1.0, seed=self.seed)
        else:
            raise ValueError(self.scheduler)
        self.last_decision_time = time.perf_counter() - t0
        out = []
        for i, r in enumerate(pending):
            exec_alive_idx = int(assign[i]) % max(len(alive), 1)
            out.append((r, alive_ids[exec_alive_idx]))
        return out
