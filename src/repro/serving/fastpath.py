"""Online serving fast path: compile-once, double-buffered decision loop.

The controller's `_policy_assign` already compiles one decision function
against a fixed padded shape; this module is the production version of that
idea, built for the paper's real-time regime (Q <= 100 edges, Z <= 1000
requests per round, millisecond decisions):

fixed padding buckets
    Live rounds vary in (q, z); jit recompiles per shape. The fast path
    quantizes every snapshot up to a small ladder of (q_pad, z_pad) buckets
    (:data:`DEFAULT_BUCKETS` covers the paper grid) so the steady state
    touches a handful of compiled executables, all warmed ahead of time by
    :meth:`DecisionFastPath.warmup`. Decisions are mask-invariant (pinned
    by tests/test_policy_stack.py), so bucket padding never changes an
    assignment.

fused in-kernel decode
    Buckets default to ``fused_decode=True`` — argmax/top-k happen inside
    the scoring kernel (see kernels/policy_score.py) and the round's (Z, Q)
    log-prob matrix is never materialized; the transfer back to the host is
    (z,) int32 instead of (Z, Q) f32. Greedy buckets also default to
    ``normalize=False``: the log-softmax normalizer cannot change an
    argmax, so serving skips it.

double-buffered staging + donated device buffers
    :meth:`submit` stages the padded snapshot into one of two host-side
    numpy buffer sets (ping-pong), ships it, and returns immediately with
    the decision still in flight (jax dispatch is async); :meth:`result`
    blocks. Staging round n+1 therefore never overwrites host memory an
    in-flight transfer of round n may still be reading. With ``donate=True``
    (default off-CPU; CPU jax does not support donation) the instance
    buffers are donated to the call, so XLA reuses the same device memory
    round after round instead of allocating per decision.

explicit SLOs
    :class:`SLOSpec` states the latency contract (p50/p95/p99 in ms);
    :func:`evaluate_slo` drives a fast path over a workload and returns a
    machine-checkable pass/fail report (benchmarks/policy_latency.py
    ``--fastpath`` writes it to results/slo_report.json; CI uploads it).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.inference import DecisionSpec, make_decision_fn
from repro.core.policy import PolicyConfig

#: (q_pad, z_pad) ladder covering the paper's serving grid (Q <= 100 edges,
#: Z <= 1000 requests/round). A snapshot lands in the smallest bucket that
#: holds it; oversize snapshots raise rather than silently recompile.
DEFAULT_BUCKETS = ((10, 100), (25, 250), (50, 500), (100, 1000))

#: Instance leaves staged per round, with their pad axis counts
#: ((n_q_axes, n_z_axes) interpretation is positional below).
_EDGE_KEYS = ("edge_coords", "phi", "replicas", "workload", "edge_mask")
_REQ_KEYS = ("req_src", "req_size", "req_mask")


def pad_instance(inst: dict, q_pad: int, z_pad: int) -> dict:
    """Zero-pad a host-side instance to (q_pad, z_pad) (numpy, no device
    work). Masks pad with False, so the policy's decision on the real rows
    is unchanged (mask invariance)."""
    q = int(np.shape(inst["edge_mask"])[-1])
    z = int(np.shape(inst["req_mask"])[-1])
    if q > q_pad or z > z_pad:
        raise ValueError(f"instance ({q}, {z}) exceeds pad ({q_pad}, {z_pad})")
    dq, dz = q_pad - q, z_pad - z
    out = dict(inst)
    for k in _EDGE_KEYS:
        a = np.asarray(inst[k])
        out[k] = np.pad(a, ((0, dq),) + ((0, 0),) * (a.ndim - 1))
    out["w"] = np.pad(np.asarray(inst["w"]), ((0, dq), (0, dq)))
    for k in _REQ_KEYS:
        out[k] = np.pad(np.asarray(inst[k]), (0, dz))
    return out


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Latency contract for one decision path, in milliseconds."""

    p50_ms: float
    p95_ms: float
    p99_ms: float
    name: str = "decision"

    def check(self, samples_ms: Sequence[float]) -> dict:
        """Measured percentiles vs the contract -> pass/fail report row."""
        s = np.asarray(list(samples_ms), np.float64)
        if s.size == 0:
            raise ValueError("no latency samples to check against the SLO")
        measured = {p: float(np.percentile(s, p)) for p in (50, 95, 99)}
        target = {50: self.p50_ms, 95: self.p95_ms, 99: self.p99_ms}
        ok = {p: measured[p] <= target[p] for p in measured}
        return {
            "name": self.name,
            "samples": int(s.size),
            "p50_ms": measured[50], "p50_slo_ms": target[50],
            "p95_ms": measured[95], "p95_slo_ms": target[95],
            "p99_ms": measured[99], "p99_slo_ms": target[99],
            "p50_ok": ok[50], "p95_ok": ok[95], "p99_ok": ok[99],
            "pass": all(ok.values()),
        }


class DecisionFastPath:
    """Compile-once, double-buffered policy decision loop.

    One instance owns, per padding bucket: a jitted decision function
    (built by :func:`repro.core.inference.make_decision_fn`, fused decode
    by default) and two ping-pong host staging buffer sets. The round loop
    is ``submit`` (stage + async dispatch) then ``result`` (block + strip
    padding); :meth:`decide` does both, :meth:`stream` overlaps them one
    round deep.

    ``donate=None`` resolves to True off-CPU (CPU jax warns and copies on
    donation, so it stays off there). Greedy mode reuses one constant PRNG
    key (the decode ignores it); sample mode folds the round counter into
    the seed so repeated rounds draw fresh candidates.
    """

    def __init__(self, params, policy_state, cfg: PolicyConfig,
                 spec: Optional[DecisionSpec] = None, *,
                 mode: str = "greedy", num_samples: int = 64,
                 buckets: Sequence[tuple[int, int]] = DEFAULT_BUCKETS,
                 fused_decode: bool = True,
                 normalize: Optional[bool] = None,
                 num_candidates: Optional[int] = None,
                 backend: Optional[str] = None,
                 donate: Optional[bool] = None, seed: int = 0):
        if donate is None:
            donate = jax.default_backend() != "cpu"
        if spec is None:
            if normalize is None:
                # the normalizer cannot move a greedy argmax; sampling
                # needs true log-probs
                normalize = mode != "greedy"
            spec = DecisionSpec(mode=mode, num_samples=num_samples,
                                backend=backend, fused_decode=fused_decode,
                                num_candidates=num_candidates,
                                normalize=normalize)
        self.spec = spec
        self.mode = spec.mode
        self.buckets = tuple(sorted(tuple(b) for b in buckets))
        self.donate = donate
        self._params, self._state, self._cfg = params, policy_state, cfg
        self._fns: dict[tuple[int, int], object] = {}
        self._staging: dict[tuple[int, int], list] = {}
        self._slot: dict[tuple[int, int], int] = {}
        self._round = 0
        self._key0 = jax.random.PRNGKey(seed)
        self.compile_ms: dict[tuple[int, int], float] = {}
        self.latencies_ms: list[float] = []

    # -- bucket machinery ---------------------------------------------------

    def bucket_for(self, q: int, z: int) -> tuple[int, int]:
        """Smallest bucket holding a (q, z) snapshot; raises when none do."""
        for b in self.buckets:
            if q <= b[0] and z <= b[1]:
                return b
        raise ValueError(
            f"snapshot ({q}, {z}) exceeds every fast-path bucket "
            f"{self.buckets}; add a larger bucket")

    def _get_fn(self, bucket):
        fn = self._fns.get(bucket)
        if fn is None:
            fn = make_decision_fn(self._params, self._state, self._cfg,
                                  self.spec, donate=self.donate)
            self._fns[bucket] = fn
            # two host staging pytrees (ping-pong): stage round n+1 while
            # round n's transfer may still be reading the other set
            self._staging[bucket] = [None, None]
            self._slot[bucket] = 0
        return fn

    def _stage(self, inst, bucket):
        """Pad into this bucket's current ping-pong staging buffers."""
        slot = self._slot[bucket]
        self._slot[bucket] = 1 - slot
        padded = pad_instance(inst, *bucket)
        buf = self._staging[bucket][slot]
        if buf is None:
            buf = {k: np.array(v, copy=True) for k, v in padded.items()}
            self._staging[bucket][slot] = buf
        else:
            for k, v in padded.items():
                np.copyto(buf[k], v, casting="same_kind")
        return buf

    def _round_key(self):
        if self.mode == "greedy":
            return self._key0  # decode ignores it: constant, never re-split
        return jax.random.fold_in(self._key0, self._round)

    # -- decision loop ------------------------------------------------------

    def warmup(self, buckets: Optional[Sequence[tuple[int, int]]] = None):
        """Compile (and time) the decision executable of each bucket ahead
        of traffic; returns {bucket: compile_ms}."""
        for bucket in (buckets or self.buckets):
            bucket = tuple(bucket)
            fn = self._get_fn(bucket)
            zero = {
                "edge_coords": np.zeros((bucket[0], 2), np.float32),
                "phi": np.zeros((bucket[0], 2), np.float32),
                "replicas": np.ones(bucket[0], np.float32),
                "workload": np.zeros((bucket[0], 3), np.float32),
                "w": np.zeros((bucket[0], bucket[0]), np.float32),
                "ct": np.float32(1.0),
                "req_src": np.zeros(bucket[1], np.int32),
                "req_size": np.zeros(bucket[1], np.float32),
                "edge_mask": np.arange(bucket[0]) < 1,
                "req_mask": np.zeros(bucket[1], bool),
            }
            t0 = time.perf_counter()
            jax.block_until_ready(fn(jax.device_put(zero), self._key0))
            self.compile_ms[bucket] = (time.perf_counter() - t0) * 1e3
        return dict(self.compile_ms)

    def submit(self, inst: dict):
        """Stage + dispatch one decision; returns an in-flight handle
        (jax async dispatch — the host is free as soon as this returns)."""
        q = int(np.shape(inst["edge_mask"])[-1])
        z = int(np.shape(inst["req_mask"])[-1])
        bucket = self.bucket_for(q, z)
        fn = self._get_fn(bucket)
        staged = self._stage(inst, bucket)
        dev = jax.device_put(staged)
        out = fn(dev, self._round_key())
        self._round += 1
        return out, z

    def result(self, handle) -> np.ndarray:
        """Block on an in-flight decision; returns the (z,) int32 assignment
        with bucket padding stripped."""
        out, z = handle
        return np.asarray(jax.block_until_ready(out))[:z]

    def decide(self, inst: dict) -> np.ndarray:
        """Synchronous submit+result, recording wall latency (ms)."""
        t0 = time.perf_counter()
        assign = self.result(self.submit(inst))
        self.latencies_ms.append((time.perf_counter() - t0) * 1e3)
        return assign

    def stream(self, insts: Iterable[dict]):
        """Pipelined decision stream: round n+1 is staged and dispatched
        while round n's result is awaited (the double buffer exists for
        exactly this overlap). Yields (z,) assignments in order."""
        pending = None
        for inst in insts:
            nxt = self.submit(inst)
            if pending is not None:
                yield self.result(pending)
            pending = nxt
        if pending is not None:
            yield self.result(pending)


def evaluate_slo(fastpath: DecisionFastPath, insts: Sequence[dict],
                 slo: SLOSpec, *, warmup_rounds: int = 2) -> dict:
    """Drive the fast path over a workload and check the SLO contract.

    Replays ``insts`` through :meth:`DecisionFastPath.decide` (after
    warming exactly the padding buckets the workload will hit, plus
    ``warmup_rounds`` unmeasured decide passes per hit bucket to absorb
    dispatch-path warmup), then evaluates ``slo`` on the recorded wall
    latencies. Returns the :meth:`SLOSpec.check` report plus
    bucket/compile metadata.
    """
    if not insts:
        raise ValueError("evaluate_slo needs at least one instance")
    # Warm exactly the buckets this workload routes to. The old gate
    # ("skip warmup when any compile_ms entry exists") meant a partial
    # warmup([...]) suppressed warmup entirely, so the first decision in a
    # still-cold bucket paid its compilation inside a measured SLO sample.
    first_in_bucket: dict[tuple[int, int], dict] = {}
    for inst in insts:
        q = int(np.shape(inst["edge_mask"])[-1])
        z = int(np.shape(inst["req_mask"])[-1])
        first_in_bucket.setdefault(fastpath.bucket_for(q, z), inst)
    cold = [b for b in first_in_bucket if b not in fastpath.compile_ms]
    if cold:
        fastpath.warmup(cold)
    before = len(fastpath.latencies_ms)
    for inst in first_in_bucket.values():
        for _ in range(warmup_rounds):
            fastpath.decide(inst)
    del fastpath.latencies_ms[before:]
    for inst in insts:
        fastpath.decide(inst)
    report = slo.check(fastpath.latencies_ms[before:])
    report["buckets"] = [list(b) for b in fastpath.buckets]
    report["compile_ms"] = {f"{b[0]}x{b[1]}": ms
                            for b, ms in fastpath.compile_ms.items()}
    report["donate"] = fastpath.donate
    return report
