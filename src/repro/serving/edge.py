"""Edge executors for the multi-edge cooperative serving runtime.

``SimEdge`` models one edge: hidden true performance (phi coefficients the
scheduler never sees), zeta parallel service replicas (the paper's
Docker/K8s replica observation, §III-C), the five request queues of Fig. 5,
and an online :class:`PhiEstimator` fitted purely from local history —
exactly the paper's system-level state evaluation model.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import numpy as np

from repro.core.state import EdgeServiceState, PhiEstimator, QueuedRequest
from repro.serving.rounds import service_runtime


@dataclasses.dataclass
class SimEdge:
    edge_id: int
    coords: tuple
    true_a: float                 # hidden: runtime = true_a * size + true_b
    true_b: float
    replicas: int
    rng: np.random.Generator
    noise: float = 0.02
    speed_factor: float = 1.0     # >1 = straggler (slowed edge)
    alive: bool = True
    phi_oracle: bool = False      # pin the estimator to the true coefficients
    # Optional injected jitter, keyed by rid (rid -> multiplier). Set by
    # resilience.faults.schedule_into_sim so both engines realize the same
    # per-request noise (a retried request keeps its jitter); replaces the
    # edge-local gaussian noise draw when present.
    jitter_fn: Optional[object] = None

    def __post_init__(self):
        phi = (PhiEstimator(a=self.true_a, b=self.true_b, frozen=True)
               if self.phi_oracle else PhiEstimator(a=1.0, b=0.0))
        self.state = EdgeServiceState(
            edge_id=self.edge_id,
            coords=self.coords,
            phi=phi,
            replicas=self.replicas,
        )
        # replica lanes: next-free times
        self._lanes = [0.0] * self.replicas
        self.completed: list[QueuedRequest] = []
        self.inflight: dict[int, QueuedRequest] = {}

    # -- execution -----------------------------------------------------

    def true_runtime(self, size: float, rid: Optional[int] = None,
                     warmup: float = 0.0) -> float:
        if self.jitter_fn is not None and rid is not None:
            jitter = float(self.jitter_fn(rid))
        else:
            jitter = 1.0 + self.noise * float(self.rng.standard_normal())
        return float(service_runtime(self.true_a, self.true_b, size,
                                     speed=self.speed_factor, jitter=jitter,
                                     warmup=warmup))

    def start_executable(self, now: float) -> list[tuple[float, QueuedRequest]]:
        """Pop requests from Q^le onto free replica lanes.

        Returns (finish_time, request) events. The lane model reproduces
        eq (1)'s zeta-way parallel service."""
        events = []
        while self.state.q_le and min(self._lanes) <= now + 1e-12 and self.alive:
            lane = int(np.argmin(self._lanes))
            req = self.state.q_le.pop(0)
            rt = self.true_runtime(req.data_size, rid=req.rid,
                                   warmup=req.miss_penalty)
            start = max(now, self._lanes[lane])
            self._lanes[lane] = start + rt
            req.start_time = start
            req.finish_time = start + rt
            # local learning for phi (paper §III-C1: only local history)
            self.state.phi.observe(req.data_size, rt)
            self.inflight[req.rid] = req
            events.append((req.finish_time, req))
        return events

    def next_free(self) -> float:
        return min(self._lanes)

    def fail(self) -> list[QueuedRequest]:
        """Edge failure: return every unfinished request (queued AND mid-
        execution) for re-dispatch; replica lanes die with the edge."""
        self.alive = False
        orphans = (list(self.state.q_le) + list(self.state.q_in)
                   + list(self.state.q_r) + list(self.inflight.values()))
        # canonical re-admission order (global arrival order), so failover
        # tie-breaks match the batched engine's slot order
        orphans.sort(key=lambda r: r.rid)
        self.state.q_le.clear()
        self.state.q_in.clear()
        self.state.q_r.clear()
        self.inflight.clear()
        return orphans

    def recover(self, now: float) -> None:
        self.alive = True
        self._lanes = [now] * self.replicas
