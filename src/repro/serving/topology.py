"""Cluster-topology helpers shared by the simulator, the central controller,
and the workload driver."""
from __future__ import annotations

from typing import Sequence

import numpy as np


def nearest_alive_edge(w: np.ndarray, src: int,
                       alive: Sequence[bool]) -> int:
    """Nearest alive edge to ``src`` by transmission distance ``w[src]``
    (``src`` itself when alive, since w[src, src] == 0). This is the single
    failover rule used everywhere a request references a dead edge: client
    arrivals, orphan re-dispatch, and controller source remapping.

    Raises ``RuntimeError`` when the whole cluster is down.
    """
    for cand in np.argsort(w[src], kind="stable"):
        if alive[cand]:
            return int(cand)
    raise RuntimeError("no alive edges")
