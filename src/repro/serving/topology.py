"""Cluster-topology helpers shared by the simulator, the central controller,
and the workload driver — including the edge–cloud tier description.

Topology with a cloud tier (``CloudSpec``)::

        clients ──► edge 0 ──┐
        clients ──► edge 1 ──┤   LAN: delay = ct * size * w[i, j]
           ...               ├──────────────────────────────────────┐
        clients ──► edge Q-1─┘                                      │
                                                                    ▼
                              WAN: delay = wan_rtt + ct * size * wan_dist
                                                                    │
                                                              ┌─────▼─────┐
                                                              │   cloud   │
                                                              │ lanes >> m│
                                                              │ all-hit $ │
                                                              └───────────┘

The cloud is one extra node (index Q) appended to every per-node array:
requests never *arrive* there, but any request may be dispatched there.
Its transfer law adds a fixed WAN round-trip ``wan_rtt`` on top of the
size-proportional term (eq 2 with distance ``wan_dist``), its service law
is its own phi line (``phi_a * size + phi_b``), and its capacity is
elastic — ``lanes`` parallel service lanes vs. an edge's few replicas. Its
service cache is the origin store: always a hit (see serving/cache.py).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class CloudSpec:
    """The cloud tier's transfer/runtime laws (see module docstring).

    wan_rtt   fixed WAN round-trip seconds added to every edge→cloud
              transfer (independent of size; the speed-of-light + peering
              floor a LAN hop doesn't pay).
    wan_dist  effective transmission distance of the WAN link — the
              size-proportional bandwidth term (eq 2's w) between every
              edge and the cloud.
    lanes     parallel service lanes (elastic capacity; an edge has
              ``replicas`` ∈ [1, replicas_high], the cloud has many).
    phi_a/b   the cloud's service-runtime line phi(size) = a*size + b.
    coords    nominal unit-square coordinates (only feeds the policy's
              edge-coordinate features; WAN costs ignore geometry).
    """

    wan_rtt: float = 0.5
    wan_dist: float = 2.0
    lanes: int = 16
    phi_a: float = 0.2
    phi_b: float = 0.05
    coords: tuple = (0.5, 0.5)


def nearest_alive_edge(w: np.ndarray, src: int,
                       alive: Sequence[bool]) -> int:
    """Nearest alive edge to ``src`` by transmission distance ``w[src]``
    (``src`` itself when alive, since w[src, src] == 0). This is the single
    failover rule used everywhere a request references a dead edge: client
    arrivals, orphan re-dispatch, and controller source remapping.

    Raises ``RuntimeError`` when the whole cluster is down.
    """
    for cand in np.argsort(w[src], kind="stable"):
        if alive[cand]:
            return int(cand)
    raise RuntimeError("no alive edges")
