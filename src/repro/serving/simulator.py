"""Event-driven multi-edge cooperative serving simulator.

Implements the seven scheduling-process steps of paper Fig. 2 on a virtual
cluster: clients submit to their local edge (Q^r), the central controller
schedules each round from request *briefs* + evaluated edge states, data
transfers cost C_t * size * distance (eq 2/7 semantics), zeta replica lanes
execute in parallel, and completions flow to Q^F. Supports edge failures
(orphaned requests re-enter the controller pool — fault tolerance) and
stragglers (a slowed edge is routed around via workload perception, paper
§V-B3/WP).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import numpy as np

from repro.core.state import QueuedRequest
from repro.serving.cache import CacheSpec, HostCache
from repro.serving.controller import CentralController
from repro.serving.edge import SimEdge
from repro.serving.rounds import (extend_cluster_with_cloud, sample_cluster,
                                  transfer_delay)
from repro.serving.topology import CloudSpec, nearest_alive_edge
from repro.workloads.base import Workload, workload_rng


@dataclasses.dataclass
class SimConfig:
    num_edges: int = 5
    replicas_high: int = 4
    ct: float = 1.0
    round_interval: float = 0.25
    seed: int = 0
    phi_low: float = 0.2
    phi_high: float = 1.0
    exec_noise: float = 0.02
    # Oracle mode: every edge's estimator is pinned to its hidden true
    # coefficients (no online fitting). Used with exec_noise=0 to pin this
    # simulator against the batched engine, which shares the same cluster
    # prior via rounds.sample_cluster.
    phi_oracle: bool = False
    # Edge–cloud tier (schema v3): an optional cloud node appended as index
    # ``num_edges`` (WAN distance + fixed RTT, elastic lanes) and optional
    # per-edge service caches. Mirrors EngineConfig.cloud / .cache.
    cloud: Optional[CloudSpec] = None
    cache: Optional[CacheSpec] = None

    @property
    def num_nodes(self) -> int:
        return self.num_edges + (1 if self.cloud is not None else 0)


class MultiEdgeSim:
    def __init__(self, cfg: SimConfig, controller: CentralController):
        self.cfg = cfg
        self.cc = controller
        cluster = sample_cluster(cfg.num_edges, cfg.replicas_high,
                                 cfg.phi_low, cfg.phi_high, cfg.seed)
        if cfg.cloud is not None:
            cluster = extend_cluster_with_cloud(cluster, cfg.cloud)
        self.w = cluster.w
        self.edges = [
            SimEdge(
                edge_id=i,
                coords=tuple(cluster.coords[i]),
                true_a=float(cluster.true_a[i]),
                true_b=float(cluster.true_b[i]),
                replicas=int(cluster.replicas[i]),
                rng=np.random.default_rng((cfg.seed, i)),
                noise=cfg.exec_noise,
                phi_oracle=cfg.phi_oracle,
            )
            for i in range(cfg.num_nodes)
        ]
        # fixed per-destination RTT (zero for edges, wan_rtt for the cloud);
        # additive on top of the size-proportional eq-(2) transfer delay
        self.rtt = np.zeros(cfg.num_nodes)
        if cfg.cloud is not None:
            self.rtt[cfg.num_edges] = cfg.cloud.wan_rtt
        self.cache = (HostCache(cfg.num_nodes, cfg.num_edges, cfg.cache)
                      if cfg.cache is not None else None)
        self.now = 0.0
        self._events: list = []   # heap of (time, seq, kind, payload)
        self._seq = 0
        self._rid = 0
        self._deadline_finite = 0   # submitted requests with a finite deadline
        self._retried: set[int] = set()   # rids orphaned by an edge failure
        self.metrics_rows: list[dict] = []
        self.decision_times: list[float] = []   # one entry per non-empty round

    # -- client API ------------------------------------------------------

    def submit(self, edge_id: int, data_size: float, t: Optional[float] = None,
               service: int = 0, deadline: float = float("inf"),
               priority: int = 0):
        """Submit one request brief. ``deadline`` is the *absolute* hard-SLO
        time (schema v3; ``inf`` = none), ``service`` keys the node caches."""
        req = QueuedRequest(rid=self._rid, data_size=float(data_size),
                            source_edge=edge_id,
                            service=int(service),
                            submit_time=self.now if t is None else t,
                            deadline=float(deadline), priority=int(priority))
        self._rid += 1
        if np.isfinite(req.deadline):
            self._deadline_finite += 1
        self._push(req.submit_time, "arrival", req)
        return req

    def drive(self, workload: Workload, until: float,
              run_until: Optional[float] = None,
              seed: Optional[int] = None) -> dict:
        """Generate arrivals from a :class:`repro.workloads.Workload` (or a
        replayed trace) over [0, until], submit them, and run the event loop
        to ``run_until`` (default: ``until``; pass a larger horizon to let
        late arrivals drain). Arrivals aimed at a dead edge fail over to the
        nearest alive edge via the standard arrival path. Deterministic for a
        fixed (workload, seed, config)."""
        trace_edges = int(getattr(workload, "num_edges", 0))
        if trace_edges > self.cfg.num_edges:
            raise ValueError(
                f"trace was recorded on {trace_edges} edges but this sim has "
                f"only {self.cfg.num_edges}; refusing to alias edge ids")
        rng = workload_rng(self.cfg.seed if seed is None else seed)
        for a in workload.arrivals(rng, self.cfg.num_edges, until):
            if not 0 <= a.edge < self.cfg.num_edges:
                raise ValueError(f"arrival at t={a.t} targets edge {a.edge}, "
                                 f"outside 0..{self.cfg.num_edges - 1}")
            self.submit(int(a.edge), float(a.size), t=float(a.t),
                        service=int(getattr(a, "service", 0)),
                        deadline=(float(a.t) + float(a.deadline)
                                  if getattr(a, "deadline", 0.0) > 0
                                  else float("inf")),
                        priority=int(getattr(a, "priority", 0)))
        return self.run(until if run_until is None else run_until)

    def fail_edge(self, edge_id: int, t: float):
        self._push(t, "fail", edge_id)

    def recover_edge(self, edge_id: int, t: float):
        self._push(t, "recover", edge_id)

    def set_straggler(self, edge_id: int, factor: float, t: float):
        self._push(t, "straggle", (edge_id, factor))

    # -- internals ---------------------------------------------------------

    def _push(self, t, kind, payload):
        heapq.heappush(self._events, (t, self._seq, kind, payload))
        self._seq += 1

    def _round(self):
        """One CC scheduling round over all pending briefs (Fig. 2 iii-vi)."""
        pending = []
        for e in self.edges:
            pending.extend(e.state.q_r)
            e.state.q_r = []
        if pending:
            decisions = self.cc.schedule(self.edges, pending, self.w,
                                         self.cfg.ct)
            self.decision_times.append(self.cc.last_decision_time)
            if self.cache is not None:
                # Cache pass in global arrival (rid) order — the batched
                # engine's commit scans the round's slots in the same order,
                # so hit/miss outcomes are identical across engines.
                for req, target in sorted(decisions, key=lambda d: d[0].rid):
                    hit = self.cache.access(target, req.service)
                    req.miss_penalty = (0.0 if hit
                                        else self.cache.spec.miss_penalty)
            # Dispatch in decision (admission) order: fault-mode orphan
            # retries must join queues after the round's fresh arrivals
            # (the engine's RETRY_EPS ready-time nudge encodes the same).
            for req, target in decisions:
                req.exec_edge = target
                src, dst = self.edges[req.source_edge], self.edges[target]
                if target == req.source_edge:
                    dst.state.q_le.append(req)
                else:
                    src.state.q_out.append(req)
                    dst.state.q_in.append(req)
                    dt = (transfer_delay(self.cfg.ct, req.data_size,
                                         self.w[req.source_edge, target])
                          + self.rtt[target])
                    self._push(self.now + dt, "transfer_done", req)
        # kick executions
        for e in self.edges:
            for ft, req in e.start_executable(self.now):
                self._push(ft, "exec_done", (req, e.edge_id, ft))

    def run(self, until: float):
        # arm the scheduling-round chain once: a second run()/drive() call
        # must not stack a parallel chain and double the round frequency
        if not any(kind == "round" for _, _, kind, _ in self._events):
            self._push(self.now + 1e-9, "round", None)
        while self._events and self._events[0][0] <= until:
            t, _, kind, payload = heapq.heappop(self._events)
            self.now = max(self.now, t)
            if kind == "arrival":
                self._admit(payload)
            elif kind == "transfer_done":
                req = payload
                dst = self.edges[req.exec_edge]
                if not dst.alive:
                    continue  # failure path re-queues via fail()
                if req in dst.state.q_in:
                    dst.state.q_in.remove(req)
                    if req in self.edges[req.source_edge].state.q_out:
                        self.edges[req.source_edge].state.q_out.remove(req)
                    dst.state.q_le.append(req)
                    for ft, r2 in dst.start_executable(self.now):
                        self._push(ft, "exec_done", (r2, dst.edge_id, ft))
            elif kind == "exec_done":
                req, eid, ft = payload
                e = self.edges[eid]
                # stale-event guard: the request may have been orphaned by a
                # failure and re-dispatched elsewhere
                stale = (not e.alive or req.rid not in e.inflight
                         or req.exec_edge != eid
                         or abs(req.finish_time - ft) > 1e-12)
                if not stale:
                    e.inflight.pop(req.rid)
                    e.state.q_f.append(req)
                    e.completed.append(req)
                    self.metrics_rows.append({
                        "rid": req.rid,
                        "edge": eid,
                        "response": req.finish_time - req.submit_time,
                        "finish": req.finish_time,
                        "transferred": eid != req.source_edge,
                        "deadline": req.deadline,
                        "cloud": eid >= self.cfg.num_edges,
                    })
                    for ft2, r2 in e.start_executable(self.now):
                        self._push(ft2, "exec_done", (r2, e.edge_id, ft2))
            elif kind == "fail":
                orphans = self.edges[payload].fail()
                # fault tolerance: orphaned requests re-enter the pool at the
                # nearest alive edge (their data is re-sent from the source)
                for req in orphans:
                    req.exec_edge = -1
                    self._retried.add(req.rid)
                    self._admit(req)
            elif kind == "recover":
                self.edges[payload].recover(self.now)
            elif kind == "straggle":
                eid, factor = payload
                self.edges[eid].speed_factor = factor
            elif kind == "round":
                self._round()
                self._push(self.now + self.cfg.round_interval, "round", None)
        self.now = until
        return self.metrics()

    def _nearest_alive(self, src: int) -> int:
        """Nearest alive edge id to ``src`` (``src`` itself when alive)."""
        return nearest_alive_edge(self.w, src, [e.alive for e in self.edges])

    def _admit(self, req) -> None:
        """Enqueue a request at its source edge, failing over to the nearest
        alive edge. During a total outage the client retries next round
        instead of crashing the sim (the request just waits in the heap)."""
        try:
            cand = self._nearest_alive(req.source_edge)
        except RuntimeError:
            self._push(self.now + self.cfg.round_interval, "arrival", req)
            return
        req.source_edge = cand
        self.edges[cand].state.q_r.append(req)

    def metrics(self) -> dict:
        """Run summary: exactly :data:`repro.serving.engine.SUMMARY_KEYS`
        (the one summary schema shared with ``engine.summarize`` and
        ``fleet.fleet_summary``), plus the oracle-only ``decision_*``
        wall-clock keys. The oracle has no admission control or overflow
        clip, so ``shed_requests``/``dropped_requests`` are always 0 and
        ``stranded_requests`` counts submitted-but-never-completed work."""
        rows = self.metrics_rows
        dec = np.asarray(self.decision_times) if self.decision_times else None
        decision = {
            "scheduler_decision_s": self.cc.last_decision_time,
            "decision_rounds": len(self.decision_times),
            "decision_mean_s": float(dec.mean()) if dec is not None else 0.0,
            "decision_p95_s": (float(np.percentile(dec, 95))
                               if dec is not None else 0.0),
            "decision_max_s": float(dec.max()) if dec is not None else 0.0,
        }
        completed = len(rows)
        submitted = self._rid
        dl_total = self._deadline_finite
        fin_rows = [r for r in rows if np.isfinite(r["deadline"])]
        dl_missed = (sum(1 for r in fin_rows if r["finish"] > r["deadline"])
                     + (dl_total - len(fin_rows)))
        hits = self.cache.hits if self.cache is not None else 0
        misses = self.cache.misses if self.cache is not None else 0
        cloud_done = sum(1 for r in rows if r["cloud"])
        transferred = sum(1 for r in rows if r["transferred"])
        out = {
            "completed": completed,
            "submitted": submitted,
            "shed_requests": 0,
            "dropped_requests": 0,
            "stranded_requests": submitted - completed,
            "retried_requests": len(self._retried),
            "shed_rate": 0.0,
            "displaced_instances": 0,
            "deadline_total": dl_total,
            "deadline_missed": dl_missed,
            "deadline_miss_frac": dl_missed / max(dl_total, 1),
            "cache_hits": hits,
            "cache_misses": misses,
            "cache_hit_rate": hits / max(hits + misses, 1),
            "cloud_completed": cloud_done,
            "cloud_offload_frac": cloud_done / max(completed, 1),
            "transferred": transferred,
            "cross_shard_transferred": 0,
            "intra_fleet_transferred": transferred,
            "cross_shard_frac": 0.0,
            "cross_shard_completed": 0,
            **decision,
        }
        if not completed:
            out.update({k: 0.0 for k in ("mean_response", "p50_response",
                                         "p95_response", "max_response",
                                         "makespan", "transferred_frac")})
            out["per_edge_completed"] = {}
            return out
        resp = np.asarray([r["response"] for r in rows])
        per_edge = {e.edge_id: sum(1 for r in rows if r["edge"] == e.edge_id)
                    for e in self.edges}
        out.update({
            "mean_response": float(resp.mean()),
            "p50_response": float(np.percentile(resp, 50)),
            "p95_response": float(np.percentile(resp, 95)),
            "max_response": float(resp.max()),
            "transferred_frac": transferred / completed,
            "per_edge_completed": per_edge,
            "makespan": float(max(r["finish"] for r in rows)),
        })
        return out
