"""Event-driven multi-edge cooperative serving simulator.

Implements the seven scheduling-process steps of paper Fig. 2 on a virtual
cluster: clients submit to their local edge (Q^r), the central controller
schedules each round from request *briefs* + evaluated edge states, data
transfers cost C_t * size * distance (eq 2/7 semantics), zeta replica lanes
execute in parallel, and completions flow to Q^F. Supports edge failures
(orphaned requests re-enter the controller pool — fault tolerance) and
stragglers (a slowed edge is routed around via workload perception, paper
§V-B3/WP).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import numpy as np

from repro.core.state import QueuedRequest
from repro.serving.controller import CentralController
from repro.serving.edge import SimEdge
from repro.serving.rounds import sample_cluster, transfer_delay
from repro.serving.topology import nearest_alive_edge
from repro.workloads.base import Workload, workload_rng


@dataclasses.dataclass
class SimConfig:
    num_edges: int = 5
    replicas_high: int = 4
    ct: float = 1.0
    round_interval: float = 0.25
    seed: int = 0
    phi_low: float = 0.2
    phi_high: float = 1.0
    exec_noise: float = 0.02
    # Oracle mode: every edge's estimator is pinned to its hidden true
    # coefficients (no online fitting). Used with exec_noise=0 to pin this
    # simulator against the batched engine, which shares the same cluster
    # prior via rounds.sample_cluster.
    phi_oracle: bool = False


class MultiEdgeSim:
    def __init__(self, cfg: SimConfig, controller: CentralController):
        self.cfg = cfg
        self.cc = controller
        cluster = sample_cluster(cfg.num_edges, cfg.replicas_high,
                                 cfg.phi_low, cfg.phi_high, cfg.seed)
        self.w = cluster.w
        self.edges = [
            SimEdge(
                edge_id=i,
                coords=tuple(cluster.coords[i]),
                true_a=float(cluster.true_a[i]),
                true_b=float(cluster.true_b[i]),
                replicas=int(cluster.replicas[i]),
                rng=np.random.default_rng((cfg.seed, i)),
                noise=cfg.exec_noise,
                phi_oracle=cfg.phi_oracle,
            )
            for i in range(cfg.num_edges)
        ]
        self.now = 0.0
        self._events: list = []   # heap of (time, seq, kind, payload)
        self._seq = 0
        self._rid = 0
        self.metrics_rows: list[dict] = []
        self.decision_times: list[float] = []   # one entry per non-empty round

    # -- client API ------------------------------------------------------

    def submit(self, edge_id: int, data_size: float, t: Optional[float] = None):
        req = QueuedRequest(rid=self._rid, data_size=float(data_size),
                            source_edge=edge_id,
                            submit_time=self.now if t is None else t)
        self._rid += 1
        self._push(req.submit_time, "arrival", req)
        return req

    def drive(self, workload: Workload, until: float,
              run_until: Optional[float] = None,
              seed: Optional[int] = None) -> dict:
        """Generate arrivals from a :class:`repro.workloads.Workload` (or a
        replayed trace) over [0, until], submit them, and run the event loop
        to ``run_until`` (default: ``until``; pass a larger horizon to let
        late arrivals drain). Arrivals aimed at a dead edge fail over to the
        nearest alive edge via the standard arrival path. Deterministic for a
        fixed (workload, seed, config)."""
        trace_edges = int(getattr(workload, "num_edges", 0))
        if trace_edges > self.cfg.num_edges:
            raise ValueError(
                f"trace was recorded on {trace_edges} edges but this sim has "
                f"only {self.cfg.num_edges}; refusing to alias edge ids")
        rng = workload_rng(self.cfg.seed if seed is None else seed)
        for a in workload.arrivals(rng, self.cfg.num_edges, until):
            if not 0 <= a.edge < self.cfg.num_edges:
                raise ValueError(f"arrival at t={a.t} targets edge {a.edge}, "
                                 f"outside 0..{self.cfg.num_edges - 1}")
            self.submit(int(a.edge), float(a.size), t=float(a.t))
        return self.run(until if run_until is None else run_until)

    def fail_edge(self, edge_id: int, t: float):
        self._push(t, "fail", edge_id)

    def recover_edge(self, edge_id: int, t: float):
        self._push(t, "recover", edge_id)

    def set_straggler(self, edge_id: int, factor: float, t: float):
        self._push(t, "straggle", (edge_id, factor))

    # -- internals ---------------------------------------------------------

    def _push(self, t, kind, payload):
        heapq.heappush(self._events, (t, self._seq, kind, payload))
        self._seq += 1

    def _round(self):
        """One CC scheduling round over all pending briefs (Fig. 2 iii-vi)."""
        pending = []
        for e in self.edges:
            pending.extend(e.state.q_r)
            e.state.q_r = []
        if pending:
            decisions = self.cc.schedule(self.edges, pending, self.w,
                                         self.cfg.ct)
            self.decision_times.append(self.cc.last_decision_time)
            for req, target in decisions:
                req.exec_edge = target
                src, dst = self.edges[req.source_edge], self.edges[target]
                if target == req.source_edge:
                    dst.state.q_le.append(req)
                else:
                    src.state.q_out.append(req)
                    dst.state.q_in.append(req)
                    dt = transfer_delay(self.cfg.ct, req.data_size,
                                        self.w[req.source_edge, target])
                    self._push(self.now + dt, "transfer_done", req)
        # kick executions
        for e in self.edges:
            for ft, req in e.start_executable(self.now):
                self._push(ft, "exec_done", (req, e.edge_id, ft))

    def run(self, until: float):
        # arm the scheduling-round chain once: a second run()/drive() call
        # must not stack a parallel chain and double the round frequency
        if not any(kind == "round" for _, _, kind, _ in self._events):
            self._push(self.now + 1e-9, "round", None)
        while self._events and self._events[0][0] <= until:
            t, _, kind, payload = heapq.heappop(self._events)
            self.now = max(self.now, t)
            if kind == "arrival":
                self._admit(payload)
            elif kind == "transfer_done":
                req = payload
                dst = self.edges[req.exec_edge]
                if not dst.alive:
                    continue  # failure path re-queues via fail()
                if req in dst.state.q_in:
                    dst.state.q_in.remove(req)
                    if req in self.edges[req.source_edge].state.q_out:
                        self.edges[req.source_edge].state.q_out.remove(req)
                    dst.state.q_le.append(req)
                    for ft, r2 in dst.start_executable(self.now):
                        self._push(ft, "exec_done", (r2, dst.edge_id, ft))
            elif kind == "exec_done":
                req, eid, ft = payload
                e = self.edges[eid]
                # stale-event guard: the request may have been orphaned by a
                # failure and re-dispatched elsewhere
                stale = (not e.alive or req.rid not in e.inflight
                         or req.exec_edge != eid
                         or abs(req.finish_time - ft) > 1e-12)
                if not stale:
                    e.inflight.pop(req.rid)
                    e.state.q_f.append(req)
                    e.completed.append(req)
                    self.metrics_rows.append({
                        "rid": req.rid,
                        "edge": eid,
                        "response": req.finish_time - req.submit_time,
                        "transferred": eid != req.source_edge,
                    })
                    for ft2, r2 in e.start_executable(self.now):
                        self._push(ft2, "exec_done", (r2, e.edge_id, ft2))
            elif kind == "fail":
                orphans = self.edges[payload].fail()
                # fault tolerance: orphaned requests re-enter the pool at the
                # nearest alive edge (their data is re-sent from the source)
                for req in orphans:
                    req.exec_edge = -1
                    self._admit(req)
            elif kind == "recover":
                self.edges[payload].recover(self.now)
            elif kind == "straggle":
                eid, factor = payload
                self.edges[eid].speed_factor = factor
            elif kind == "round":
                self._round()
                self._push(self.now + self.cfg.round_interval, "round", None)
        self.now = until
        return self.metrics()

    def _nearest_alive(self, src: int) -> int:
        """Nearest alive edge id to ``src`` (``src`` itself when alive)."""
        return nearest_alive_edge(self.w, src, [e.alive for e in self.edges])

    def _admit(self, req) -> None:
        """Enqueue a request at its source edge, failing over to the nearest
        alive edge. During a total outage the client retries next round
        instead of crashing the sim (the request just waits in the heap)."""
        try:
            cand = self._nearest_alive(req.source_edge)
        except RuntimeError:
            self._push(self.now + self.cfg.round_interval, "arrival", req)
            return
        req.source_edge = cand
        self.edges[cand].state.q_r.append(req)

    def metrics(self) -> dict:
        rows = self.metrics_rows
        dec = np.asarray(self.decision_times) if self.decision_times else None
        decision = {
            "scheduler_decision_s": self.cc.last_decision_time,
            "decision_rounds": len(self.decision_times),
            "decision_mean_s": float(dec.mean()) if dec is not None else 0.0,
            "decision_p95_s": (float(np.percentile(dec, 95))
                               if dec is not None else 0.0),
            "decision_max_s": float(dec.max()) if dec is not None else 0.0,
        }
        if not rows:
            return {"completed": 0, "submitted": self._rid, **decision}
        resp = np.asarray([r["response"] for r in rows])
        per_edge = {e.edge_id: sum(1 for r in rows if r["edge"] == e.edge_id)
                    for e in self.edges}
        return {
            "completed": len(rows),
            "submitted": self._rid,
            "mean_response": float(resp.mean()),
            "p50_response": float(np.percentile(resp, 50)),
            "p95_response": float(np.percentile(resp, 95)),
            "max_response": float(resp.max()),
            "transferred_frac": float(np.mean([r["transferred"] for r in rows])),
            "per_edge_completed": per_edge,
            **decision,
        }
