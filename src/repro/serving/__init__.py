from repro.serving.controller import CentralController, SchedulerChoice
from repro.serving.simulator import MultiEdgeSim, SimConfig
from repro.serving.edge import SimEdge

__all__ = ["CentralController", "SchedulerChoice", "MultiEdgeSim", "SimConfig",
           "SimEdge"]
