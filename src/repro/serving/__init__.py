from repro.serving.controller import CentralController, SchedulerChoice
from repro.serving.simulator import MultiEdgeSim, SimConfig
from repro.serving.edge import SimEdge
from repro.serving.topology import nearest_alive_edge

__all__ = ["CentralController", "SchedulerChoice", "MultiEdgeSim", "SimConfig",
           "SimEdge", "nearest_alive_edge"]
