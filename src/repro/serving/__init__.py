from repro.serving.controller import CentralController, SchedulerChoice
from repro.serving.simulator import MultiEdgeSim, SimConfig
from repro.serving.edge import SimEdge
from repro.serving.engine import (ASSIGN_FNS, EngineConfig, greedy_assign,
                                  init_batch, init_state, local_assign,
                                  make_policy_assign, make_rollout,
                                  partials_to_summary, resolve_assign_fn,
                                  step_round, summarize, summarize_partials)
from repro.serving.fastpath import (DEFAULT_BUCKETS, DecisionFastPath,
                                    SLOSpec, evaluate_slo, pad_instance)
from repro.serving.fleet import (FleetPartition, apply_partition,
                                 fleet_summary, make_fleet_rollout,
                                 zipf_partition)
from repro.serving.topology import nearest_alive_edge

__all__ = ["CentralController", "SchedulerChoice", "MultiEdgeSim", "SimConfig",
           "SimEdge", "nearest_alive_edge",
           "EngineConfig", "init_state", "init_batch", "step_round",
           "make_rollout", "summarize", "summarize_partials",
           "partials_to_summary", "local_assign", "greedy_assign",
           "make_policy_assign", "ASSIGN_FNS", "resolve_assign_fn",
           "FleetPartition", "zipf_partition", "apply_partition",
           "make_fleet_rollout", "fleet_summary",
           "DecisionFastPath", "SLOSpec", "DEFAULT_BUCKETS", "evaluate_slo",
           "pad_instance"]
