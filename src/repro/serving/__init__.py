from repro.serving.controller import CentralController, SchedulerChoice
from repro.serving.simulator import MultiEdgeSim, SimConfig
from repro.serving.edge import SimEdge
from repro.serving.engine import (ASSIGN_FNS, EngineConfig, greedy_assign,
                                  init_batch, init_state, local_assign,
                                  make_policy_assign, make_rollout,
                                  resolve_assign_fn, step_round, summarize)
from repro.serving.fastpath import (DEFAULT_BUCKETS, DecisionFastPath,
                                    SLOSpec, evaluate_slo, pad_instance)
from repro.serving.topology import nearest_alive_edge

__all__ = ["CentralController", "SchedulerChoice", "MultiEdgeSim", "SimConfig",
           "SimEdge", "nearest_alive_edge",
           "EngineConfig", "init_state", "init_batch", "step_round",
           "make_rollout", "summarize", "local_assign", "greedy_assign",
           "make_policy_assign", "ASSIGN_FNS", "resolve_assign_fn",
           "DecisionFastPath", "SLOSpec", "DEFAULT_BUCKETS", "evaluate_slo",
           "pad_instance"]
