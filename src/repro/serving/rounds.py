"""Round semantics shared by the two serving engines.

The event-driven oracle (:class:`repro.serving.simulator.MultiEdgeSim`) and
the array-native batched engine (:mod:`repro.serving.engine`) implement the
same physical model; this module is the single home for the pieces both
must agree on bit-for-bit:

* :func:`sample_cluster` — the cluster prior (coords, distances, hidden phi
  coefficients, replica counts) with a *pinned rng call order*, so the two
  engines built from the same seed simulate the same cluster.
* :func:`transfer_delay` / :func:`exec_time` / :func:`service_runtime` —
  eq (2)'s transmission cost and the affine service model with the
  straggler speed factor and the runtime floor.

The lane model itself (zeta parallel replicas, work-conserving FIFO by
data-ready time) is what makes the engines equivalent: a request's start
time is ``max(ready, earliest lane free)`` processed in ready order. The
oracle realizes it with heap events and cascading ``start_executable``
calls; the engine realizes it with a ``lax.scan`` over slots sorted by
ready time (it mirrors :func:`service_runtime` in jnp — constants here are
the contract, pinned by a cross-engine test).
"""
from __future__ import annotations

import dataclasses

import numpy as np

#: Execution times are floored here so a zero-size request still occupies a
#: replica lane for a nonzero interval (keeps the event heap ordered).
MIN_RUNTIME = 1e-6

#: Straggler jitter multipliers are floored here (a noisy draw may not make
#: an edge more than 10x faster than its mean).
MIN_JITTER = 0.1


@dataclasses.dataclass(frozen=True)
class ClusterParams:
    """One sampled cluster: everything both engines derive from the seed."""

    coords: np.ndarray      # (Q, 2) edge positions, U(0,1)^2
    w: np.ndarray           # (Q, Q) pairwise transmission distances
    true_a: np.ndarray      # (Q,) hidden phi slope per edge
    true_b: np.ndarray      # (Q,) hidden phi intercept per edge
    replicas: np.ndarray    # (Q,) int service replica count zeta


def sample_cluster(num_edges: int, replicas_high: int, phi_low: float,
                   phi_high: float, seed: int) -> ClusterParams:
    """Sample the cluster exactly as the seed simulator always has.

    The rng call order (coords first, then per-edge a/b/replicas) is part
    of the contract: both engines call this, so a given seed names one
    cluster everywhere.
    """
    rng = np.random.default_rng(seed)
    coords = rng.uniform(0, 1, size=(num_edges, 2))
    w = np.linalg.norm(coords[:, None] - coords[None, :], axis=-1)
    true_a = np.zeros(num_edges)
    true_b = np.zeros(num_edges)
    replicas = np.zeros(num_edges, np.int64)
    for i in range(num_edges):
        true_a[i] = rng.uniform(phi_low, phi_high)
        true_b[i] = rng.uniform(0.0, 0.1)
        replicas[i] = rng.integers(1, replicas_high + 1)
    return ClusterParams(coords=coords, w=w, true_a=true_a, true_b=true_b,
                         replicas=replicas)


def transfer_delay(ct: float, size, dist):
    """Eq (2): moving ``size`` over distance ``dist`` costs ct * size * dist."""
    return ct * size * dist


def exec_time(a, b, size):
    """The affine service model phi(x) = a x + b (paper §III-C1)."""
    return a * size + b


def service_runtime(a, b, size, speed: float = 1.0, jitter: float = 1.0,
                    warmup: float = 0.0):
    """Realized lane occupancy of one request: the affine mean, scaled by
    the straggler ``speed`` factor and a noise ``jitter`` multiplier (both
    1.0 in the deterministic engine), plus an additive ``warmup`` (the
    service-cache miss penalty — a cache-aside pull happens once, so it is
    not scaled by speed or jitter), floored at :data:`MIN_RUNTIME`."""
    return np.maximum(
        MIN_RUNTIME,
        exec_time(a, b, size) * np.maximum(jitter, MIN_JITTER) * speed + warmup,
    )


def extend_cluster_with_cloud(cluster: ClusterParams, cloud) -> ClusterParams:
    """Append the cloud tier as one extra node row (index Q) to a sampled
    cluster: transmission distance ``cloud.wan_dist`` from every edge (the
    size-proportional WAN bandwidth term; the fixed ``wan_rtt`` is additive
    per-destination delay and lives outside ``w`` — see
    :class:`repro.serving.topology.CloudSpec`), its own phi line, and
    ``cloud.lanes`` elastic service lanes. Both engines call this with the
    same spec, so (seed, CloudSpec) names one tiered cluster everywhere."""
    q = cluster.w.shape[0]
    w = np.zeros((q + 1, q + 1), cluster.w.dtype)
    w[:q, :q] = cluster.w
    w[:q, q] = w[q, :q] = cloud.wan_dist
    return ClusterParams(
        coords=np.concatenate(
            [cluster.coords, np.asarray([cloud.coords], cluster.coords.dtype)]),
        w=w,
        true_a=np.concatenate([cluster.true_a, [cloud.phi_a]]),
        true_b=np.concatenate([cluster.true_b, [cloud.phi_b]]),
        replicas=np.concatenate([cluster.replicas, [cloud.lanes]]),
    )
