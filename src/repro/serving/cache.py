"""Per-node service caches for the edge–cloud tier (schema v3).

Every edge keeps a fixed number of *service slots* (model weights, container
images, feature stores — whatever a service needs resident to run fast). A
request dispatched to a node whose cache holds its ``service`` id runs at
the nominal phi runtime; a miss triggers a cache-aside pull that adds
``miss_penalty`` seconds of warm-up to that request's runtime *and* installs
the service in the node's cache (FIFO eviction), so the next request for the
same service hits. The cloud tier caches everything — a cloud dispatch is
always a hit (its elastic backing store is the origin the edges pull from).

One semantics, two implementations, equivalence-tested against each other:

* :func:`cache_commit` — pure jnp ``lax.scan`` over one round's scheduled
  arrivals in slot (== rid) order, run inside the array engine's ``commit``
  (the cache tensors live in the SimState pytree, fixed shapes (N, slots)).
* :class:`HostCache` — the event-driven oracle's mirror, accessed request
  by request in the same rid order by ``MultiEdgeSim._round``.

Both process a round's dispatch decisions sequentially in global arrival
(rid) order, which makes hit/miss outcomes — including two same-service
misses in one round, where the second becomes a hit — deterministic and
identical across engines.

FIFO (not LRU) eviction is deliberate: hits don't reorder state, so cache
contents depend only on the *miss sequence*, which keeps the array scan
O(1)-state and the equivalence argument simple.
"""
from __future__ import annotations

import dataclasses

import numpy as np

CACHE_EMPTY = -1


@dataclasses.dataclass(frozen=True)
class CacheSpec:
    """Service-cache law shared by both engines.

    slots         per-edge cache capacity (service ids resident at once).
    miss_penalty  seconds of cache-aside warm-up added to the runtime of
                  the request that misses (the service pull).
    num_services  size of the service-id universe (drives warm placement
                  and the policy's cache-locality features).
    warm          deterministically pre-place services edge-by-edge
                  (edge e starts holding services (e + j) % num_services,
                  j < slots) so locality structure exists from round 0;
                  False starts every edge cold.
    """

    slots: int = 2
    miss_penalty: float = 0.5
    num_services: int = 8
    warm: bool = True


def initial_cache(num_nodes: int, num_edges: int,
                  spec: CacheSpec) -> np.ndarray:
    """(num_nodes, slots) int32 initial cache contents (CACHE_EMPTY = free).
    Rows past ``num_edges`` (the cloud) stay empty — the cloud is an
    always-hit by convention, its row is never consulted."""
    cache = np.full((num_nodes, spec.slots), CACHE_EMPTY, np.int32)
    if spec.warm:
        for e in range(num_edges):
            for j in range(spec.slots):
                cache[e, j] = (e + j) % max(1, spec.num_services)
    return cache


def cache_commit(cache, ptr, assign, service, on, num_edges: int):
    """One round's cache pass, array-native: scan the round's arrivals in
    slot (== rid) order, looking up and cache-aside-installing each.

    cache   (N, C) int32   per-node resident service ids
    ptr     (N,)   int32   per-node FIFO insertion cursor
    assign  (A,)   int32   dispatch decision per arrival
    service (A,)   int32   service id per arrival
    on      (A,)   bool    real, scheduled arrivals (mask & admitted)
    Returns (cache, ptr, hit) with hit (A,) bool (False wherever ``on``
    is False). Cloud nodes (index >= num_edges) always hit, never install.
    """
    import jax.numpy as jnp
    from jax import lax

    slots = cache.shape[-1]

    def body(carry, x):
        cache, ptr = carry
        node, svc, active = x
        is_cloud = node >= num_edges
        hit = jnp.any(cache[node] == svc) | is_cloud
        install = active & ~hit
        slot = ptr[node]
        cache = cache.at[node, slot].set(
            jnp.where(install, svc, cache[node, slot]))
        ptr = ptr.at[node].set(
            jnp.where(install, (slot + 1) % slots, slot))
        return (cache, ptr), hit & active

    (cache, ptr), hit = lax.scan(
        body, (cache, ptr),
        (assign.astype(jnp.int32), service.astype(jnp.int32), on))
    return cache, ptr, hit


class HostCache:
    """The event-driven oracle's cache mirror: same FIFO cache-aside
    semantics as :func:`cache_commit`, accessed one request at a time (the
    simulator sorts each round's decisions by rid first). Tracks aggregate
    hit/miss counts for ``MultiEdgeSim.metrics``."""

    def __init__(self, num_nodes: int, num_edges: int, spec: CacheSpec):
        self.spec = spec
        self.num_edges = int(num_edges)
        self.cache = initial_cache(num_nodes, num_edges, spec)
        self.ptr = np.zeros(num_nodes, np.int64)
        self.hits = 0
        self.misses = 0

    def access(self, node: int, service: int) -> bool:
        """Look up (and on miss, install) ``service`` at ``node``; returns
        True on a hit. The caller charges ``spec.miss_penalty`` runtime
        warm-up on False."""
        node = int(node)
        if node >= self.num_edges or service in self.cache[node]:
            self.hits += 1
            return True
        self.misses += 1
        self.cache[node, self.ptr[node]] = service
        self.ptr[node] = (self.ptr[node] + 1) % self.spec.slots
        return False

    def hit_fraction(self, node: int, services) -> float:
        """Fraction of ``services`` resident at ``node`` right now (no
        state change) — the oracle twin of the engine's per-edge
        cache-locality feature."""
        if len(services) == 0:
            return 0.0
        if node >= self.num_edges:
            return 1.0
        row = self.cache[int(node)]
        return float(np.mean([s in row for s in services]))
