"""Continuous batching of dispatched requests into a real LM backend.

``LMEdgeBackend`` runs an actual (reduced-config) model on this host:
prefill on admission, then decode steps over the active batch, admitting
queued requests into free lanes between steps (vLLM-style continuous
batching, TPU-friendly fixed batch shape). Measured (prompt_tokens,
latency) pairs feed the edge's PhiEstimator — the live demonstration that
LM serving is an *ideal service* in the paper's sense (runtime affine in
input size), closing the loop between the serving substrate and the
paper's state-evaluation model. Used by examples/serve_multi_edge.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.state import PhiEstimator
from repro.models import lm


@dataclasses.dataclass
class LaneState:
    rid: int = -1
    remaining: int = 0
    generated: int = 0


class LMEdgeBackend:
    """One edge's model server: ``lanes`` concurrent sequences (the edge's
    service-replica count), fixed max_seq ring cache per lane."""

    def __init__(self, cfg: ModelConfig, params, lanes: int = 4,
                 max_seq: int = 128, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.lanes = lanes
        self.max_seq = max_seq
        self.phi = PhiEstimator()
        self._lane_states = [LaneState() for _ in range(lanes)]
        self._queue: list[tuple[int, np.ndarray, int]] = []  # rid, prompt, gen_len
        self._rng = np.random.default_rng(seed)
        self.finished: dict[int, int] = {}  # rid -> generated tokens

        self._cache = lm.init_cache(cfg, lanes, max_seq)
        self._tokens = jnp.zeros((lanes,), jnp.int32)

        def _decode(params, cache, token):
            return lm.decode_step(params, cache, {"token": token}, cfg, 1)

        self._decode = jax.jit(_decode, donate_argnums=(1,))

        def _prefill_one(params, tokens):
            return lm.prefill(params, {"tokens": tokens}, cfg, 1,
                              max_seq=max_seq)

        self._prefill = jax.jit(_prefill_one)

    # -- admission --------------------------------------------------------

    def submit(self, rid: int, prompt_len: int, gen_len: int) -> None:
        prompt = self._rng.integers(
            0, self.cfg.vocab_size, size=(1, max(prompt_len, 2))).astype(np.int32)
        self._queue.append((rid, prompt, gen_len))

    def _admit(self) -> None:
        for lane, st in enumerate(self._lane_states):
            if st.remaining > 0 or not self._queue:
                continue
            rid, prompt, gen_len = self._queue.pop(0)
            t0 = time.perf_counter()
            cache1, logits = self._prefill(self.params, jnp.asarray(prompt))
            jax.block_until_ready(logits)
            dt = time.perf_counter() - t0
            self.phi.observe(prompt.shape[1], dt)  # ideal-service fit
            # splice lane 'lane' of the batch cache from the single-seq cache
            self._cache = _splice_cache(self._cache, cache1, lane)
            self._tokens = self._tokens.at[lane].set(
                int(jnp.argmax(logits[0])) % self.cfg.vocab_size)
            self._lane_states[lane] = LaneState(rid=rid, remaining=gen_len)

    # -- decode loop --------------------------------------------------------

    def step(self) -> int:
        """Admit + one decode step over the whole batch. Returns #active."""
        self._admit()
        active = [i for i, s in enumerate(self._lane_states) if s.remaining > 0]
        if not active:
            return 0
        self._cache, logits = self._decode(self.params, self._cache, self._tokens)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._tokens = jnp.where(
            jnp.asarray([s.remaining > 0 for s in self._lane_states]),
            nxt % self.cfg.vocab_size, self._tokens)
        for i in active:
            st = self._lane_states[i]
            st.remaining -= 1
            st.generated += 1
            if st.remaining == 0:
                self.finished[st.rid] = st.generated
                self._lane_states[i] = LaneState()
        return len(active)

    def drain(self, max_steps: int = 10_000) -> None:
        steps = 0
        while (self._queue or any(s.remaining for s in self._lane_states)) \
                and steps < max_steps:
            self.step()
            steps += 1


def _splice_cache(batch_cache, one_cache, lane: int):
    """Insert a single-sequence cache into lane ``lane`` of a batched cache.

    Handles differing sequence capacity (pads/crops the window axis)."""
    out = dict(batch_cache)
    out["pos"] = batch_cache["pos"].at[lane].set(one_cache["pos"][0])
    if "slot_pos" in batch_cache:
        w_b = batch_cache["slot_pos"].shape[1]
        sp = _fit_axis(one_cache["slot_pos"], w_b, axis=1, fill=-1)
        out["slot_pos"] = batch_cache["slot_pos"].at[lane].set(sp[0])
    lay = dict(batch_cache["layers"])
    for k_ in batch_cache["layers"]:
        b = batch_cache["layers"][k_]
        o = one_cache["layers"][k_]
        if k_ in ("k", "v"):
            o = _fit_axis(o, b.shape[2], axis=2, fill=0)
        lay[k_] = b.at[:, lane].set(o[:, 0])
    out["layers"] = lay
    if "enc_out" in batch_cache:
        out["enc_out"] = batch_cache["enc_out"].at[lane].set(one_cache["enc_out"][0])
    return out


def _fit_axis(x, size: int, axis: int, fill=0):
    cur = x.shape[axis]
    if cur == size:
        return x
    if cur > size:
        idx = [slice(None)] * x.ndim
        idx[axis] = slice(cur - size, cur)
        return x[tuple(idx)]
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, size - cur)
    return jnp.pad(x, pad, constant_values=fill)
