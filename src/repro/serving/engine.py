"""Array-native batched rollout engine for multi-edge cooperative serving.

The struct-of-arrays twin of :class:`repro.serving.simulator.MultiEdgeSim`:
the whole serving system lives in one fixed-shape ``SimState`` pytree (a
plain dict, like every instance pytree in this repo) and one pure
``step_round`` transition, so rollouts are `jit`-able end to end and
`vmap`-able over an instance axis — hundreds of scenario instances roll
forward in parallel on device. The event-driven simulator remains the
correctness oracle; a trace-driven equivalence test pins the two engines to
each other (tests/test_engine.py).

Why the engines agree: the oracle's replica-lane model is a
work-conserving FIFO-by-ready-time multi-server queue — a request's start
time is ``max(data_ready, earliest free lane)``, with requests claiming
lanes in the order their data arrives. Once a request's ready time has
passed, no later-scheduled request can be ahead of it in that order (new
commits always become ready at or after the current round). The engine
exploits this: each round it *finalizes* the start/finish of every slot
whose computed start time has arrived via a ``lax.scan`` lane recursion in
ready order, and leaves in-transfer and still-queued slots open. That is
exactly the schedule the event heap would produce, without events.
Finalization is deferred to the window a start actually falls in (rather
than eagerly booking future starts): within one edge, start times are
nondecreasing along the ready-order scan — a deferred slot only postpones
a per-edge suffix, so deferral never changes the schedule, and it is what
makes mid-rollout faults tractable (an edge failure must be able to orphan
every not-yet-finished slot without unwinding lane state).

Faults (``repro.resilience``): when the arrival batch carries materialized
fault rows (``alive``/``speed``/``jitter`` from
``resilience.faults.attach_faults``), ``step_round`` switches into fault
mode — row r is applied at the round-r scheduling instant, orphans on
newly-dead edges are re-admitted at the nearest alive edge (the oracle's
failover rule), and arrivals are source-remapped exactly like the oracle's
two-step admission (arrival-time failover under the previous round's
liveness, then fail-event re-admission under the new one). An optional
:class:`repro.resilience.ResilienceConfig` on the engine config adds
admission control (heuristic or policy-supplied), circuit breaking with
half-open probes, and retry backoff on top.

State layout (Q edges, L = replicas_high lanes, Z = num_rounds *
max_per_round request slots; all leaves fixed-shape, so a leading batch
axis vmaps):

    coords (Q,2)  w (Q,Q)  phi_true (Q,2)  phi_est (Q,2)  replicas (Q,)
    speed (Q,)  ct ()  t ()  round () i32  completed () i32
    lane_free (Q,L)                       INF beyond an edge's zeta lanes
    slot_size/src/edge/submit/ready/start/finish (Z,)   edge=-1 => empty
    slot_jitter/slot_retries (Z,)         fault-mode runtime noise / retries
    alive (Q,)  breaker_open/trips/healthy (Q,)   fault + breaker state
    shed/dropped/retried () i32           admission & overflow accounting
    phi_n/sx/sy/sxx/sxy (Q,)              running LSQ sums (learn_phi mode)

Deliberate deviations from the oracle (documented, not bugs): execution is
deterministic unless fault-mode jitter is injected (the oracle's
``exec_noise`` models measurement jitter; pin the oracle with
``exec_noise=0``), and online phi fitting uses running sums over the whole
rollout rather than a sliding window.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.inference import make_policy_assign, make_policy_assign_fused
from repro.core.objective import makespan
from repro.core.state import slot_workload_features
from repro.resilience.policies import (ResilienceConfig, admission_mask,
                                       breaker_step, dispatch_mask,
                                       nearest_alive, probe_cap)
from repro.serving import rounds
from repro.serving.cache import CACHE_EMPTY, CacheSpec, cache_commit, initial_cache
from repro.serving.topology import CloudSpec

#: Sentinel for "never" (empty lane slots, un-ready/un-started requests).
INF = 1e30
#: Horizon passed to :func:`advance` to drain every committed request.
DRAIN_HORIZON = 1e7
#: Ready-time nudge for retried orphans: in the oracle, a fail event's
#: re-admissions join the pool after the window's fresh arrivals, so engine
#: retries must sort after same-instant fresh local commits in the ready
#: order (large enough to survive float32 rounding at rollout timescales).
RETRY_EPS = 1e-6
#: Deadline-slack cap (seconds) for the policy's per-request slack feature:
#: requests with no deadline (slot_deadline == INF) saturate here instead
#: of feeding INF into the encoder.
SLACK_CAP = 8.0

#: assign_fn(key, instance) -> (A,) int32 execution-edge per pending
#: request, or an (assign, admit) tuple when the policy also decides
#: admission (see core.inference.make_policy_assign(admission=True)).
AssignFn = Callable[[jax.Array, dict], jax.Array]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static shape/physics parameters of a batched rollout.

    Field names follow :class:`repro.serving.simulator.SimConfig` where the
    two overlap, and :func:`init_state` draws the cluster through the same
    ``rounds.sample_cluster``, so (cfg, seed) names the same cluster in both
    engines."""

    num_edges: int = 5
    replicas_high: int = 4
    ct: float = 1.0
    round_interval: float = 0.25
    phi_low: float = 0.2
    phi_high: float = 1.0
    num_rounds: int = 12           # scheduling rounds (slot table rows)
    max_per_round: int = 16        # padded arrivals per round (slot cols)
    learn_phi: bool = False        # online phi fitting vs oracle phi_true
    phi_min_samples: int = 8
    resilience: Optional[ResilienceConfig] = None
    # Edge–cloud tier: with ``cloud`` set, one extra node (index num_edges)
    # is appended to every per-node array — elastic lanes, its own phi line,
    # WAN transfer law (rounds.extend_cluster_with_cloud). ``cache`` gives
    # every *edge* a fixed-slot service cache (serving/cache.py); a miss
    # adds ``cache.miss_penalty`` warm-up to that request's runtime. Both
    # default off, so flat single-tier configs are unchanged.
    cloud: Optional[CloudSpec] = None
    cache: Optional[CacheSpec] = None

    @property
    def num_nodes(self) -> int:
        """Dispatchable nodes: the edges plus the cloud row when tiered."""
        return self.num_edges + (1 if self.cloud is not None else 0)

    @property
    def lane_width(self) -> int:
        """Lane-table width L: the cloud's elastic lanes may exceed
        ``replicas_high``."""
        return max(self.replicas_high,
                   self.cloud.lanes if self.cloud is not None else 0)

    @property
    def num_slots(self) -> int:
        return self.num_rounds * self.max_per_round

    @property
    def until(self) -> float:
        """Arrival horizon covered by the slot table."""
        return self.num_rounds * self.round_interval


def init_state(cfg: EngineConfig, seed: int = 0) -> dict:
    """Fresh SimState for one instance (numpy leaves; jit converts).

    With ``cfg.cloud`` the per-node axis is ``num_nodes = num_edges + 1``:
    the cloud row carries its WAN rtt in ``rtt``, ``tier`` 1, elastic lanes,
    and is always alive. The cache tensors (``cache``/``cache_ptr``) and
    schema-v3 slot columns (service / deadline / priority / warm-up
    penalty) are always present so the pytree structure is config-stable
    for sharding specs; without ``cfg.cache`` they stay inert."""
    q, n, z = cfg.num_edges, cfg.num_nodes, cfg.num_slots
    cluster = rounds.sample_cluster(q, cfg.replicas_high, cfg.phi_low,
                                    cfg.phi_high, seed)
    if cfg.cloud is not None:
        cluster = rounds.extend_cluster_with_cloud(cluster, cfg.cloud)
    phi_true = np.stack([cluster.true_a, cluster.true_b], -1).astype(np.float32)
    lane_free = np.where(
        np.arange(cfg.lane_width)[None, :] < cluster.replicas[:, None],
        0.0, INF).astype(np.float32)
    rtt = np.zeros(n, np.float32)
    tier = np.zeros(n, np.float32)
    if cfg.cloud is not None:
        rtt[q:] = cfg.cloud.wan_rtt
        tier[q:] = 1.0
    cache = (initial_cache(n, q, cfg.cache) if cfg.cache is not None
             else np.full((n, 1), CACHE_EMPTY, np.int32))
    return {
        "coords": cluster.coords.astype(np.float32),
        "w": cluster.w.astype(np.float32),
        "phi_true": phi_true,
        "phi_est": (np.tile(np.float32([1.0, 0.0]), (n, 1))
                    if cfg.learn_phi else phi_true.copy()),
        "replicas": cluster.replicas.astype(np.float32),
        "speed": np.ones(n, np.float32),
        "ct": np.float32(cfg.ct),
        "t": np.float32(0.0),
        "round": np.int32(0),
        "completed": np.int32(0),
        "lane_free": lane_free,
        "rtt": rtt,
        "tier": tier,
        "cache": cache,
        "cache_ptr": np.zeros(n, np.int32),
        "cache_hits": np.int32(0),
        "cache_misses": np.int32(0),
        "slot_size": np.zeros(z, np.float32),
        "slot_src": np.zeros(z, np.int32),
        "slot_edge": np.full(z, -1, np.int32),
        "slot_submit": np.zeros(z, np.float32),
        "slot_ready": np.full(z, INF, np.float32),
        "slot_start": np.full(z, INF, np.float32),
        "slot_finish": np.full(z, INF, np.float32),
        "slot_jitter": np.ones(z, np.float32),
        "slot_retries": np.zeros(z, np.float32),
        "slot_service": np.zeros(z, np.int32),
        "slot_deadline": np.full(z, INF, np.float32),
        "slot_priority": np.zeros(z, np.float32),
        "slot_penalty": np.zeros(z, np.float32),
        "alive": np.ones(n, np.float32),
        "breaker_open": np.full(n, -1.0, np.float32),
        "breaker_trips": np.zeros(n, np.float32),
        "breaker_healthy": np.zeros(n, np.float32),
        "shed": np.int32(0),
        "dropped": np.int32(0),
        "retried": np.int32(0),
        "phi_n": np.zeros(n, np.float32),
        "phi_sx": np.zeros(n, np.float32),
        "phi_sy": np.zeros(n, np.float32),
        "phi_sxx": np.zeros(n, np.float32),
        "phi_sxy": np.zeros(n, np.float32),
    }


def init_batch(cfg: EngineConfig, seeds) -> dict:
    """Stack per-seed states into one pytree with a leading batch axis."""
    states = [init_state(cfg, int(s)) for s in seeds]
    return {k: np.stack([s[k] for s in states]) for k in states[0]}


# ---------------------------------------------------------------------------
# transition pieces (pure; compose into step_round / rollout)
# ---------------------------------------------------------------------------


def stable_order(keys):
    """Stable ascending argsort of 1-D ``keys`` without an XLA sort op.

    The lane scans below consume the permutation as scan xs only, which
    leaves the sort's key output dead. Under a jitted ``shard_map`` (the
    fleet rollout) the SPMD partitioner then rewrites that sort into
    ``select(partition_id == 0, keys, 0)`` + all-reduce before sorting —
    every shard silently schedules with shard 0's keys. Rank-by-pairwise-
    comparison has no sort op to mis-partition and is bit-identical to
    ``jnp.argsort`` (stable: ties resolve toward the lower index); the n^2
    comparisons are noise next to the O(n) sequential scan that consumes
    the order."""
    n = keys.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    before = (keys[None, :] < keys[:, None]) | (
        (keys[None, :] == keys[:, None]) & (idx[None, :] < idx[:, None]))
    rank = jnp.sum(before, axis=1)  # permutation: rank[i] = sorted position
    return jnp.zeros(n, jnp.int32).at[rank].set(idx)


def advance(state: dict, t_new, cfg: EngineConfig) -> dict:
    """Move time forward to ``t_new``: finalize the lane schedule of every
    slot whose start time arrives by ``t_new`` (ready order; mirrors the
    oracle's FIFO lane recursion — see module docstring) and book
    completions. A slot whose computed start would land past ``t_new`` is
    left open and re-derived next round — within one edge, starts are
    nondecreasing along the ready-order scan, so deferral postpones a
    per-edge suffix without changing the schedule (and keeps lane state
    clean if a fault orphans the slot first)."""
    startable = ((state["slot_edge"] >= 0) & (state["slot_start"] > INF / 2)
                 & (state["slot_ready"] <= t_new))
    keys = jnp.where(startable, state["slot_ready"], INF)
    order = stable_order(keys)  # stable: ties resolve in slot (arrival) order

    def body(carry, idx):
        lane_free, start, finish, psums = carry
        e = jnp.clip(state["slot_edge"][idx], 0, cfg.num_nodes - 1)
        lanes = lane_free[e]
        lane = jnp.argmin(lanes)
        st = jnp.maximum(state["slot_ready"][idx], lanes[lane])
        ok = (keys[idx] < INF / 2) & (st <= t_new)
        size = state["slot_size"][idx]
        # jnp mirror of rounds.service_runtime (incl. cache-miss warm-up)
        rt = jnp.maximum(
            rounds.MIN_RUNTIME,
            (state["phi_true"][e, 0] * size + state["phi_true"][e, 1])
            * jnp.maximum(state["slot_jitter"][idx], rounds.MIN_JITTER)
            * state["speed"][e]
            + state["slot_penalty"][idx],
        )
        fin = st + rt
        lane_free = lane_free.at[e, lane].set(jnp.where(ok, fin, lanes[lane]))
        start = start.at[idx].set(jnp.where(ok, st, start[idx]))
        finish = finish.at[idx].set(jnp.where(ok, fin, finish[idx]))
        if cfg.learn_phi:  # observe (size, runtime) at start, like the oracle
            n, sx, sy, sxx, sxy = psums
            g = ok.astype(jnp.float32)
            psums = (n.at[e].add(g), sx.at[e].add(g * size),
                     sy.at[e].add(g * rt), sxx.at[e].add(g * size * size),
                     sxy.at[e].add(g * size * rt))
        return (lane_free, start, finish, psums), None

    psums = (state["phi_n"], state["phi_sx"], state["phi_sy"],
             state["phi_sxx"], state["phi_sxy"])
    carry = (state["lane_free"], state["slot_start"], state["slot_finish"],
             psums)
    (lane_free, start, finish, psums), _ = jax.lax.scan(body, carry, order)

    out = dict(state)
    out["lane_free"] = lane_free
    out["slot_start"] = start
    out["slot_finish"] = finish
    out["t"] = jnp.asarray(t_new, jnp.float32)
    out["completed"] = jnp.sum(finish <= t_new).astype(jnp.int32)
    if cfg.learn_phi:
        n, sx, sy, sxx, sxy = psums
        out["phi_n"], out["phi_sx"], out["phi_sy"] = n, sx, sy
        out["phi_sxx"], out["phi_sxy"] = sxx, sxy
        nn = jnp.maximum(n, 1.0)
        var = sxx / nn - jnp.square(sx / nn)
        denom = sxx - jnp.square(sx) / nn
        a = (sxy - sx * sy / nn) / jnp.where(denom == 0, 1.0, denom)
        b = (sy - a * sx) / nn
        valid = ((n >= cfg.phi_min_samples) & (var > 1e-12) & (a > 0)
                 & jnp.isfinite(a) & jnp.isfinite(b))
        est = jnp.stack([a, jnp.maximum(b, 0.0)], -1)
        out["phi_est"] = jnp.where(valid[:, None], est, state["phi_est"])
    return out


def apply_faults(state: dict, arr: dict, cfg: EngineConfig) -> dict:
    """Apply this round's fault row (``arr["alive"]``/``arr["speed"]``) at
    the current scheduling instant — the array twin of the oracle's
    fail/recover/straggle events firing just before the CC round.

    A newly-dead edge loses its lanes and orphans every not-yet-finished
    slot (queued, in transfer, or mid-execution — the oracle's
    ``SimEdge.fail``); orphans are re-admitted as local retries at the
    nearest alive edge with a small ready-time nudge (re-admissions sort
    after the window's fresh arrivals, as in the event heap). A recovered
    edge gets fresh lanes at the current time."""
    res = cfg.resilience
    t = state["t"]
    prev_alive = state["alive"] > 0
    alive = arr["alive"] > 0
    died = prev_alive & ~alive
    recovered = ~prev_alive & alive

    out = dict(state)
    out["alive"] = alive.astype(jnp.float32)
    out["speed"] = arr["speed"].astype(jnp.float32)
    lanes = jnp.arange(state["lane_free"].shape[-1])[None, :]
    fresh = jnp.where(lanes < state["replicas"][:, None], t, INF)
    lane_free = jnp.where(died[:, None], INF, state["lane_free"])
    out["lane_free"] = jnp.where(recovered[:, None], fresh, lane_free)

    e = jnp.clip(state["slot_edge"], 0, cfg.num_nodes - 1)
    orphan = ((state["slot_edge"] >= 0) & died[e]
              & (state["slot_finish"] > t))
    retries = state["slot_retries"] + orphan
    new_src = nearest_alive(state["w"], alive,
                            jnp.clip(state["slot_src"], 0, cfg.num_nodes - 1))
    backoff = 0.0
    if res is not None and res.retry_backoff_rounds:
        backoff = (res.retry_backoff_rounds * cfg.round_interval
                   * jnp.exp2(jnp.clip(retries - 1.0, 0.0,
                                       float(res.retry_backoff_cap))))
    out["slot_src"] = jnp.where(orphan, new_src, state["slot_src"])
    out["slot_edge"] = jnp.where(orphan, new_src, state["slot_edge"])
    out["slot_ready"] = jnp.where(orphan, t + RETRY_EPS + backoff,
                                  state["slot_ready"])
    out["slot_start"] = jnp.where(orphan, INF, state["slot_start"])
    out["slot_finish"] = jnp.where(orphan, INF, state["slot_finish"])
    out["slot_retries"] = retries.astype(jnp.float32)
    out["retried"] = state["retried"] + jnp.sum(orphan).astype(jnp.int32)
    if res is not None and res.breaker:
        (out["breaker_open"], out["breaker_trips"],
         out["breaker_healthy"]) = breaker_step(
            state["breaker_open"], state["breaker_trips"],
            state["breaker_healthy"], died, alive, t,
            cfg.round_interval, res)
    return out


def dispatchable_edges(state: dict, cfg: EngineConfig):
    """(Q,) bool dispatch eligibility: alive edges, minus open circuit
    breakers when breaking is enabled (all ones in the fault-free world)."""
    alive = state["alive"] > 0
    res = cfg.resilience
    if res is not None and res.breaker:
        return dispatch_mask(alive, state["breaker_open"], state["t"])
    return alive


def round_instance(state: dict, arr: dict, cfg: EngineConfig) -> dict:
    """Freeze (state, this round's arrivals) into a scheduling instance with
    the same layout as core.instances/core.state.snapshot_instance, so the
    policy, the heuristics, and the objective all run on it unchanged.

    Tier/schema-v3 extras (consumed only by a policy configured with
    ``tier_features``; heuristics and the objective ignore them): ``tier``
    (per-node cloud flag), ``cache_frac`` (fraction of this round's
    services resident per node), ``req_slack`` (deadline slack capped at
    :data:`SLACK_CAP`), ``req_priority``, and ``req_cached`` (is the
    request's service resident at its source)."""
    wl = slot_workload_features(
        state["phi_est"], state["replicas"], state["w"], state["ct"],
        state["slot_size"], state["slot_src"], state["slot_edge"],
        state["slot_ready"], state["slot_start"], state["t"],
    )
    mask = arr["mask"]
    src = arr["src"].astype(jnp.int32)
    inst = {
        "edge_coords": state["coords"],
        "phi": state["phi_est"],
        "replicas": state["replicas"],
        "workload": wl,
        "w": state["w"],
        "ct": state["ct"],
        "req_src": src,
        "req_size": jnp.where(mask, arr["size"], 0.0),
        "edge_mask": dispatchable_edges(state, cfg),
        "req_mask": mask,
        "tier": state["tier"],
    }
    if "rid" in arr:  # pass-through for scripted/replay assign fns
        inst["req_rid"] = arr["rid"].astype(jnp.int32)
    if "deadline" in arr:
        slack = jnp.clip(arr["deadline"] - state["t"], 0.0, SLACK_CAP)
        inst["req_slack"] = jnp.where(mask, slack, 0.0).astype(jnp.float32)
    if "priority" in arr:
        inst["req_priority"] = jnp.where(
            mask, arr["priority"], 0.0).astype(jnp.float32)
    if cfg.cache is not None and "service" in arr:
        svc = arr["service"].astype(jnp.int32)
        # (N, A) residency now: cloud rows (tier 1) always hit
        res = jnp.any(state["cache"][:, :, None] == svc[None, None, :], axis=1)
        res = res | (state["tier"][:, None] > 0)
        mf = mask.astype(jnp.float32)
        inst["cache_frac"] = (jnp.sum(res * mf[None, :], -1)
                              / jnp.maximum(jnp.sum(mf), 1.0)).astype(jnp.float32)
        a_idx = jnp.arange(svc.shape[-1])
        inst["req_cached"] = (res[src, a_idx] & mask).astype(jnp.float32)
    return inst


def commit(state: dict, arr: dict, assign, cfg: EngineConfig,
           admit=None, ready_offset=None) -> dict:
    """Dispatch this round's arrivals (CC steps v-vi): write them into the
    round's slot row with their execution edge and data-ready time (local:
    now; remote: now + eq (2) transfer delay). ``admit`` is an optional
    (A,) bool admission mask — non-admitted arrivals are shed (never
    written to the slot table, counted in ``state["shed"]``).
    ``ready_offset`` is an optional (A,) per-arrival ready-time bump
    (fault mode: re-admitted arrivals sort after native fresh ones)."""
    a_cols = cfg.max_per_round
    if arr["size"].shape[-1] != a_cols:
        raise ValueError(
            f"arrival batch width {arr['size'].shape[-1]} != "
            f"cfg.max_per_round {a_cols}; slot-table rows would misalign "
            f"(materialize with max_per_round={a_cols}, or build the "
            f"EngineConfig from the materialized width)")
    assign = assign.astype(jnp.int32)
    src = arr["src"].astype(jnp.int32)
    mask = arr["mask"]
    sched = mask if admit is None else mask & admit
    size = jnp.where(mask, arr["size"], 0.0).astype(jnp.float32)
    exec_node = jnp.clip(assign, 0, cfg.num_nodes - 1)
    # eq (2) + per-destination additive delay (the cloud's WAN rtt; zero
    # for every edge destination, so the flat-tier ready law is unchanged)
    delay = (rounds.transfer_delay(state["ct"], size,
                                   state["w"][src, exec_node])
             + state["rtt"][exec_node])
    ready = state["t"] + jnp.where(assign == src, 0.0, delay)
    if ready_offset is not None:
        ready = ready + ready_offset
    base = state["round"] * a_cols

    def put(dst, vals):
        return jax.lax.dynamic_update_slice(dst, vals, (base,))

    out = dict(state)
    svc = (arr["service"].astype(jnp.int32) if "service" in arr
           else jnp.zeros_like(src))
    if cfg.cache is not None:
        # one sequential cache pass over the round's dispatches in slot
        # (== rid) order — the oracle's HostCache accesses in the same
        # order, so hit/miss outcomes are identical across engines
        cache, ptr, hit = cache_commit(state["cache"], state["cache_ptr"],
                                       exec_node, svc, sched, cfg.num_edges)
        miss = sched & ~hit
        out["cache"], out["cache_ptr"] = cache, ptr
        out["cache_hits"] = state["cache_hits"] + jnp.sum(hit).astype(jnp.int32)
        out["cache_misses"] = (state["cache_misses"]
                               + jnp.sum(miss).astype(jnp.int32))
        penalty = cfg.cache.miss_penalty * miss.astype(jnp.float32)
    else:
        penalty = jnp.zeros_like(size)
    out["slot_penalty"] = put(state["slot_penalty"], penalty)
    out["slot_service"] = put(state["slot_service"], svc)
    if "deadline" in arr:
        out["slot_deadline"] = put(state["slot_deadline"],
                                   arr["deadline"].astype(jnp.float32))
    if "priority" in arr:
        out["slot_priority"] = put(state["slot_priority"],
                                   arr["priority"].astype(jnp.float32))
    out["slot_size"] = put(state["slot_size"], size)
    out["slot_src"] = put(state["slot_src"], src)
    out["slot_edge"] = put(state["slot_edge"], jnp.where(sched, assign, -1))
    out["slot_submit"] = put(state["slot_submit"],
                             arr["t"].astype(jnp.float32))
    out["slot_ready"] = put(state["slot_ready"],
                            jnp.where(sched, ready, INF).astype(jnp.float32))
    if "jitter" in arr:
        out["slot_jitter"] = put(state["slot_jitter"],
                                 arr["jitter"].astype(jnp.float32))
    if admit is not None:
        out["shed"] = state["shed"] + jnp.sum(mask & ~admit).astype(jnp.int32)
    if "dropped" in arr:  # materializer overflow clips, per round
        out["dropped"] = state["dropped"] + arr["dropped"].astype(jnp.int32)
    out["round"] = state["round"] + 1
    return out


def step_round(state: dict, arr: dict, assign_fn: AssignFn,
               cfg: EngineConfig, key) -> tuple[dict, dict]:
    """One scheduling round (paper Fig. 2 iii-vi): advance the cluster one
    round interval, apply this round's fault row (if the arrival batch
    carries one), evaluate per-edge workload state, schedule this round's
    arrivals, apply admission control, dispatch. Returns (state, per-round
    info)."""
    res = cfg.resilience
    fault_mode = "alive" in arr
    ready_offset = None
    prev_completed = state["completed"]
    prev_shed, prev_retried = state["shed"], state["retried"]
    state = advance(state, state["t"] + cfg.round_interval, cfg)
    if fault_mode and cfg.cloud is not None:
        # materialized fault rows cover the edges; the cloud column is
        # always alive at nominal speed
        arr = dict(arr)
        pad = jnp.ones_like(arr["alive"][..., :1])
        arr["alive"] = jnp.concatenate([arr["alive"], pad], -1)
        arr["speed"] = jnp.concatenate([arr["speed"], pad], -1)
    if fault_mode:
        # two-step source failover, mirroring the oracle's admission path:
        # arrivals fail over under the liveness they arrived under, then a
        # fail event re-admits the dead edge's pool under the new row.
        # Arrivals caught by that second step were sitting in the dying
        # edge's queue when it failed — they re-enter the pool *after* the
        # surviving edges' native arrivals (rid order within the orphan
        # group matches, since committed orphans always have smaller rids).
        arr = dict(arr)
        arr["src"] = nearest_alive(state["w"], state["alive"] > 0,
                                   jnp.clip(arr["src"].astype(jnp.int32), 0,
                                            cfg.num_edges - 1))
        state = apply_faults(state, arr, cfg)
        readmitted = ~(state["alive"] > 0)[arr["src"]]
        ready_offset = RETRY_EPS * readmitted
        arr["src"] = nearest_alive(state["w"], state["alive"] > 0,
                                   arr["src"])
    inst = round_instance(state, arr, cfg)
    decision = assign_fn(key, inst)
    assign, admit = (decision if isinstance(decision, tuple)
                     else (decision, None))
    if fault_mode:
        # clamp any dispatch outside the eligible set to the nearest
        # eligible edge (policies see edge_mask, but must not be able to
        # resurrect a dead edge by emitting its index)
        assign = nearest_alive(state["w"], inst["edge_mask"],
                               jnp.clip(assign.astype(jnp.int32), 0,
                                        cfg.num_nodes - 1))
        if res is not None and res.breaker:
            half_open = ((state["alive"] > 0)
                         & (state["t"] >= state["breaker_open"])
                         & (state["breaker_trips"] > 0))
            closed = inst["edge_mask"] & ~half_open
            assign = probe_cap(state["w"], assign, arr["mask"],
                               arr["src"], half_open, closed, res)
    if admit is None and res is not None and res.admission != "none":
        admit = admission_mask(res, inst, assign)
    state = commit(state, arr, assign, cfg, admit=admit,
                   ready_offset=ready_offset)
    finish = state["slot_finish"]
    done = finish <= state["t"]
    info = {
        "t": state["t"],
        "features": inst["workload"],
        "assign": assign.astype(jnp.int32),
        "completed": state["completed"],
        "round_completions": state["completed"] - prev_completed,
        "round_shed": state["shed"] - prev_shed,
        "round_retries": state["retried"] - prev_retried,
        "makespan": jnp.max(jnp.where(done, finish, 0.0)),
    }
    return state, info


def make_rollout(cfg: EngineConfig, assign_fn: AssignFn, *,
                 batch: bool = False, drain_to: Optional[float] = DRAIN_HORIZON):
    """Build a jitted ``run(state, arrivals, key) -> (state, infos)``.

    ``arrivals`` is the padded per-round batch from
    :func:`repro.workloads.batch.materialize_rounds` — dict of (R, A) arrays
    (leading batch axis too when ``batch=True``, as produced by
    ``materialize_round_batch``; pass a (B,)-batch of states from
    :func:`init_batch` and a (B,) key array). ``drain_to`` runs a final
    :func:`advance` so in-flight work completes (None: leave it in flight).
    """

    def run(state, arrivals, key):
        num_rounds = arrivals["size"].shape[0]
        if num_rounds > cfg.num_rounds:
            raise ValueError(
                f"arrivals cover {num_rounds} rounds but the slot table "
                f"holds cfg.num_rounds={cfg.num_rounds}")

        def body(carry, arr):
            st, k = carry
            k, sub = jax.random.split(k)
            st, info = step_round(st, arr, assign_fn, cfg, sub)
            return (st, k), info

        (state, _), infos = jax.lax.scan(body, (state, key), arrivals)
        if drain_to is not None:
            state = advance(state, drain_to, cfg)
        return state, infos

    if batch:
        run = jax.vmap(run)
    return jax.jit(run)


#: The one summary schema (satellite of the edge–cloud API redesign).
#: Every summary producer in the serving stack —
#: :func:`summarize` (single/vmapped final states),
#: :func:`partials_to_summary` / :func:`repro.serving.fleet.fleet_summary`
#: (psum-reduced shard partials), and the event-driven oracle's
#: ``MultiEdgeSim.metrics()`` — returns exactly these keys (always present,
#: zero-valued defaults when no work completed), so benchmarks never
#: special-case which engine produced a row. ``slo`` / ``slo_violation_frac``
#: additionally appear when an SLO is given; the oracle adds its
#: ``decision_*`` wall-clock keys on top (the jitted engines cannot measure
#: per-decision time). Flat counts/floats only; ``per_edge_completed`` is
#: the one nested dict (node id -> completions).
SUMMARY_KEYS = (
    "completed", "submitted", "shed_requests", "dropped_requests",
    "stranded_requests", "retried_requests", "shed_rate",
    "displaced_instances",
    "mean_response", "p50_response", "p95_response", "max_response",
    "makespan",
    "transferred", "transferred_frac", "cross_shard_transferred",
    "intra_fleet_transferred", "cross_shard_frac", "cross_shard_completed",
    "per_edge_completed",
    "deadline_total", "deadline_missed", "deadline_miss_frac",
    "cache_hits", "cache_misses", "cache_hit_rate",
    "cloud_completed", "cloud_offload_frac",
)


def summarize(state: dict, slo: Optional[float] = None) -> dict:
    """Host-side metrics from the final slot table, returning exactly
    :data:`SUMMARY_KEYS` (see there for the schema contract). Works on
    batched states (leading axis is aggregated as one population).

    ``submitted`` counts every arrival the engine saw — dispatched, shed by
    admission control, or dropped by the materializer's overflow clip — so
    ``shed_rate`` and the SLO metrics are honest about load that never
    reached a slot. With ``slo`` set, a violation is a completion slower
    than the SLO *or* any request that was shed, dropped, or stranded on a
    dead edge (shedding is never a free lunch for the violation metric).
    ``deadline_*`` covers committed requests with a finite schema-v3
    deadline: a miss is a completion past its deadline or a stranded
    request that never completed."""
    s = jax.device_get(state)
    committed = s["slot_edge"] >= 0
    done = committed & (s["slot_finish"] <= np.expand_dims(
        s["t"], axis=tuple(range(np.ndim(s["t"]), s["slot_finish"].ndim))))
    shed = int(np.sum(s["shed"]))
    dropped = int(np.sum(s["dropped"]))
    stranded = int(committed.sum() - done.sum())
    submitted = int(committed.sum()) + shed + dropped
    completed = int(done.sum())
    finite_dl = committed & (s["slot_deadline"] < INF / 2)
    dl_missed = finite_dl & (~done | (s["slot_finish"] > s["slot_deadline"]))
    dl_total = int(finite_dl.sum())
    n = s["w"].shape[-1]
    e_clip = np.clip(s["slot_edge"], 0, n - 1)
    on_cloud = np.take_along_axis(s["tier"], e_clip, axis=-1) > 0
    cloud_done = int(np.sum(done & on_cloud))
    hits, misses = int(np.sum(s["cache_hits"])), int(np.sum(s["cache_misses"]))
    out = {
        "completed": completed,
        "submitted": submitted,
        "shed_requests": shed,
        "dropped_requests": dropped,
        "stranded_requests": stranded,
        "retried_requests": int((s["slot_retries"][committed] > 0).sum()),
        "shed_rate": (shed + dropped) / max(submitted, 1),
        "displaced_instances": 0,
        "deadline_total": dl_total,
        "deadline_missed": int(dl_missed.sum()),
        "deadline_miss_frac": int(dl_missed.sum()) / max(dl_total, 1),
        "cache_hits": hits,
        "cache_misses": misses,
        "cache_hit_rate": hits / max(hits + misses, 1),
        "cloud_completed": cloud_done,
        "cloud_offload_frac": cloud_done / max(completed, 1),
    }
    if not completed:
        out.update({k: 0.0 for k in ("mean_response", "p50_response",
                                     "p95_response", "max_response",
                                     "makespan", "transferred_frac",
                                     "cross_shard_frac")})
        out.update({k: 0 for k in ("transferred", "cross_shard_transferred",
                                   "intra_fleet_transferred",
                                   "cross_shard_completed")})
        out["per_edge_completed"] = {}
        if slo is not None:
            out["slo"] = float(slo)
            out["slo_violation_frac"] = ((shed + dropped + stranded)
                                         / max(submitted, 1))
        return out
    resp = (s["slot_finish"] - s["slot_submit"])[done]
    edges = s["slot_edge"][done]
    transferred = int((edges != s["slot_src"][done]).sum())
    out.update({
        "mean_response": float(resp.mean()),
        "p50_response": float(np.percentile(resp, 50)),
        "p95_response": float(np.percentile(resp, 95)),
        "max_response": float(resp.max()),
        "transferred": transferred,
        "transferred_frac": transferred / completed,
        "cross_shard_transferred": 0,
        "intra_fleet_transferred": transferred,
        "cross_shard_frac": 0.0,
        "cross_shard_completed": 0,
        "per_edge_completed": {int(e): int(c) for e, c in
                               zip(*np.unique(edges, return_counts=True))},
        "makespan": float(s["slot_finish"][done].max()),
    })
    if slo is not None:
        violations = int((resp > slo).sum()) + shed + dropped + stranded
        out["slo"] = float(slo)
        out["slo_violation_frac"] = violations / max(submitted, 1)
    return out


#: Response-time histogram defaults for the shard-friendly summary path:
#: fixed bins so per-shard partial histograms psum into the global one.
HIST_BINS = 256
HIST_MAX = 32.0


def summarize_partials(state: dict, *, hist_bins: int = HIST_BINS,
                       hist_max: float = HIST_MAX, displaced=None,
                       slo: Optional[float] = None) -> dict:
    """Pure-jnp summary partials from a (possibly batched) final state.

    The mergeable core of :func:`summarize`: every value is either a sum
    (counts, response-time histogram, per-edge completions, response-time
    total) or a max (max response, makespan) over the state's instances, so
    per-shard partials reduce into the fleet-wide summary with one
    psum/pmax instead of ``device_get``-ing the full slot table
    (:mod:`repro.serving.fleet`). :func:`partials_to_summary` turns the
    reduced partials back into ``summarize``-style metrics; p50/p95 are
    estimated from the fixed-bin histogram (responses past ``hist_max``
    land in the last bin, so tail percentiles degrade gracefully to
    ``max_response``).

    ``displaced`` is an optional (B,) bool — True for instances placed off
    their home shard by the fleet partition (:func:`repro.serving.fleet
    .zipf_partition`) — and splits transfer traffic into intra-fleet vs
    cross-shard accounting."""
    committed = state["slot_edge"] >= 0
    finish = state["slot_finish"]
    t = jnp.asarray(state["t"])
    tb = jnp.expand_dims(t, axis=tuple(range(t.ndim, finish.ndim)))
    done = committed & (finish <= tb)
    resp = jnp.where(done, finish - state["slot_submit"], 0.0)

    num_done = jnp.sum(done).astype(jnp.int32)
    shed = jnp.sum(state["shed"]).astype(jnp.int32)
    dropped = jnp.sum(state["dropped"]).astype(jnp.int32)
    num_committed = jnp.sum(committed).astype(jnp.int32)

    scale = hist_bins / hist_max
    idx = jnp.clip((resp * scale).astype(jnp.int32), 0, hist_bins - 1)
    hist = jnp.zeros(hist_bins, jnp.int32).at[idx.ravel()].add(
        done.ravel().astype(jnp.int32))

    q = state["w"].shape[-1]
    edges = jnp.clip(state["slot_edge"], 0, q - 1)
    per_edge = jnp.zeros(q, jnp.int32).at[edges.ravel()].add(
        done.ravel().astype(jnp.int32))

    finite_dl = committed & (state["slot_deadline"] < INF / 2)
    dl_missed = finite_dl & (~done | (finish > state["slot_deadline"]))
    on_cloud = jnp.take_along_axis(state["tier"], edges, axis=-1) > 0

    transferred = done & (state["slot_edge"] != state["slot_src"])
    if displaced is None:
        disp_slots = jnp.zeros_like(done)
        displaced_instances = jnp.int32(0)
    else:
        disp = jnp.asarray(displaced, bool)
        disp_slots = jnp.expand_dims(
            disp, axis=tuple(range(disp.ndim, done.ndim))) & done
        displaced_instances = jnp.sum(disp).astype(jnp.int32)

    out = {
        "completed": num_done,
        "submitted": num_committed + shed + dropped,
        "shed": shed,
        "dropped": dropped,
        "stranded": num_committed - num_done,
        "retried": jnp.sum(committed
                           & (state["slot_retries"] > 0)).astype(jnp.int32),
        "resp_sum": jnp.sum(resp),
        "resp_max": jnp.max(resp),
        "makespan": jnp.max(jnp.where(done, finish, 0.0)),
        "resp_hist": hist,
        "per_edge_completed": per_edge,
        "transferred": jnp.sum(transferred).astype(jnp.int32),
        "cross_shard_transferred": jnp.sum(
            transferred & disp_slots).astype(jnp.int32),
        "cross_shard_completed": jnp.sum(disp_slots).astype(jnp.int32),
        "displaced_instances": displaced_instances,
        "deadline_total": jnp.sum(finite_dl).astype(jnp.int32),
        "deadline_missed": jnp.sum(dl_missed).astype(jnp.int32),
        "cache_hits": jnp.sum(state["cache_hits"]).astype(jnp.int32),
        "cache_misses": jnp.sum(state["cache_misses"]).astype(jnp.int32),
        "cloud_completed": jnp.sum(done & on_cloud).astype(jnp.int32),
    }
    if slo is not None:
        out["slo_violations"] = jnp.sum(done & (resp > slo)).astype(jnp.int32)
    return out


#: partial keys merged with a max (everything else sums)
PARTIAL_MAX_KEYS = frozenset({"resp_max", "makespan"})


def _hist_percentile(hist: np.ndarray, pct: float, hist_max: float,
                     resp_max: float) -> float:
    """Deterministic percentile estimate from fixed-bin counts (linear
    interpolation inside the covering bin; the overflow bin reports
    ``resp_max``). Shard-order invariant: identical histograms give
    identical estimates no matter how the counts were accumulated."""
    total = int(hist.sum())
    cum = np.cumsum(hist)
    target = pct / 100.0 * total
    b = int(np.searchsorted(cum, max(target, 1e-9), side="left"))
    b = min(b, len(hist) - 1)
    if b == len(hist) - 1:  # overflow bin: past hist_max, report the max
        return float(resp_max)
    prev = float(cum[b - 1]) if b > 0 else 0.0
    frac = (target - prev) / max(float(hist[b]), 1.0)
    width = hist_max / len(hist)
    return float(min((b + min(max(frac, 0.0), 1.0)) * width, resp_max))


def partials_to_summary(partials: dict, slo: Optional[float] = None,
                        hist_max: float = HIST_MAX) -> dict:
    """Host-side: reduced :func:`summarize_partials` -> the
    :data:`SUMMARY_KEYS` metrics dict (exactly the :func:`summarize`
    schema). p50/p95 come from the histogram (see
    :func:`summarize_partials`); all counts, ``mean_response``,
    ``max_response`` and ``makespan`` are exact."""
    p = {k: np.asarray(jax.device_get(v)) for k, v in partials.items()}
    completed = int(p["completed"])
    submitted = int(p["submitted"])
    shed, dropped = int(p["shed"]), int(p["dropped"])
    stranded = int(p["stranded"])
    dl_total, dl_missed = int(p["deadline_total"]), int(p["deadline_missed"])
    hits, misses = int(p["cache_hits"]), int(p["cache_misses"])
    cloud_done = int(p["cloud_completed"])
    out = {
        "completed": completed,
        "submitted": submitted,
        "shed_requests": shed,
        "dropped_requests": dropped,
        "stranded_requests": stranded,
        "retried_requests": int(p["retried"]),
        "shed_rate": (shed + dropped) / max(submitted, 1),
        "displaced_instances": int(p["displaced_instances"]),
        "deadline_total": dl_total,
        "deadline_missed": dl_missed,
        "deadline_miss_frac": dl_missed / max(dl_total, 1),
        "cache_hits": hits,
        "cache_misses": misses,
        "cache_hit_rate": hits / max(hits + misses, 1),
        "cloud_completed": cloud_done,
        "cloud_offload_frac": cloud_done / max(completed, 1),
    }
    if not completed:
        out.update({k: 0.0 for k in ("mean_response", "p50_response",
                                     "p95_response", "max_response",
                                     "makespan", "transferred_frac",
                                     "cross_shard_frac")})
        out.update({k: 0 for k in ("transferred", "cross_shard_transferred",
                                   "intra_fleet_transferred",
                                   "cross_shard_completed")})
        out["per_edge_completed"] = {}
        if slo is not None and "slo_violations" in p:
            out["slo"] = float(slo)
            out["slo_violation_frac"] = ((shed + dropped + stranded)
                                         / max(submitted, 1))
        return out
    resp_max = float(p["resp_max"])
    transferred = int(p["transferred"])
    cross = int(p["cross_shard_transferred"])
    out.update({
        "mean_response": float(p["resp_sum"]) / completed,
        "p50_response": _hist_percentile(p["resp_hist"], 50.0, hist_max,
                                         resp_max),
        "p95_response": _hist_percentile(p["resp_hist"], 95.0, hist_max,
                                         resp_max),
        "max_response": resp_max,
        "transferred": transferred,
        "transferred_frac": transferred / completed,
        "cross_shard_transferred": cross,
        "intra_fleet_transferred": transferred - cross,
        "cross_shard_frac": cross / max(transferred, 1),
        "cross_shard_completed": int(p["cross_shard_completed"]),
        "per_edge_completed": {int(e): int(c)
                               for e, c in enumerate(p["per_edge_completed"])
                               if c},
        "makespan": float(p["makespan"]),
    })
    if slo is not None and "slo_violations" in p:
        violations = int(p["slo_violations"]) + shed + dropped + stranded
        out["slo"] = float(slo)
        out["slo_violation_frac"] = violations / max(submitted, 1)
    return out


# ---------------------------------------------------------------------------
# built-in assign functions (all jit/vmap-safe)
# ---------------------------------------------------------------------------


def local_assign(key, inst):
    """Every request executes at its source edge (the Local baseline)."""
    del key
    return inst["req_src"].astype(jnp.int32)


def greedy_assign(key, inst):
    """jnp twin of heuristics.solve_greedy: size-descending greedy insertion,
    each request to the eligible edge (``edge_mask``) minimizing the
    incremental makespan (later requests parked at their source during
    evaluation)."""
    del key
    num_edges = inst["w"].shape[-1]
    sizes, rmask = inst["req_size"], inst["req_mask"]
    order = stable_order(jnp.where(rmask, -sizes, jnp.inf))
    cur0 = inst["req_src"].astype(jnp.int32)

    def body(cur, z):
        costs = jax.vmap(
            lambda q: makespan(inst, cur.at[z].set(q))
        )(jnp.arange(num_edges, dtype=jnp.int32))
        costs = jnp.where(inst["edge_mask"], costs, jnp.inf)
        best = jnp.argmin(costs).astype(jnp.int32)
        return jnp.where(rmask[z], cur.at[z].set(best), cur), None

    cur, _ = jax.lax.scan(body, cur0, order)
    return cur


#: Engine scheduling backends, selectable by name. Plain entries are
#: AssignFns; entries tagged ``_assign_factory`` (the policy) are built
#: with policy kwargs through :func:`resolve_assign_fn`. Both policy names
#: are aliases of the single :func:`repro.core.inference.make_assign_factory`
#: factory, differing only in their default
#: :class:`~repro.core.inference.DecisionSpec`: ``"policy-fused"`` defaults
#: the in-kernel fused decode on (same decisions, never materializes the
#: per-round (Z, Q) log-prob matrix — the serving default for latency-bound
#: rollouts).
ASSIGN_FNS = {
    "local": local_assign,
    "greedy": greedy_assign,
    "policy": make_policy_assign,
    "policy-fused": make_policy_assign_fused,
}


def resolve_assign_fn(name: str, **policy_kwargs) -> AssignFn:
    """Look an engine backend up by name.

    Heuristic backends resolve to their AssignFn directly; the ``"policy"``
    / ``"policy-fused"`` entries are one DecisionSpec-parameterized factory
    and are built from ``policy_kwargs`` (``params``, ``policy_state``,
    ``policy_cfg``, optional ``spec=DecisionSpec(...)`` or the deprecated
    per-flag keywords — see :func:`repro.core.inference.make_assign_factory`)."""
    try:
        entry = ASSIGN_FNS[name]
    except KeyError:
        raise ValueError(
            f"unknown engine backend {name!r}; registered: "
            f"{', '.join(sorted(ASSIGN_FNS))}") from None
    if getattr(entry, "_assign_factory", False):
        if not policy_kwargs:
            raise ValueError(
                f"engine backend {name!r} is a policy factory; pass at "
                f"least params=, policy_state= and policy_cfg= (see "
                f"repro.core.inference.make_policy_assign)")
        return entry(**policy_kwargs)
    if policy_kwargs:
        raise ValueError(
            f"engine backend {name!r} is not a policy factory; it takes "
            f"no kwargs (got {sorted(policy_kwargs)})")
    return entry
