"""Fleet-sharded rollouts: the batched engine over a real device mesh.

The array-native engine (:mod:`repro.serving.engine`) vmaps a (B,) batch of
independent cluster instances on one device. This module spreads that batch
over a 1-D ``("fleet",)`` device mesh (:func:`repro.launch.mesh
.make_fleet_mesh`) with ``shard_map``: each device rolls its slice of
instances forward with the exact same jitted ``make_rollout(batch=True)``
program, then the per-shard summary partials (:func:`repro.serving.engine
.summarize_partials` — counts, a fixed-bin response-time histogram for
p50/p95, per-edge completions) are reduced across the fleet with
psum/pmax. The host only ever sees the few-hundred-float reduced summary,
never a device_get of B full slot tables — which is what lets one run
simulate thousands of clusters.

Placement is where fleets stop being embarrassingly parallel.
:func:`zipf_partition` models the real-world skew ROADMAP item 1 calls
for: every instance gets a *home* shard drawn from a Zipf popularity law
over shards (hot regions attract more clusters), while the actual
*placement* is capacity-balanced (``shard_map`` needs exactly B/S
instances per device). Instances that could not fit their home shard are
*displaced* — their traffic had to leave its region — and the summary
accounts transfers of displaced instances as cross-shard traffic,
separate from intra-fleet transfers. :meth:`FleetPartition
.imbalance_report` quantifies the skew the balancer absorbed.

Equivalence: a fleet-sharded rollout reduces to exactly the single-device
vmap engine's summary (instances never interact across shards; the only
cross-device ops are the final psums) — pinned at 1e-5 on a forced
8-device host mesh by tests/fleet_child.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

try:
    shard_map = jax.shard_map
except AttributeError:  # pre-0.5 jax keeps it in jax.experimental
    from jax.experimental.shard_map import shard_map

from repro.serving import engine
from repro.sharding.specs import arrival_specs, engine_state_specs
from repro.workloads.base import edge_weights


@dataclasses.dataclass(frozen=True)
class FleetPartition:
    """Instance-to-shard assignment for one fleet rollout.

    ``home`` is the Zipf-drawn region of each instance; ``shard`` the
    capacity-balanced placement actually used on the mesh; ``order`` the
    permutation that groups placements into the contiguous (B/S)-blocks
    ``shard_map`` splits the leading axis into (apply it with
    :func:`apply_partition` before running)."""

    num_shards: int
    home: np.ndarray   # (B,) int — Zipf-popular home shard per instance
    shard: np.ndarray  # (B,) int — balanced placement shard per instance
    order: np.ndarray  # (B,) int — permutation grouping placement shards

    @property
    def displaced(self) -> np.ndarray:
        """(B,) bool, instance order: placed off its home shard."""
        return self.home != self.shard

    @property
    def placed_displaced(self) -> np.ndarray:
        """(B,) bool in *placement* order — pass this to the fleet rollout
        so cross-shard accounting travels with the reordered instances."""
        return self.displaced[self.order]

    def imbalance_report(self, loads=None) -> dict:
        """How skewed the requested (home) load was vs what each shard
        actually runs. ``loads`` weights instances (e.g. real arrival
        counts from an arrival batch's ``mask.sum``); defaults to 1 per
        instance. ``home_imbalance`` is max/mean of per-shard home load —
        1.0 is perfectly uniform."""
        b = len(self.home)
        loads = np.ones(b) if loads is None else np.asarray(loads, float)
        home_load = np.bincount(self.home, weights=loads,
                                minlength=self.num_shards)
        placed_load = np.bincount(self.shard, weights=loads,
                                  minlength=self.num_shards)
        mean = max(loads.sum() / self.num_shards, 1e-12)
        displaced = int(self.displaced.sum())
        return {
            "num_shards": self.num_shards,
            "capacity": b // self.num_shards,
            "home_load": [float(x) for x in home_load],
            "placed_load": [float(x) for x in placed_load],
            "home_imbalance": float(home_load.max() / mean),
            "placed_imbalance": float(placed_load.max() / mean),
            "displaced_instances": displaced,
            "displaced_frac": displaced / max(b, 1),
        }


def zipf_partition(num_instances: int, num_shards: int, *, skew: float = 0.0,
                   seed: int = 0) -> FleetPartition:
    """Draw each instance's home shard from a Zipf popularity law
    (rank-k shard has weight (k+1)^-skew; ``skew=0`` is uniform) and place
    instances with a capacity-balanced first-fit: home shard while it has
    room, else the least-loaded shard with remaining capacity. The gap
    between the two is exactly the load the fleet must move cross-shard."""
    if num_instances % num_shards != 0:
        raise ValueError(
            f"cannot partition {num_instances} instance(s) over "
            f"{num_shards} shard(s): shard_map needs equal blocks "
            f"(instances % shards == 0)")
    probs = edge_weights(num_shards, skew)
    rng = np.random.default_rng(seed)
    home = rng.choice(num_shards, size=num_instances, p=probs)
    cap = num_instances // num_shards
    counts = np.zeros(num_shards, np.int64)
    shard = np.empty(num_instances, np.int64)
    for i, h in enumerate(home):
        if counts[h] < cap:
            shard[i] = h
        else:
            shard[i] = int(np.argmin(np.where(counts < cap, counts,
                                              num_instances + 1)))
        counts[shard[i]] += 1
    order = np.argsort(shard, kind="stable")
    return FleetPartition(num_shards=num_shards, home=home, shard=shard,
                          order=order)


def apply_partition(part: FleetPartition, tree):
    """Reorder a batched pytree's leading instance axis into the
    partition's placement order (contiguous per-shard blocks)."""
    return jax.tree.map(lambda x: np.asarray(x)[part.order], tree)


def make_fleet_rollout(cfg: engine.EngineConfig, assign_fn, mesh, *,
                       axis: str = "fleet",
                       hist_bins: int = engine.HIST_BINS,
                       hist_max: float = engine.HIST_MAX,
                       slo: Optional[float] = None,
                       drain_to: Optional[float] = engine.DRAIN_HORIZON):
    """Build ``run(states, arrivals, keys, displaced=None) -> partials``:
    the fleet-sharded twin of ``make_rollout(batch=True)`` + ``summarize``.

    Inputs are the same (B,)-leading batched pytrees the vmap engine takes
    (``init_batch`` states, ``materialize_round_batch`` arrivals, (B,)
    split keys), reordered with :func:`apply_partition` when using a
    skewed partition; B must divide by the mesh's fleet-axis size. The
    return value is the psum/pmax-reduced :func:`repro.serving.engine
    .summarize_partials` dict (replicated, small) — feed it to
    :func:`repro.serving.engine.partials_to_summary` for the metrics
    dict. ``displaced`` is ``FleetPartition.placed_displaced`` and drives
    the cross-shard transfer split."""
    num_shards = int(mesh.shape[axis])
    inner = engine.make_rollout(cfg, assign_fn, batch=True, drain_to=drain_to)

    def body(states, arrivals, keys, displaced):
        final, _infos = inner(states, arrivals, keys)
        p = engine.summarize_partials(final, hist_bins=hist_bins,
                                      hist_max=hist_max, displaced=displaced,
                                      slo=slo)
        return {k: (jax.lax.pmax(v, axis) if k in engine.PARTIAL_MAX_KEYS
                    else jax.lax.psum(v, axis))
                for k, v in p.items()}

    cache: dict = {}

    def run(states, arrivals, keys, displaced=None):
        b = int(np.shape(arrivals["size"])[0])
        if b % num_shards != 0:
            raise ValueError(
                f"batch of {b} instance(s) does not divide over the "
                f"{num_shards}-shard fleet axis {axis!r}; pad the batch or "
                f"shrink the mesh")
        if displaced is None:
            displaced = np.zeros(b, bool)
        sig = (jax.tree.structure(states), jax.tree.structure(arrivals))
        fn = cache.get(sig)
        if fn is None:
            in_specs = (engine_state_specs(states, axis),
                        arrival_specs(arrivals, axis),
                        arrival_specs(keys, axis), P(axis))
            fn = jax.jit(shard_map(body, mesh=mesh, in_specs=in_specs,
                                   out_specs=P(), check_rep=False))
            cache[sig] = fn
        return fn(states, arrivals, keys, displaced)

    return run


def fleet_summary(partials: dict, *, slo: Optional[float] = None,
                  hist_max: float = engine.HIST_MAX) -> dict:
    """Reduced fleet partials -> ``summarize``-style metrics dict
    (thin alias of :func:`repro.serving.engine.partials_to_summary`)."""
    return engine.partials_to_summary(partials, slo=slo, hist_max=hist_max)
