"""Minimal functional neural-network substrate (no external NN library).

Modules are (init, apply) pairs over plain dict pytrees. Stateful layers
(BatchNorm) thread an explicit ``state`` collection. This is the substrate
both for the CoRaiS policy network (paper §IV) and for the LM model zoo.
"""
from repro.nn.module import (
    uniform_init,
    normal_init,
    zeros_init,
    ones_init,
    split_keys,
    param_count,
    tree_size_bytes,
)
from repro.nn.layers import (
    linear_init,
    linear_apply,
    mha_init,
    mha_apply,
    batchnorm_init,
    batchnorm_apply,
    layernorm_init,
    layernorm_apply,
    rmsnorm_init,
    rmsnorm_apply,
    nonparametric_layernorm,
)

__all__ = [
    "uniform_init", "normal_init", "zeros_init", "ones_init", "split_keys",
    "param_count", "tree_size_bytes",
    "linear_init", "linear_apply", "mha_init", "mha_apply",
    "batchnorm_init", "batchnorm_apply", "layernorm_init", "layernorm_apply",
    "rmsnorm_init", "rmsnorm_apply", "nonparametric_layernorm",
]
