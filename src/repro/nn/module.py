"""Parameter-tree utilities shared by all functional modules."""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = Any  # nested dict pytree of jnp arrays


def split_keys(key: jax.Array, n: int) -> list[jax.Array]:
    return list(jax.random.split(key, n))


def uniform_init(key: jax.Array, shape: tuple[int, ...], fan_in: int | None = None,
                 dtype=jnp.float32) -> jax.Array:
    """Paper §V.A init: Uniform(-1/sqrt(d), 1/sqrt(d)) with d the input dim."""
    if fan_in is None:
        fan_in = shape[0] if len(shape) == 1 else shape[-2]
    bound = 1.0 / math.sqrt(max(fan_in, 1))
    return jax.random.uniform(key, shape, dtype, minval=-bound, maxval=bound)


def normal_init(key: jax.Array, shape: tuple[int, ...], stddev: float = 0.02,
                dtype=jnp.float32) -> jax.Array:
    return stddev * jax.random.normal(key, shape, dtype)


def zeros_init(shape: tuple[int, ...], dtype=jnp.float32) -> jax.Array:
    return jnp.zeros(shape, dtype)


def ones_init(shape: tuple[int, ...], dtype=jnp.float32) -> jax.Array:
    return jnp.ones(shape, dtype)


def param_count(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def tree_size_bytes(params: Params) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(params))
