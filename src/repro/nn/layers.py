"""Functional layers: Linear, multi-head attention, normalizations.

Used by the CoRaiS policy network (paper §IV eqs 12-17) and, for the norms,
by the LM model zoo. All `*_init` return dict pytrees; all `*_apply` are
pure functions.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.module import split_keys, uniform_init

# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------


def linear_init(key, in_dim: int, out_dim: int, bias: bool = True, dtype=jnp.float32):
    kw, kb = jax.random.split(key)
    p = {"w": uniform_init(kw, (in_dim, out_dim), fan_in=in_dim, dtype=dtype)}
    if bias:
        p["b"] = uniform_init(kb, (out_dim,), fan_in=in_dim, dtype=dtype)
    return p


def linear_apply(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# Multi-head attention (paper eq 12/14/15 building block)
# ---------------------------------------------------------------------------


def mha_init(key, dim: int, num_heads: int, kv_dim: Optional[int] = None,
             out_dim: Optional[int] = None, dtype=jnp.float32):
    """MHA projections. ``kv_dim`` lets the context decoder attend from
    edge-context vectors (query dim != key/value dim source, eq 15).

    ``num_heads`` is a static property of the module, not a parameter —
    pass it to :func:`mha_apply` (keeps param pytrees array-only for
    optimizers/checkpointing)."""
    kv_dim = kv_dim or dim
    out_dim = out_dim or dim
    del num_heads
    kq, kk, kv, ko = split_keys(key, 4)
    return {
        "wq": uniform_init(kq, (dim, out_dim), fan_in=dim, dtype=dtype),
        "wk": uniform_init(kk, (kv_dim, out_dim), fan_in=kv_dim, dtype=dtype),
        "wv": uniform_init(kv, (kv_dim, out_dim), fan_in=kv_dim, dtype=dtype),
        "wo": uniform_init(ko, (out_dim, out_dim), fan_in=out_dim, dtype=dtype),
    }


def mha_apply(p, q_in, kv_in=None, mask=None, *, num_heads: int = 8):
    """Self-attention if ``kv_in`` is None, else cross-attention.

    q_in: (..., Nq, D); kv_in: (..., Nk, Dkv); mask: broadcastable to
    (..., H, Nq, Nk), True = keep.
    """
    if kv_in is None:
        kv_in = q_in
    h = num_heads
    q = q_in @ p["wq"]
    k = kv_in @ p["wk"]
    v = kv_in @ p["wv"]
    dh = q.shape[-1] // h

    def heads(x):
        return jnp.moveaxis(x.reshape(*x.shape[:-1], h, dh), -2, -3)

    qh, kh, vh = heads(q), heads(k), heads(v)  # (..., H, N, dh)
    logits = jnp.einsum("...qd,...kd->...qk", qh, kh) / math.sqrt(dh)
    if mask is not None:
        logits = jnp.where(mask, logits, -1e9)
    attn = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("...qk,...kd->...qd", attn, vh)
    out = jnp.moveaxis(out, -3, -2).reshape(*q_in.shape[:-1], h * dh)
    return out @ p["wo"]


# ---------------------------------------------------------------------------
# BatchNorm with running stats (Kool-style: stats over batch x nodes)
# ---------------------------------------------------------------------------


def batchnorm_init(dim: int, dtype=jnp.float32):
    params = {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}
    state = {"mean": jnp.zeros((dim,), dtype), "var": jnp.ones((dim,), dtype),
             "count": jnp.zeros((), dtype)}
    return params, state


def batchnorm_apply(params, state, x, *, training: bool, momentum: float = 0.9,
                    eps: float = 1e-5):
    """x: (..., dim) — statistics over all leading axes (batch and nodes),
    matching attention-model practice for BN in encoder sublayers."""
    if training:
        axes = tuple(range(x.ndim - 1))
        mean = jnp.mean(x, axis=axes)
        var = jnp.var(x, axis=axes)
        new_state = {
            "mean": momentum * state["mean"] + (1 - momentum) * mean,
            "var": momentum * state["var"] + (1 - momentum) * var,
            "count": state["count"] + 1,
        }
    else:
        # Fall back to batch stats if the layer has never been trained.
        trained = state["count"] > 0
        axes = tuple(range(x.ndim - 1))
        mean = jnp.where(trained, state["mean"], jnp.mean(x, axis=axes))
        var = jnp.where(trained, state["var"], jnp.var(x, axis=axes))
        new_state = state
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    return y * params["scale"] + params["bias"], new_state


# ---------------------------------------------------------------------------
# LayerNorm family (LM zoo)
# ---------------------------------------------------------------------------


def layernorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm_apply(p, x, eps: float = 1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    return y * p["scale"] + p["bias"]


def nonparametric_layernorm(x, eps: float = 1e-5):
    """OLMo-style LN without learnable parameters (arXiv:2402.00838)."""
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps)


def rmsnorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm_apply(p, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    return (y * p["scale"]).astype(dtype)
