"""Training driver: CoRaiS RL (the paper's training, §IV-B) or LM pretrain.

Both paths share the substrate: checkpointing (async, atomic, keep-K),
preemption-safe resume (data-pipeline state rides in checkpoint extras),
gradient clipping, and the sharded step builders.

    python -m repro.launch.train corais --batches 200 --ckpt /tmp/corais
    python -m repro.launch.train lm --arch olmo-1b --steps 50 --scale reduced
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.core.instances import InstanceConfig
from repro.core.policy import PolicyConfig
from repro.core.train import RLConfig, train as rl_train
from repro.data.synthetic import SyntheticTokens
from repro.models import lm
from repro.optim import AdamConfig, adam_init, adam_update, clip_by_global_norm


def train_corais(args) -> None:
    cfg = RLConfig(
        policy=PolicyConfig(d_model=args.policy_dim),
        instance=InstanceConfig(num_edges=args.edges, num_requests=args.requests,
                                backlog_high=args.backlog),
        batch_size=args.batch_size,
        num_samples=args.samples,
        lr=args.lr,
        num_batches=args.batches,
        seed=args.seed,
    )
    ckpt = Checkpointer(args.ckpt, every=args.ckpt_every) if args.ckpt else None
    params = state = opt_state = None
    start = 0
    if ckpt is not None:
        from repro.core.policy import corais_init
        from repro.optim import adam_init as ainit
        template = jax.eval_shape(
            lambda: corais_init(jax.random.PRNGKey(cfg.seed), cfg.policy))
        opt_template = jax.eval_shape(
            lambda: ainit(template[0], AdamConfig(lr=cfg.lr)))
        restored = ckpt.restore_latest(
            {"params": template[0], "state": template[1],
             "opt_state": opt_template})
        if restored:
            start = restored["step"] + 1
            params = restored["tree"]["params"]
            state = restored["tree"]["state"]
            opt_state = restored["tree"]["opt_state"]
            print(f"resumed from batch {restored['step']}")

    def log(m):
        print(f"batch {m['batch']:5d} loss {m['loss']:+9.3f} "
              f"cost_mean {m['cost_mean']:7.3f} cost_best {m['cost_best']:7.3f} "
              f"H {m['entropy']:7.2f} ({m['sec']*1e3:6.1f} ms)")

    params, state, opt_state, hist = rl_train(
        cfg, params=params, state=state, opt_state=opt_state,
        callback=log, checkpointer=ckpt, start_batch=start)
    if ckpt is not None:
        ckpt.save(start + cfg.num_batches,
                  {"params": params, "state": state, "opt_state": opt_state})
        ckpt.wait()
    print("final cost_mean:", hist[-1]["cost_mean"])


def train_lm(args) -> None:
    from repro.configs import get_config, get_reduced_config

    cfg = get_reduced_config(args.arch) if args.scale == "reduced" \
        else get_config(args.arch)
    if cfg.encoder_decoder or not cfg.embed_input:
        raise SystemExit(f"{args.arch}: synthetic token pretrain applies to "
                         "token-input decoder archs; pick a dense/moe/ssm arch")
    adam = AdamConfig(lr=args.lr)
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(key, cfg)
    opt_state = adam_init(params, adam)
    pipe = SyntheticTokens(cfg.vocab_size, args.batch_size, args.seq, seed=args.seed)
    ckpt = Checkpointer(args.ckpt, every=args.ckpt_every) if args.ckpt else None
    start = 0
    if ckpt is not None:
        restored = ckpt.restore_latest(
            {"params": jax.eval_shape(lambda: lm.init_params(key, cfg)),
             "opt_state": jax.eval_shape(lambda: adam_init(
                 jax.eval_shape(lambda: lm.init_params(key, cfg)), adam))})
        if restored:
            start = restored["step"]
            params = restored["tree"]["params"]
            opt_state = restored["tree"]["opt_state"]
            pipe.load_state_dict(restored["extras"]["pipeline"])
            print(f"resumed from step {start}")

    @jax.jit
    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm.train_loss(p, batch, cfg, 1), has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = adam_update(params, grads, opt_state, adam)
        return params, opt_state, loss, gnorm

    losses = []
    for i in range(start, start + args.steps):
        batch = jax.tree.map(jnp.asarray, next(pipe))
        t0 = time.perf_counter()
        params, opt_state, loss, gnorm = step(params, opt_state, batch)
        loss = float(loss)
        losses.append(loss)
        if i % args.log_every == 0:
            print(f"step {i:5d} loss {loss:8.4f} gnorm {float(gnorm):8.2f} "
                  f"({(time.perf_counter()-t0)*1e3:7.1f} ms)")
        if ckpt is not None and ckpt.should_save(i):
            ckpt.save(i, {"params": params, "opt_state": opt_state},
                      extras={"pipeline": pipe.state_dict()})
    if ckpt is not None:
        ckpt.wait()
    first = np.mean(losses[:5]) if len(losses) >= 5 else losses[0]
    last = np.mean(losses[-5:])
    print(f"loss {first:.4f} -> {last:.4f} over {len(losses)} steps")


def main() -> None:
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="mode", required=True)

    c = sub.add_parser("corais")
    c.add_argument("--edges", type=int, default=5)
    c.add_argument("--requests", type=int, default=50)
    c.add_argument("--backlog", type=int, default=100)
    c.add_argument("--batch-size", type=int, default=128)
    c.add_argument("--samples", type=int, default=64)
    c.add_argument("--batches", type=int, default=40000)
    c.add_argument("--lr", type=float, default=1e-5)
    c.add_argument("--policy-dim", type=int, default=256)
    c.add_argument("--seed", type=int, default=0)
    c.add_argument("--ckpt", default=None)
    c.add_argument("--ckpt-every", type=int, default=100)

    l = sub.add_parser("lm")
    l.add_argument("--arch", required=True)
    l.add_argument("--scale", choices=("reduced", "full"), default="reduced")
    l.add_argument("--steps", type=int, default=100)
    l.add_argument("--batch-size", type=int, default=8)
    l.add_argument("--seq", type=int, default=128)
    l.add_argument("--lr", type=float, default=3e-4)
    l.add_argument("--seed", type=int, default=0)
    l.add_argument("--ckpt", default=None)
    l.add_argument("--ckpt-every", type=int, default=50)
    l.add_argument("--log-every", type=int, default=10)

    args = ap.parse_args()
    if args.mode == "corais":
        train_corais(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
