import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (task spec MULTI-POD DRY-RUN).

For every (architecture x input-shape x mesh) cell: build the sharded step,
``.lower(**ShapeDtypeStructs)``, ``.compile()``, print memory/cost analysis,
parse the collective schedule, and append a CellReport to the results JSON.

The XLA_FLAGS line above MUST stay the first statement — jax locks the
device count at first init. Never import this module from test/bench code
that needs the real single-device view; run it as a subprocess
(``python -m repro.launch.dryrun ...``).

Usage:
    python -m repro.launch.dryrun --arch olmo-1b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --out results/dryrun.json
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import TrainKnobs, build_for_shape, lowering_inputs
from repro.roofline.analysis import analyze_compiled


# §Perf hillclimb variants (EXPERIMENTS.md): composable with "+", e.g.
# --variant "flashdecode+ssm-bf16". Model-config overrides:
CFG_VARIANTS = {
    "flashdecode": {"decode_flash_shardmap": True},
    "ssm-bf16": {"ssm_scan_dtype": "bfloat16"},
    "ssm-chunk32": {"ssm_chunk": 32},
    "ssm-chunk64": {"ssm_chunk": 64},
    "ssm-chunk128": {"ssm_chunk": 128},
    "ssm-chunk1024": {"ssm_chunk": 1024},
    "ssm-chunk4096": {"ssm_chunk": 4096},
    "remat-dots": {"remat": "dots"},
    "remat-none": {"remat": "none"},
    "mb1": {"num_microbatches": 1},
    "mb2": {"num_microbatches": 2},
    "mb4": {"num_microbatches": 4},
    "mb16": {"num_microbatches": 16},
    "dp-layout": {"layout": "dp"},
    "tpserve": {"layout": "tp-serve"},
    "densemoe": {"moe_dense_decode": True},
    "seqshard": {"seq_shard_activations": True},
    "noseqshard": {"seq_shard_activations": False},
    "adam": {"optimizer": "adam"},
    "adafactor": {"optimizer": "adafactor"},
}
# Execution-knob overrides:
KNOB_VARIANTS = {
    "accum-bf16": {"grad_accum_dtype": "bfloat16"},
}


def apply_variant(cfg, knobs: TrainKnobs, variant: str):
    if variant in ("", "baseline"):
        return cfg, knobs
    for part in variant.split("+"):
        if part in CFG_VARIANTS:
            cfg = dataclasses.replace(cfg, **CFG_VARIANTS[part])
        elif part in KNOB_VARIANTS:
            knobs = dataclasses.replace(knobs, **KNOB_VARIANTS[part])
        else:
            raise KeyError(f"unknown variant component {part!r}; known: "
                           f"{sorted(CFG_VARIANTS) + sorted(KNOB_VARIANTS)}")
    return cfg, knobs


def probe_config(cfg, shape, n_layers: int):
    """Unrolled shallow twin of ``cfg`` for exact cost accounting.

    XLA's HloCostAnalysis counts while-loop bodies once, so the full scanned
    program under-reports FLOPs/bytes/collectives. The probe unrolls every
    loop (layers, microbatches, attention blocks, ssm chunks) at 1 and 2
    layers; per-layer deltas extrapolate to the real depth. Attention probe
    chunks are coarsened to keep the unroll small — a <10% SWA-span
    overcount, noted in EXPERIMENTS.md §Roofline.
    """
    s = shape.seq_len if shape.kind != "decode" else 1
    attn_chunk = max(512, s // 8)
    if cfg.sliding_window:
        attn_chunk = min(attn_chunk, max(cfg.sliding_window, 512))
    attn_chunk = min(attn_chunk, max(s, 1))
    # respect explicitly-reduced ssm chunks (the ssm-chunk* variants);
    # otherwise coarsen so the probe unroll stays small
    ssm_chunk = min(max(256, s // 4), max(s, 1))
    if cfg.ssm_chunk < ssm_chunk:
        ssm_chunk = min(cfg.ssm_chunk, max(s, 1))
    repl = dict(
        num_layers=n_layers,
        scan_layers=False,
        attn_unroll=True,
        attn_chunk=attn_chunk,
        ssm_unroll=True,
        ssm_chunk=ssm_chunk,
    )
    if cfg.encoder_decoder:
        repl["num_encoder_layers"] = n_layers
    return dataclasses.replace(cfg, **repl)


def _probe_one(cfg, shape, mesh, knobs):
    from repro.roofline.hlo_parse import collective_wire_bytes

    with mesh:
        step, _, _ = build_for_shape(cfg, mesh, shape, knobs)
        args = lowering_inputs(cfg, shape, knobs)
        compiled = step.lower(*args).compile()
    ca = compiled.cost_analysis() or {}
    wire = collective_wire_bytes(compiled.as_text())
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)),
            float(wire.get("_total", 0.0)))


def _probe_costs(cfg, shape, mesh, knobs):
    """(flops, bytes, wire) per device corrected for loop trip counts.

    Bilinear model cost(L, m) = a + b*L + c*m + d*L*m over unrolled probes
    at (L, m) in {1,2}^2 — weight-gather traffic scales with L*m (FSDP
    re-gathers per layer per microbatch), so the cross term is real.
    """
    pknobs = dataclasses.replace(knobs, unroll_microbatches=True)
    L = cfg.num_layers
    M = max(cfg.num_microbatches, 1)
    if M == 1 or shape.kind != "train":
        vals = [_probe_one(dataclasses.replace(probe_config(cfg, shape, n),
                                               num_microbatches=1),
                           shape, mesh, pknobs) for n in (1, 2)]
        (f1, b1, w1), (f2, b2, w2) = vals
        return (f1 + (L - 1) * max(f2 - f1, 0.0),
                b1 + (L - 1) * max(b2 - b1, 0.0),
                w1 + (L - 1) * max(w2 - w1, 0.0))
    grid = {}
    for n in (1, 2):
        for mm in (1, 2):
            pcfg = dataclasses.replace(probe_config(cfg, shape, n),
                                       num_microbatches=mm)
            grid[(n, mm)] = _probe_one(pcfg, shape, mesh, pknobs)

    def extrapolate(i):
        c11, c12 = grid[(1, 1)][i], grid[(1, 2)][i]
        c21, c22 = grid[(2, 1)][i], grid[(2, 2)][i]
        d = c22 - c21 - c12 + c11
        b = c21 - c11 - d
        c = c12 - c11 - d
        a = c11 - b - c - d
        return max(a + b * L + c * M + d * L * M, 0.0)

    return extrapolate(0), extrapolate(1), extrapolate(2)


def run_cell(arch: str, shape_name: str, mesh_name: str,
             knobs: TrainKnobs = TrainKnobs(), variant: str = "baseline",
             verbose: bool = True, probe: bool = True,
             cfg_override=None) -> dict:
    cfg = cfg_override or get_config(arch)
    cfg, knobs = apply_variant(cfg, knobs, variant)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": why, "variant": variant}
    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = 512 if multi else 256
    t0 = time.time()
    with mesh:
        step, _, _ = build_for_shape(cfg, mesh, shape, knobs)
        args = lowering_inputs(cfg, shape, knobs)
        lowered = step.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    report = analyze_compiled(compiled, cfg, shape, mesh_name, chips,
                              args[0], t_compile, variant)
    raw = (report.hlo_flops_per_device, report.hlo_bytes_per_device,
           report.wire_bytes_per_device)
    # Roofline accounting (single-pod only per task spec): correct the
    # loop-body undercount with unrolled probes.
    if probe and mesh_name == "single":
        f, b, w = _probe_costs(cfg, shape, mesh, knobs)
        report.hlo_flops_per_device = f
        report.hlo_bytes_per_device = b
        report.wire_bytes_per_device = w
    out = report.to_json()
    out["status"] = "ok"
    out["lower_seconds"] = t_lower
    out["raw_scan_counted"] = {"flops": raw[0], "bytes": raw[1], "wire": raw[2]}
    ma = compiled.memory_analysis()
    out["memory_analysis"] = {
        "argument_size_in_bytes": int(ma.argument_size_in_bytes),
        "output_size_in_bytes": int(ma.output_size_in_bytes),
        "temp_size_in_bytes": int(ma.temp_size_in_bytes),
        "alias_size_in_bytes": int(ma.alias_size_in_bytes),
    }
    if verbose:
        print(f"== {arch} x {shape_name} x {mesh_name} [{variant}] ==")
        print("memory_analysis:", out["memory_analysis"])
        t = out["terms"]
        print(f"flops/dev={out['hlo_flops_per_device']:.3e} "
              f"bytes/dev={out['hlo_bytes_per_device']:.3e} "
              f"wire/dev={out['wire_bytes_per_device']:.3e}")
        print(f"terms: compute={t['compute_s']:.4f}s memory={t['memory_s']:.4f}s "
              f"collective={t['collective_s']:.4f}s dominant={t['dominant']} "
              f"useful_ratio={t['useful_flop_ratio']:.3f}")
        print(f"collectives: {out['collective_ops']}  "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true", help="run every (arch x shape)")
    ap.add_argument("--out", default=None, help="append JSON results here")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--grad-accum-dtype", default="float32")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip cells already present (ok/skipped) in --out")
    args = ap.parse_args()

    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    if args.all:
        cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    knobs = TrainKnobs(grad_accum_dtype=args.grad_accum_dtype, lr=args.lr)
    results, failures = [], 0

    def flush():
        if not args.out:
            return
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        # replace any prior entry for the same (arch, shape, mesh, variant)
        done = {(r["arch"], r["shape"], r["mesh"], r.get("variant", "baseline"))
                for r in results}
        existing = [r for r in existing
                    if (r["arch"], r["shape"], r["mesh"],
                        r.get("variant", "baseline")) not in done]
        existing.extend(results)
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out + ".tmp", "w") as f:
            json.dump(existing, f, indent=1)
        os.replace(args.out + ".tmp", args.out)

    already = set()
    if args.skip_existing and args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for r in json.load(f):
                if r["status"] in ("ok", "skipped"):
                    already.add((r["arch"], r["shape"], r["mesh"],
                                 r.get("variant", "baseline")))
    for arch, shape in cells:
        for mesh_name in meshes:
            if (arch, shape, mesh_name, args.variant) in already:
                continue
            try:
                results.append(run_cell(arch, shape, mesh_name, knobs,
                                        variant=args.variant))
            except Exception as e:  # a failed cell is a bug; record + continue
                failures += 1
                traceback.print_exc()
                results.append({"arch": arch, "shape": shape, "mesh": mesh_name,
                                "status": "failed", "error": repr(e),
                                "variant": args.variant})
            flush()  # incremental: partial progress survives interruption
    if args.out:
        print(f"wrote {len(results)} cell results -> {args.out}")
    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    print(f"dryrun: {n_ok} ok, {n_skip} skipped (documented), {failures} FAILED")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
