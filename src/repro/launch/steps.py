"""Sharded step builders: train_step / prefill / decode_step per (arch, mesh).

These are the single source of truth for how a cell is executed: optimizer
choice, microbatching (grad accumulation), gradient clipping, activation
sharding context, and in/out shardings. The dry-run, the real trainer and
the serving runtime all build their jitted functions here.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.synthetic import input_specs
from repro.models import lm
from repro.optim import (
    AdafactorConfig,
    AdamConfig,
    adafactor_init,
    adafactor_update,
    adam_init,
    adam_update,
    clip_by_global_norm,
)
from repro.sharding import specs as S
from repro.sharding.ctx import ShardCtx, use_sharding


@dataclasses.dataclass(frozen=True)
class TrainKnobs:
    """Execution knobs independent of the architecture definition."""
    grad_clip: float = 1.0
    lr: float = 3e-4
    grad_accum_dtype: str = "float32"   # "bfloat16" = compressed accumulation
    donate: bool = True
    # statically unroll the grad-accumulation loop (dry-run cost probes:
    # HloCostAnalysis counts a scanned microbatch body once)
    unroll_microbatches: bool = False


def _dp_groups(mesh: Mesh, cfg: ModelConfig, shape: ShapeConfig) -> int:
    """MoE dispatch groups. One group per data shard keeps dispatch local,
    but below ~64 tokens/group the (MXU-aligned) capacity floor pads the
    expert GEMMs several-fold — there, a single global group (one small
    token all-gather) is cheaper. Decode cells take the g=1 path."""
    ax = S.mesh_axes(mesh, cfg.layout)
    dp = S._axsize(mesh, ax["dp"])
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train" else 1)
    if shape.global_batch % dp == 0 and tokens % dp == 0 and tokens // dp >= 64:
        return dp
    return 1


def _shard_ctx(mesh: Mesh, cfg: ModelConfig, shape: ShapeConfig) -> ShardCtx:
    ax = S.mesh_axes(mesh, cfg.layout)
    dp_size = S._axsize(mesh, ax["dp"])
    return ShardCtx(
        mesh=mesh,
        dp_axes=ax["dp"],
        tp_axis=ax["tp"],
        fsdp_axis=ax["fsdp"],
        seq_shard=cfg.seq_shard_activations and ax["tp"] is not None,
        batch_divisible=shape.global_batch % dp_size == 0,
    )


def make_optimizer(cfg: ModelConfig, knobs: TrainKnobs):
    if cfg.optimizer == "adafactor":
        ocfg = AdafactorConfig(lr=knobs.lr)
        return ocfg, partial(adafactor_init, cfg=ocfg), partial(adafactor_update, cfg=ocfg)
    ocfg = AdamConfig(lr=knobs.lr)
    return ocfg, partial(adam_init, cfg=ocfg), partial(adam_update, cfg=ocfg)


def param_and_opt_shapes(cfg: ModelConfig, knobs: TrainKnobs):
    """abstract (no-allocation) param/opt trees for lowering."""
    params = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    _, opt_init, _ = make_optimizer(cfg, knobs)
    opt = jax.eval_shape(lambda: opt_init(params))
    return params, opt


def build_train_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                     knobs: TrainKnobs = TrainKnobs()):
    """Returns (jitted_step, in_specs, out_specs). step(params, opt, batch)
    -> (params, opt, metrics)."""
    _, opt_init, opt_update = make_optimizer(cfg, knobs)
    dp_groups = _dp_groups(mesh, cfg, shape)
    ctx = _shard_ctx(mesh, cfg, shape)
    accum_dtype = jnp.dtype(knobs.grad_accum_dtype)
    m = max(cfg.num_microbatches, 1)

    def loss_fn(params, batch):
        total, metrics = lm.train_loss(params, batch, cfg, dp_groups)
        return total, metrics

    def step(params, opt_state, batch):
        with use_sharding(ctx):
            if m == 1:
                (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, batch)
            else:
                def split(x):
                    return x.reshape((m, x.shape[0] // m) + x.shape[1:])

                # M-RoPE positions carry a leading (3,) axis; split on batch
                mbatches = {k: split(v) for k, v in batch.items()
                            if k != "positions"}
                if "positions" in batch:
                    p = batch["positions"]
                    mbatches["positions"] = p.reshape(
                        (3, m, p.shape[1] // m) + p.shape[2:]).swapaxes(0, 1)

                def micro(acc, mb):
                    (l, met), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                    g = jax.tree.map(lambda a, b: a + b.astype(accum_dtype), acc[0], g)
                    return (g, acc[1] + l), met

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, accum_dtype), params)
                if knobs.unroll_microbatches:
                    acc, mets_list = (zeros, 0.0), []
                    for i in range(m):
                        acc, met = micro(acc, jax.tree.map(lambda x, i=i: x[i],
                                                           mbatches))
                        mets_list.append(met)
                    grads, loss_sum = acc
                    mets = jax.tree.map(lambda *xs: jnp.stack(xs), *mets_list)
                else:
                    (grads, loss_sum), mets = jax.lax.scan(
                        micro, (zeros, 0.0), mbatches)
                grads = jax.tree.map(lambda g: (g / m).astype(jnp.float32), grads)
                loss = loss_sum / m
                metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), mets)
            grads, gnorm = clip_by_global_norm(grads, knobs.grad_clip)
            new_params, new_opt = opt_update(params=params, grads=grads,
                                             opt_state=opt_state)
            metrics = dict(metrics)
            metrics["grad_norm"] = gnorm
            metrics["loss_total"] = loss
            return new_params, new_opt, metrics

    # shardings
    params_shapes, opt_shapes = param_and_opt_shapes(cfg, knobs)
    pspecs = S.param_specs(params_shapes, cfg, mesh)
    ospecs = S.opt_state_specs(opt_shapes, pspecs, cfg, mesh)
    bshapes = input_specs(cfg, shape)["batch"]
    bspecs = S.batch_specs(bshapes, cfg, shape, mesh)
    mspec = jax.tree.map(lambda _: NamedSharding(mesh, P()),
                         {"grad_norm": 0, "loss": 0, "aux_loss": 0,
                          "tokens": 0, "loss_total": 0})
    jitted = jax.jit(
        step,
        in_shardings=(pspecs, ospecs, bspecs),
        out_shardings=(pspecs, ospecs, mspec),
        donate_argnums=(0, 1) if knobs.donate else (),
    )
    return jitted, (pspecs, ospecs, bspecs), (pspecs, ospecs, mspec)


def build_prefill(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                  knobs: TrainKnobs = TrainKnobs()):
    dp_groups = _dp_groups(mesh, cfg, shape)
    ctx = _shard_ctx(mesh, cfg, shape)

    def step(params, batch):
        with use_sharding(ctx):
            return lm.prefill(params, batch, cfg, dp_groups,
                              max_seq=shape.seq_len)

    params_shapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    pspecs = S.param_specs(params_shapes, cfg, mesh)
    bshapes = input_specs(cfg, shape)["batch"]
    bspecs = S.batch_specs(bshapes, cfg, shape, mesh)
    cache_shapes = jax.eval_shape(
        lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len))
    cspecs = S.cache_specs(cache_shapes, cfg, shape, mesh)
    ax = S.mesh_axes(mesh, cfg.layout)
    dp = ctx.dp
    lspec = NamedSharding(mesh, P(dp, ax["tp"]))
    jitted = jax.jit(step, in_shardings=(pspecs, bspecs),
                     out_shardings=(cspecs, lspec))
    return jitted, (pspecs, bspecs), (cspecs, lspec)


def build_decode_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                      knobs: TrainKnobs = TrainKnobs()):
    dp_groups = _dp_groups(mesh, cfg, shape)
    ctx = _shard_ctx(mesh, cfg, shape)

    def step(params, cache, batch):
        with use_sharding(ctx):
            return lm.decode_step(params, cache, batch, cfg, dp_groups)

    params_shapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    pspecs = S.param_specs(params_shapes, cfg, mesh)
    specs_all = input_specs(cfg, shape)
    cspecs = S.cache_specs(specs_all["cache"], cfg, shape, mesh)
    bspecs = S.batch_specs(specs_all["batch"], cfg, shape, mesh)
    ax = S.mesh_axes(mesh, cfg.layout)
    lspec = NamedSharding(mesh, P(ctx.dp, ax["tp"]))
    jitted = jax.jit(step, in_shardings=(pspecs, cspecs, bspecs),
                     out_shardings=(cspecs, lspec),
                     donate_argnums=(1,) if knobs.donate else ())
    return jitted, (pspecs, cspecs, bspecs), (cspecs, lspec)


def build_for_shape(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                    knobs: TrainKnobs = TrainKnobs()):
    if shape.kind == "train":
        return build_train_step(cfg, mesh, shape, knobs)
    if shape.kind == "prefill":
        return build_prefill(cfg, mesh, shape, knobs)
    return build_decode_step(cfg, mesh, shape, knobs)


def lowering_inputs(cfg: ModelConfig, shape: ShapeConfig,
                    knobs: TrainKnobs = TrainKnobs()):
    """ShapeDtypeStruct argument tuple for .lower() per shape kind."""
    params_shapes, opt_shapes = param_and_opt_shapes(cfg, knobs)
    io = input_specs(cfg, shape)
    if shape.kind == "train":
        return (params_shapes, opt_shapes, io["batch"])
    if shape.kind == "prefill":
        return (params_shapes, io["batch"])
    return (params_shapes, io["cache"], io["batch"])
