"""Multi-edge cooperative serving driver.

Runs the event-driven cluster with a chosen scheduler (optionally a trained
CoRaiS checkpoint) under a synthetic open-loop workload, with optional
fault/straggler injection. Prints per-scheduler latency metrics.

    python -m repro.launch.serve --scheduler greedy --edges 5 --requests 200
    python -m repro.launch.serve --scheduler corais --policy-ckpt /tmp/corais
    python -m repro.launch.serve --scheduler greedy --fail-edge 0 --straggle 1:8
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.serving import CentralController, MultiEdgeSim, SimConfig


def build_controller(args) -> CentralController:
    if args.scheduler.startswith("corais"):
        from repro.checkpoint import Checkpointer
        from repro.core.policy import PolicyConfig, corais_init
        from repro.optim import AdamConfig, adam_init

        pcfg = PolicyConfig(d_model=args.policy_dim)
        template = jax.eval_shape(
            lambda: corais_init(jax.random.PRNGKey(0), pcfg))
        ckpt = Checkpointer(args.policy_ckpt, every=1)
        opt_template = jax.eval_shape(
            lambda: adam_init(template[0], AdamConfig()))
        restored = ckpt.restore_latest({"params": template[0],
                                        "state": template[1],
                                        "opt_state": opt_template})
        if restored is None:
            raise SystemExit(f"no checkpoint under {args.policy_ckpt}; train "
                             "one with: python -m repro.launch.train corais")
        return CentralController(
            scheduler=args.scheduler,
            policy_params=restored["tree"]["params"],
            policy_state=restored["tree"]["state"],
            policy_cfg=pcfg,
            z_pad=args.z_pad,
        )
    return CentralController(scheduler=args.scheduler)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scheduler", default="greedy",
                    choices=("greedy", "local", "random", "ils", "corais",
                             "corais-sample"))
    ap.add_argument("--edges", type=int, default=5)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--arrival-window", type=float, default=5.0)
    ap.add_argument("--until", type=float, default=240.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-edge", type=int, default=None)
    ap.add_argument("--fail-at", type=float, default=2.0)
    ap.add_argument("--straggle", default=None, help="edge:factor, e.g. 1:8")
    ap.add_argument("--policy-ckpt", default=None)
    ap.add_argument("--policy-dim", type=int, default=256)
    ap.add_argument("--z-pad", type=int, default=64)
    args = ap.parse_args()

    cc = build_controller(args)
    sim = MultiEdgeSim(SimConfig(num_edges=args.edges, seed=args.seed), cc)
    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        sim.submit(int(rng.integers(0, args.edges)),
                   float(rng.uniform(0.05, 1.0)),
                   t=float(rng.uniform(0, args.arrival_window)))
    if args.fail_edge is not None:
        sim.fail_edge(args.fail_edge, t=args.fail_at)
    if args.straggle:
        eid, factor = args.straggle.split(":")
        sim.set_straggler(int(eid), float(factor), t=0.0)
    m = sim.run(until=args.until)
    print(f"scheduler={args.scheduler}")
    for k, v in m.items():
        print(f"  {k}: {v}")
    if m.get("completed", 0) < args.requests:
        raise SystemExit("not all requests completed; increase --until")


if __name__ == "__main__":
    main()
