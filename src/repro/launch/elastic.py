import os

if "XLA_FLAGS" not in os.environ:
    # set BEFORE jax init; overridden by --devices via re-exec below
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Elastic-scaling demonstration: train -> checkpoint -> resume on a
DIFFERENT mesh size (node failure / pod resize), with bitwise-identical
parameters after resharding.

    python -m repro.launch.elastic --steps 8

Phase A trains a reduced LM on a (4, 2) mesh and checkpoints. Phase B
re-creates the world with HALF the devices (simulating a failed pod),
builds a (2, 2) mesh, restores the same checkpoint with the new shardings,
and continues training. The checkpoint layer stores host-gathered arrays
with logical paths, so any mesh that fits the divisibility rules works.
"""
import argparse
import subprocess
import sys

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import get_reduced_config
from repro.data.synthetic import SyntheticTokens
from repro.models import lm
from repro.optim import AdamConfig, adam_init, adam_update
from repro.sharding import specs as S


def run_phase(phase: str, mesh_shape, steps: int, ckpt_dir: str, arch: str):
    cfg = get_reduced_config(arch)
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    adam = AdamConfig(lr=1e-3)
    key = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(lambda: lm.init_params(key, cfg))
    pspecs = S.param_specs(params_shapes, cfg, mesh)
    opt_shapes = jax.eval_shape(lambda: adam_init(params_shapes, adam))
    ospecs = S.opt_state_specs(opt_shapes, pspecs, cfg, mesh)

    ckpt = Checkpointer(ckpt_dir, every=1, async_save=False)
    pipe = SyntheticTokens(cfg.vocab_size, batch=8, seq=32)
    restored = ckpt.restore_latest(
        {"params": params_shapes, "opt_state": opt_shapes},
        shardings={"params": pspecs, "opt_state": ospecs})
    if restored is None:
        params = jax.jit(lambda k: lm.init_params(k, cfg),
                         out_shardings=pspecs)(key)
        opt_state = jax.jit(lambda p: adam_init(p, adam),
                            out_shardings=ospecs)(params)
        start = 0
    else:
        params = restored["tree"]["params"]
        opt_state = restored["tree"]["opt_state"]
        pipe.load_state_dict(restored["extras"]["pipeline"])
        start = restored["step"]
        print(f"[{phase}] restored step {start} onto mesh {mesh_shape} "
              f"({len(jax.devices())} devices)")

    @jax.jit
    def step(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: lm.train_loss(p, batch, cfg, 1), has_aux=True)(params)
        params, opt_state = adam_update(params, grads, opt_state, adam)
        return params, opt_state, loss

    with mesh:
        for i in range(start, start + steps):
            batch = jax.tree.map(jnp.asarray, next(pipe))
            params, opt_state, loss = step(params, opt_state, batch)
            print(f"[{phase}] step {i} mesh={mesh_shape} loss={float(loss):.4f}")
    ckpt.save(start + steps, {"params": params, "opt_state": opt_state},
              extras={"pipeline": pipe.state_dict()})
    ckpt.wait()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--ckpt", default="/tmp/repro_elastic_ckpt")
    ap.add_argument("--phase", default=None, help="internal")
    ap.add_argument("--devices", type=int, default=None, help="internal")
    args = ap.parse_args()

    if args.phase == "A":
        run_phase("A", (4, 2), args.steps, args.ckpt, args.arch)
        return
    if args.phase == "B":
        run_phase("B", (2, 2), args.steps, args.ckpt, args.arch)
        return

    # orchestrate: phase A on 8 devices, phase B on 4 (simulated pod loss)
    import shutil
    shutil.rmtree(args.ckpt, ignore_errors=True)
    for phase, devs in (("A", 8), ("B", 4)):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devs}"
        cmd = [sys.executable, "-m", "repro.launch.elastic", "--phase", phase,
               "--steps", str(args.steps), "--ckpt", args.ckpt,
               "--arch", args.arch]
        print(f"== phase {phase}: {devs} devices ==")
        subprocess.run(cmd, check=True, env=env)
    print("elastic restart OK: trained, shrank the mesh 8->4, resumed.")


if __name__ == "__main__":
    main()
