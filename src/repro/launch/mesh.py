"""Production mesh construction (task spec MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (device count is locked at first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod = 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over the actually-available devices (tests, examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))
