"""Production mesh construction (task spec MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (device count is locked at first jax init).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod = 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1,
                   axis_names: tuple[str, str] = ("data", "model")):
    """Mesh over the actually-available devices (tests, examples).

    Raises ``ValueError`` (not ``assert``, which vanishes under ``python
    -O``) when the device count does not divide: the fleet mesh and every
    sharded test build on this helper, so a bad layout must fail loudly."""
    n = len(jax.devices())
    if model_parallel < 1 or n % model_parallel != 0:
        raise ValueError(
            f"cannot build a host mesh: {n} available device(s) not "
            f"divisible by model_parallel={model_parallel}")
    return jax.make_mesh((n // model_parallel, model_parallel), axis_names)


def make_fleet_mesh(num_shards: int | None = None, *, dry_run: bool = False):
    """1-D ``("fleet",)`` mesh for fleet-sharded rollouts
    (:mod:`repro.serving.fleet`).

    Locally this builds on :func:`make_host_mesh`: every available device
    lands on the fleet axis (``num_shards=None``), or the first
    ``num_shards`` devices do — the subset form exists for scaling curves
    (1, 2, 4, 8 shards on one forced 8-device host). With ``dry_run=True``
    the 256-chip :func:`make_production_mesh` pod is flattened onto one
    fleet axis (usable only under the dry-run harness that forces that many
    devices)."""
    if dry_run:
        prod = make_production_mesh()
        return Mesh(prod.devices.reshape(-1), ("fleet",))
    devices = jax.devices()
    n = len(devices)
    if num_shards is None or num_shards == n:
        host = make_host_mesh(1, axis_names=("fleet", "model"))
        return Mesh(host.devices.reshape(-1), ("fleet",))
    if not 1 <= num_shards <= n:
        raise ValueError(
            f"cannot build a fleet mesh with {num_shards} shard(s): "
            f"{n} device(s) available")
    return Mesh(np.asarray(devices[:num_shards]), ("fleet",))
