from repro.data.synthetic import (
    SyntheticTokens,
    input_specs,
    make_batch,
    make_decode_batch,
)

__all__ = ["SyntheticTokens", "input_specs", "make_batch", "make_decode_batch"]
