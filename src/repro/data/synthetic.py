"""Synthetic LM data: deterministic token pipeline + dry-run input specs.

``input_specs(cfg, shape)`` is the task-mandated ShapeDtypeStruct factory:
weak-type-correct stand-ins for every model input of a (arch x shape) cell,
with NO device allocation. ``make_batch`` builds real (small) numpy batches
with the same pytree structure for smoke tests and the training example.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.lm import init_cache


def _train_shapes(cfg: ModelConfig, batch: int, seq: int) -> dict:
    d = {}
    if cfg.encoder_decoder:
        d["embeds"] = ((batch, seq, cfg.d_model), jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
        d["tokens"] = ((batch, seq), jnp.int32)
    elif not cfg.embed_input:
        d["embeds"] = ((batch, seq, cfg.d_model), jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
        if cfg.mrope:
            d["positions"] = ((3, batch, seq), jnp.int32)
    else:
        d["tokens"] = ((batch, seq), jnp.int32)
    d["labels"] = ((batch, seq), jnp.int32)
    return d


def _prefill_shapes(cfg: ModelConfig, batch: int, seq: int) -> dict:
    d = _train_shapes(cfg, batch, seq)
    d.pop("labels")
    return d


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStruct pytree(s) for one (arch x shape) cell.

    train/prefill -> {"batch": ...}; decode -> {"cache": ..., "batch": ...}.
    """
    b, s = shape.global_batch, shape.seq_len

    def sds(d):
        return {k: jax.ShapeDtypeStruct(sh, dt) for k, (sh, dt) in d.items()}

    if shape.kind == "train":
        return {"batch": sds(_train_shapes(cfg, b, s))}
    if shape.kind == "prefill":
        return {"batch": sds(_prefill_shapes(cfg, b, s))}
    # decode: a cache filled to seq_len, one new token per sequence
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
    batch = {"token": jax.ShapeDtypeStruct((b,), jnp.int32)}
    if cfg.mrope:
        batch["positions"] = jax.ShapeDtypeStruct((3, b), jnp.int32)
    return {"cache": cache, "batch": batch}


def make_batch(rng: np.random.Generator, cfg: ModelConfig, batch: int, seq: int,
               kind: str = "train") -> dict:
    """Real numpy batch with the same structure as input_specs' train/prefill."""
    shapes = _train_shapes(cfg, batch, seq) if kind == "train" else _prefill_shapes(cfg, batch, seq)
    out = {}
    for k, (sh, dt) in shapes.items():
        if k in ("tokens",):
            out[k] = rng.integers(0, cfg.vocab_size, size=sh).astype(np.int32)
        elif k == "labels":
            out[k] = rng.integers(0, cfg.vocab_size, size=sh).astype(np.int32)
        elif k == "positions":
            pos = np.broadcast_to(np.arange(sh[-1], dtype=np.int32), sh).copy()
            out[k] = pos
        else:  # embeds
            out[k] = (0.02 * rng.standard_normal(size=sh)).astype(np.float32)
    return out


def make_decode_batch(rng: np.random.Generator, cfg: ModelConfig, batch: int) -> dict:
    out = {"token": rng.integers(0, cfg.vocab_size, size=(batch,)).astype(np.int32)}
    if cfg.mrope:
        out["positions"] = np.zeros((3, batch), np.int32)
    return out


@dataclasses.dataclass
class SyntheticTokens:
    """Deterministic, checkpointable synthetic token stream.

    Sequences are Zipf-ish draws seeded by (seed, step) so a restored
    pipeline resumes exactly where it left off (fault-tolerant training)."""

    vocab_size: int
    batch: int
    seq: int
    seed: int = 0
    step: int = 0

    def state_dict(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, d: dict) -> None:
        self.seed = int(d["seed"])
        self.step = int(d["step"])

    def __next__(self) -> dict:
        rng = np.random.default_rng((self.seed, self.step))
        self.step += 1
        z = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        toks = (z % self.vocab_size).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        return self
