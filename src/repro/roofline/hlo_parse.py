"""Parse collective traffic out of compiled (post-SPMD) HLO text.

``compiled.as_text()`` is the per-device program; result types of collective
ops give payload sizes and ``replica_groups`` gives the group size n. Wire
bytes per device follow ring-algorithm accounting:

    all-gather:          result * (n-1)/n       (each shard traverses ring)
    reduce-scatter:      result * (n-1)         (input = result*n)
    all-reduce:          result * 2*(n-1)/n     (RS + AG)
    all-to-all:          result * (n-1)/n
    collective-permute:  result                 (point-to-point)
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(bf16|f64|f32|f16|f8e4m3|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")
_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _types_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2  # conservative default


def collective_wire_bytes(hlo_text: str) -> dict:
    """Returns {op_kind: wire_bytes_per_device} + '_total' and '_payload'."""
    out = defaultdict(float)
    payload = defaultdict(float)
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        result_types, op = m.group(1), m.group(2)
        if "-done(" in line:
            continue  # count the -start, not the -done
        size = _types_bytes(result_types)
        n = _group_size(line)
        if n <= 1:
            continue
        if op == "all-gather":
            wire = size * (n - 1) / n
        elif op == "reduce-scatter":
            wire = size * (n - 1)
        elif op == "all-reduce":
            wire = size * 2 * (n - 1) / n
        elif op == "all-to-all":
            wire = size * (n - 1) / n
        else:  # collective-permute
            wire = size
        out[op] += wire
        payload[op] += size
    out["_total"] = sum(v for k, v in out.items() if not k.startswith("_"))
    out["_payload"] = sum(payload.values())
    return dict(out)


def count_ops(hlo_text: str) -> dict:
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if m and "-done(" not in line:
            counts[m.group(2)] += 1
    return dict(counts)
