from repro.roofline.hw import HW
from repro.roofline.hlo_parse import collective_wire_bytes
from repro.roofline.analysis import analyze_compiled, roofline_terms

__all__ = ["HW", "collective_wire_bytes", "analyze_compiled", "roofline_terms"]
