"""Target-hardware model (TPU v5e-like, constants from the task spec)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class HWModel:
    name: str = "tpu-v5e-like"
    peak_flops_bf16: float = 197e12      # FLOP/s per chip
    hbm_bw: float = 819e9                # bytes/s per chip
    ici_link_bw: float = 50e9            # bytes/s per link
    hbm_bytes: float = 16e9              # capacity per chip
    vmem_bytes: float = 128 * 1024**2


HW = HWModel()
