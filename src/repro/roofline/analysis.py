"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = per_device_HLO_FLOPs / peak_FLOP/s
    memory term     = per_device_HLO_bytes / HBM_bw
    collective term = per_device_wire_bytes / ICI_link_bw

``compiled.cost_analysis()`` on an SPMD executable reports per-device values
(the partitioned module is a per-device program), so no further division by
chip count is needed. MODEL_FLOPS is the analytic useful work (6*N*D for
training; 2*N_active*tokens for inference, + exact attention FLOPs), giving
the usefulness ratio MODEL_FLOPS / (HLO_FLOPs * chips).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import ModelConfig, ShapeConfig
from repro.roofline.hlo_parse import collective_wire_bytes, count_ops
from repro.roofline.hw import HW, HWModel


@dataclasses.dataclass
class CellReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    wire_bytes_per_device: float
    collective_ops: dict
    collective_breakdown: dict
    temp_bytes_per_device: float
    arg_bytes_per_device: float
    out_bytes_per_device: float
    model_flops: float
    params_total: float
    params_active: float
    compile_seconds: float
    variant: str = "baseline"

    def terms(self, hw: HWModel = HW) -> dict:
        t_comp = self.hlo_flops_per_device / hw.peak_flops_bf16
        t_mem = self.hlo_bytes_per_device / hw.hbm_bw
        # Floor: every argument byte (sharded params/opt/cache/inputs) read
        # once + outputs written once. The HLO bytes-accessed metric above
        # additionally counts CPU-backend converts/layout copies that a TPU
        # lowering fuses away, so it is an upper bound (see EXPERIMENTS.md
        # §Roofline notes).
        t_mem_floor = ((self.arg_bytes_per_device + self.out_bytes_per_device)
                       / hw.hbm_bw)
        t_coll = self.wire_bytes_per_device / hw.ici_link_bw
        dominant = max(
            (("compute", t_comp), ("memory", t_mem), ("collective", t_coll)),
            key=lambda kv: kv[1],
        )[0]
        total_hlo_flops = self.hlo_flops_per_device * self.chips
        return {
            "compute_s": t_comp,
            "memory_s": t_mem,
            "memory_floor_s": t_mem_floor,
            "collective_s": t_coll,
            "dominant": dominant,
            "bound_s": max(t_comp, t_mem, t_coll),
            "useful_flop_ratio": (self.model_flops / total_hlo_flops
                                  if total_hlo_flops else 0.0),
            "roofline_fraction": (
                t_comp / max(t_comp, t_mem, t_coll)
                if max(t_comp, t_mem, t_coll) > 0 else 0.0),
            "model_mfu_bound": (
                (self.model_flops / (self.chips * hw.peak_flops_bf16))
                / max(t_comp, t_mem, t_coll)
                if max(t_comp, t_mem, t_coll) > 0 else 0.0),
        }

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["terms"] = self.terms()
        return d


def _param_counts(cfg: ModelConfig, params_tree) -> tuple[float, float]:
    import jax

    total = 0
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_tree)[0]:
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
        if any("moe" in str(getattr(p, "key", "")) for p in path) and \
           not any("router" in str(getattr(p, "key", "")) for p in path):
            expert += n
    active = total
    if cfg.num_experts:
        active = total - expert * (cfg.num_experts - cfg.experts_per_token) / cfg.num_experts
    return float(total), float(active)


def model_flops(cfg: ModelConfig, shape: ShapeConfig, params_active: float) -> float:
    """Analytic useful FLOPs per step: matmul term + exact attention term."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0  # fwd 2 + bwd 4
        ctx = shape.seq_len
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0
        ctx = shape.seq_len
    else:  # decode: one token per sequence against a seq_len context
        tokens = shape.global_batch
        mult = 2.0
        ctx = shape.seq_len
    core = mult * params_active * tokens
    # attention score+value FLOPs: 4 * tokens * ctx_avg * H * hd per layer
    if cfg.family != "ssm" and cfg.num_heads:
        win = cfg.sliding_window
        if shape.kind == "decode":
            ctx_avg = min(ctx, win) if win else ctx
        else:
            ctx_avg = ctx / 2 if win is None else min(win, ctx / 2)
        attn = (mult / 2.0) * 4 * tokens * ctx_avg * cfg.num_heads * cfg.head_dim \
            * cfg.num_layers
        core += attn
    return core


def analyze_compiled(compiled, cfg: ModelConfig, shape: ShapeConfig,
                     mesh_name: str, chips: int, params_tree,
                     compile_seconds: float, variant: str = "baseline") -> CellReport:
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    wire = collective_wire_bytes(hlo)
    total, active = _param_counts(cfg, params_tree)
    return CellReport(
        arch=cfg.name,
        shape=shape.name,
        mesh=mesh_name,
        chips=chips,
        hlo_flops_per_device=float(ca.get("flops", 0.0)),
        hlo_bytes_per_device=float(ca.get("bytes accessed", 0.0)),
        wire_bytes_per_device=float(wire.get("_total", 0.0)),
        collective_ops=count_ops(hlo),
        collective_breakdown={k: v for k, v in wire.items() if not k.startswith("_")},
        temp_bytes_per_device=float(getattr(ma, "temp_size_in_bytes", 0)),
        arg_bytes_per_device=float(getattr(ma, "argument_size_in_bytes", 0)),
        out_bytes_per_device=float(getattr(ma, "output_size_in_bytes", 0)),
        model_flops=model_flops(cfg, shape, active),
        params_total=total,
        params_active=active,
        compile_seconds=compile_seconds,
        variant=variant,
    )


def roofline_terms(report: CellReport, hw: Optional[HWModel] = None) -> dict:
    return report.terms(hw or HW)
