"""Resilience subsystem: fault injection, admission control, circuit
breaking, and retry for the multi-edge engines.

``policies`` is leaf-level (pure jnp, imported by the batched engine);
``faults`` sits above the serving layer and is imported lazily by callers
(``from repro.resilience import faults``) so the package init itself stays
out of the engine's import path.
"""
from repro.resilience.policies import (ResilienceConfig, admission_mask,
                                       breaker_step, dispatch_mask,
                                       est_response, nearest_alive,
                                       probe_cap)

__all__ = [
    "ResilienceConfig", "admission_mask", "breaker_step", "dispatch_mask",
    "est_response", "nearest_alive", "probe_cap",
]
