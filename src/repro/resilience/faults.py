"""Fault processes for the multi-edge engines: failures, stragglers, jitter.

The serving stack simulates a fault-free world unless told otherwise; this
module is the single place faults are *described* and *materialized*. A
:class:`FaultSpec` is a pure description (scripted outages, Markov up/down
churn, straggler slowdowns, per-request runtime jitter); materialization
turns it into fixed-shape per-round event tensors that compose with the
jit/vmap batched engine, and into scheduled events for the event-driven
oracle — the same (spec, num_edges, num_rounds, seed) names the same fault
trajectory in both engines, which is what the chaos equivalence tests pin.

Event-tensor layout (R rounds, Q edges), mirroring ``workloads/batch.py``:

    alive (R, Q) bool   edge up-status in effect at scheduling round r
    speed (R, Q) f32    straggler runtime multiplier (1.0 = nominal)

Row ``r`` takes effect at the round-r scheduling instant — wall time
``(r+1) * round_interval`` — i.e. it governs the dispatch of window-r
arrivals and execution until the next round. :func:`schedule_into_sim`
realizes the same trajectory on a :class:`MultiEdgeSim` by pushing
fail/recover/straggle events at ``(r+1)*dt + FAULT_EPS``: after the
window's client arrivals, before the controller's scheduling round.

Per-request runtime jitter is keyed by the *global arrival index* (rid),
not by draw order, so a request keeps its jitter across retries and both
engines realize identical noise: :func:`jitter_table` builds the rid ->
multiplier lookup, :func:`attach_faults` folds it into the padded arrival
batch, and ``SimEdge.jitter_fn`` reads the same table in the oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

#: Oracle-side fault event offset past the round boundary: after the
#: window's arrivals (t <= boundary), before the CC round at boundary+1e-9.
FAULT_EPS = 5e-10

#: rng-stream salt keeping fault draws disjoint from the workload stream
#: (which uses (seed, 1_000_000_007)) and the cluster prior (seed).
_FAULT_SALT = 416_273_909
_JITTER_SALT = 86_028_121


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault process: everything is per scheduling round.

    Scripted and stochastic parts compose: scripted outages/stragglers are
    applied on top of the Markov draws, and ``min_alive`` is enforced last
    (a failure transition that would leave fewer than ``min_alive`` edges
    up is refused, deterministically in edge order).

    Fields:
      fail_prob / recover_prob      Markov up->down / down->up per round
      scripted_failures             ((edge, start_round, end_round), ...)
                                    edge is down for rounds [start, end)
      rolling                       (start_round, down_rounds): every edge
                                    in turn is down for ``down_rounds``
                                    rounds beginning at ``start_round``
      straggle_prob / straggle_recover_prob   Markov straggler churn
      straggle_factor               runtime multiplier while straggling
      scripted_stragglers           ((edge, start, end, factor), ...)
      jitter_sigma                  lognormal sigma of per-request runtime
                                    jitter (0 = deterministic runtimes)
      min_alive                     floor on simultaneously-alive edges
    """

    fail_prob: float = 0.0
    recover_prob: float = 0.25
    scripted_failures: tuple = ()
    rolling: Optional[tuple] = None
    straggle_prob: float = 0.0
    straggle_recover_prob: float = 0.5
    straggle_factor: float = 4.0
    scripted_stragglers: tuple = ()
    jitter_sigma: float = 0.0
    min_alive: int = 1

    @property
    def has_faults(self) -> bool:
        return bool(self.fail_prob or self.scripted_failures or self.rolling
                    or self.straggle_prob or self.scripted_stragglers
                    or self.jitter_sigma)


def fault_rng(seed: int) -> np.random.Generator:
    """The canonical fault-event stream for ``seed`` (disjoint from the
    workload and cluster streams by salt)."""
    return np.random.default_rng((seed, _FAULT_SALT))


def materialize_faults(spec: FaultSpec, num_edges: int, num_rounds: int,
                       *, seed: int = 0) -> dict:
    """Materialize a fault trajectory as per-round event tensors.

    Returns ``{"alive": (R, Q) bool, "speed": (R, Q) float32}``.
    Deterministic in (spec, num_edges, num_rounds, seed).
    """
    rng = fault_rng(seed)
    alive = np.ones((num_rounds, num_edges), bool)
    speed = np.ones((num_rounds, num_edges), np.float32)

    up = np.ones(num_edges, bool)
    straggling = np.zeros(num_edges, bool)
    for r in range(num_rounds):
        # Markov churn (draw per edge every round so the stream consumed is
        # independent of the current state -> trajectories stay comparable
        # across specs with the same seed)
        u_fail = rng.random(num_edges)
        u_rec = rng.random(num_edges)
        for q in range(num_edges):
            if up[q]:
                if u_fail[q] < spec.fail_prob and up.sum() > spec.min_alive:
                    up[q] = False
            elif u_rec[q] < spec.recover_prob:
                up[q] = True
        u_str = rng.random(num_edges)
        u_strrec = rng.random(num_edges)
        straggling = np.where(
            straggling, u_strrec >= spec.straggle_recover_prob,
            u_str < spec.straggle_prob)
        alive[r] = up
        speed[r] = np.where(straggling, spec.straggle_factor, 1.0)

    # scripted outages / stragglers override the Markov draws
    scripted = list(spec.scripted_failures)
    if spec.rolling is not None:
        start, dur = spec.rolling
        scripted += [(q, start + q * dur, start + (q + 1) * dur)
                     for q in range(num_edges)]
    for q, lo, hi in scripted:
        alive[max(lo, 0):hi, q % num_edges] = False
    for q, lo, hi, factor in spec.scripted_stragglers:
        speed[max(lo, 0):hi, q % num_edges] = factor

    # min_alive floor: refuse the highest-indexed scripted kills last
    for r in range(num_rounds):
        short = spec.min_alive - int(alive[r].sum())
        if short > 0:
            dead = np.flatnonzero(~alive[r])
            alive[r, dead[:short]] = True
    return {"alive": alive, "speed": speed.astype(np.float32)}


def jitter_table(spec: FaultSpec, num_requests: int, *, seed: int = 0
                 ) -> np.ndarray:
    """Per-rid runtime jitter multipliers, lognormal(0, sigma), floored at
    the shared :data:`repro.serving.rounds.MIN_JITTER` contract."""
    # deferred: importing serving at module scope closes an import cycle
    # (workloads.scenarios -> faults -> serving -> core.train -> workloads)
    from repro.serving.rounds import MIN_JITTER

    if not spec.jitter_sigma:
        return np.ones(num_requests, np.float32)
    rng = np.random.default_rng((seed, _JITTER_SALT))
    j = np.exp(spec.jitter_sigma * rng.standard_normal(num_requests))
    return np.maximum(j, MIN_JITTER).astype(np.float32)


def attach_faults(arrivals: dict, events: dict,
                  jitter_by_rid: Optional[np.ndarray] = None) -> dict:
    """Fold a materialized fault trajectory into a padded arrival batch
    (the dict from ``workloads.batch.materialize_rounds``): adds ``alive``
    and ``speed`` rows plus a per-slot ``jitter`` lookup by rid. The result
    feeds ``engine.make_rollout`` unchanged — the engine switches into
    fault mode when the keys are present."""
    num_rounds = arrivals["mask"].shape[-2]
    if events["alive"].shape[0] < num_rounds:
        raise ValueError(
            f"fault events cover {events['alive'].shape[0]} rounds but the "
            f"arrival batch holds {num_rounds}")
    out = dict(arrivals)
    out["alive"] = events["alive"][:num_rounds]
    out["speed"] = events["speed"][:num_rounds]
    if jitter_by_rid is not None:
        rid = np.asarray(arrivals["rid"])
        table = np.asarray(jitter_by_rid, np.float32)
        jit = table[np.clip(rid, 0, len(table) - 1)]
        out["jitter"] = np.where(np.asarray(arrivals["mask"]), jit,
                                 1.0).astype(np.float32)
    return out


def attach_fault_batch(arrivals: dict, spec: FaultSpec, num_edges: int,
                       *, seeds) -> dict:
    """Batched :func:`attach_faults`: one independent fault trajectory per
    batch element (arrivals (B, R, A) from ``materialize_round_batch``,
    one seed per element)."""
    seeds = list(seeds)
    batch, num_rounds = arrivals["mask"].shape[0], arrivals["mask"].shape[1]
    if len(seeds) != batch:
        raise ValueError(f"{len(seeds)} fault seeds for batch {batch}")
    merged = []
    for i, s in enumerate(seeds):
        one = {k: np.asarray(v[i]) for k, v in arrivals.items()}
        ev = materialize_faults(spec, num_edges, num_rounds, seed=int(s))
        n_rid = int(one["rid"].max()) + 1 if one["mask"].any() else 1
        jit = (jitter_table(spec, n_rid, seed=int(s))
               if spec.jitter_sigma else None)
        merged.append(attach_faults(one, ev, jit))
    return {k: np.stack([m[k] for m in merged]) for k in merged[0]}


# -- device-resident fault materialization (pure jax.random) -----------------

def _scripted_overrides(spec: FaultSpec, num_edges: int,
                        num_rounds: int) -> tuple:
    """Static (host numpy) parts of a fault trajectory: scripted/rolling
    outage masks and scripted straggler overrides, identical to the
    override pass in :func:`materialize_faults`."""
    alive_ok = np.ones((num_rounds, num_edges), bool)
    scripted = list(spec.scripted_failures)
    if spec.rolling is not None:
        start, dur = spec.rolling
        scripted += [(q, start + q * dur, start + (q + 1) * dur)
                     for q in range(num_edges)]
    for q, lo, hi in scripted:
        alive_ok[max(lo, 0):hi, q % num_edges] = False
    speed_mask = np.zeros((num_rounds, num_edges), bool)
    speed_val = np.ones((num_rounds, num_edges), np.float32)
    for q, lo, hi, factor in spec.scripted_stragglers:
        speed_mask[max(lo, 0):hi, q % num_edges] = True
        speed_val[max(lo, 0):hi, q % num_edges] = factor
    return alive_ok, speed_mask, speed_val


def materialize_faults_device(spec: FaultSpec, num_edges: int,
                              num_rounds: int, key) -> dict:
    """Device twin of :func:`materialize_faults`: same fault laws (Markov
    fail/recover with the min_alive refusal in edge order, straggler churn,
    scripted/rolling overrides, min_alive floor), drawn with ``jax.random``
    inside the trace. Distributionally equivalent to the host path, not
    draw-for-draw — the chaos *equivalence* tests keep pinning the host
    tensors; this path exists so training episodes stay on device."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    Q, R = num_edges, num_rounds
    alive_ok, spd_mask, spd_val = _scripted_overrides(spec, Q, R)
    alive_ok = jnp.asarray(alive_ok)
    spd_mask, spd_val = jnp.asarray(spd_mask), jnp.asarray(spd_val)

    def markov_fail(up, u_fail, u_rec):
        # sequential in edge order: each failure sees the up-count left by
        # the previous edges' transitions, exactly as the host loop does
        def body(q, up):
            can_fail = (up[q] & (u_fail[q] < spec.fail_prob)
                        & (jnp.sum(up) > spec.min_alive))
            rec = (~up[q]) & (u_rec[q] < spec.recover_prob)
            return up.at[q].set(jnp.where(can_fail, False,
                                          jnp.where(rec, True, up[q])))
        return lax.fori_loop(0, Q, body, up)

    def round_body(carry, xs):
        up, straggling = carry
        kr, ok_row, sm_row, sv_row = xs
        k1, k2, k3, k4 = jax.random.split(kr, 4)
        if spec.fail_prob:
            up = markov_fail(up, jax.random.uniform(k1, (Q,)),
                             jax.random.uniform(k2, (Q,)))
        if spec.straggle_prob:
            straggling = jnp.where(
                straggling,
                jax.random.uniform(k4, (Q,)) >= spec.straggle_recover_prob,
                jax.random.uniform(k3, (Q,)) < spec.straggle_prob)
        row = up & ok_row
        # min_alive floor: revive the lowest-indexed dead edges
        short = spec.min_alive - jnp.sum(row)
        dead_rank = jnp.cumsum(~row)          # 1-based rank among dead
        row = row | (~row & (dead_rank <= short))
        speed_row = jnp.where(straggling, spec.straggle_factor, 1.0)
        speed_row = jnp.where(sm_row, sv_row, speed_row)
        return (up, straggling), (row, speed_row.astype(jnp.float32))

    keys = jax.random.split(key, R)
    _, (alive, speed) = lax.scan(
        round_body, (jnp.ones(Q, bool), jnp.zeros(Q, bool)),
        (keys, alive_ok, spd_mask, spd_val))
    return {"alive": alive, "speed": speed}


def attach_fault_batch_device(arrivals: dict, spec: FaultSpec,
                              num_edges: int, keys) -> dict:
    """Device twin of :func:`attach_fault_batch`: one independent in-jit
    fault trajectory per batch element ((B, 2) ``keys``, one per element),
    plus per-slot runtime jitter drawn directly per slot — retries reuse the
    engine's stored ``slot_jitter``, so a per-slot draw realizes the same
    law as the host's rid-keyed table without materializing it."""
    import jax
    import jax.numpy as jnp

    from repro.serving.rounds import MIN_JITTER

    num_rounds = arrivals["mask"].shape[-2]

    def one(key, mask):
        k_ev, k_jit = jax.random.split(key)
        ev = materialize_faults_device(spec, num_edges, num_rounds, k_ev)
        out = dict(ev)
        if spec.jitter_sigma:
            j = jnp.exp(spec.jitter_sigma
                        * jax.random.normal(k_jit, mask.shape))
            out["jitter"] = jnp.where(mask, jnp.maximum(j, MIN_JITTER),
                                      1.0).astype(jnp.float32)
        return out

    extra = jax.vmap(one)(keys, arrivals["mask"])
    return {**{k: jnp.asarray(v) for k, v in arrivals.items()}, **extra}


def fault_events_from_rows(events: dict, round_interval: float) -> tuple:
    """Flatten materialized per-round event tensors into the absolute-time
    :class:`repro.workloads.trace.FaultEvent` timeline a v2 trace records:
    one event per alive/speed *transition*, stamped at the round boundary
    it takes effect (``(r+1)*dt + FAULT_EPS``)."""
    from repro.workloads.trace import FaultEvent

    alive, speed = np.asarray(events["alive"]), np.asarray(events["speed"])
    num_rounds, num_edges = alive.shape
    prev_alive = np.ones(num_edges, bool)
    prev_speed = np.ones(num_edges, np.float32)
    out = []
    for r in range(num_rounds):
        t = (r + 1) * round_interval + FAULT_EPS
        # within a round: recoveries, then speed changes, then failures —
        # a fail event's orphan failover must see every same-round recovery
        # already applied (the batched engine applies the row atomically)
        for q in range(num_edges):
            if not prev_alive[q] and alive[r, q]:
                out.append(FaultEvent(t=t, kind="recover", edge=q))
        for q in range(num_edges):
            if speed[r, q] != prev_speed[q]:
                out.append(FaultEvent(t=t, kind="straggle", edge=q,
                                      factor=float(speed[r, q])))
        for q in range(num_edges):
            if prev_alive[q] and not alive[r, q]:
                out.append(FaultEvent(t=t, kind="fail", edge=q))
        prev_alive, prev_speed = alive[r], speed[r]
    return tuple(out)


def schedule_fault_events(sim, fault_events) -> None:
    """Push a :class:`FaultEvent` timeline (e.g. from a v2 trace's
    ``fault_events``) onto a ``MultiEdgeSim``."""
    for ev in fault_events:
        if ev.kind == "fail":
            sim.fail_edge(ev.edge, ev.t)
        elif ev.kind == "recover":
            sim.recover_edge(ev.edge, ev.t)
        else:
            sim.set_straggler(ev.edge, float(ev.factor), ev.t)


def schedule_into_sim(sim, events: dict, round_interval: float,
                      jitter_by_rid: Optional[np.ndarray] = None) -> None:
    """Realize a materialized fault trajectory on a ``MultiEdgeSim``: push
    fail/recover/straggle events at ``(r+1)*dt + FAULT_EPS`` (row r takes
    effect at the round-r scheduling instant, exactly as in the batched
    engine) and pin per-request jitter to the shared rid table."""
    schedule_fault_events(sim, fault_events_from_rows(events, round_interval))
    if jitter_by_rid is not None:
        table = np.asarray(jitter_by_rid, np.float32)

        def fn(rid, _table=table):
            return float(_table[min(int(rid), len(_table) - 1)])

        for e in sim.edges:
            e.jitter_fn = fn
