"""Resilience mechanisms as pure array ops on engine state.

Three mechanisms, all jit/vmap-safe and all driven from
``serving/engine.py::step_round`` when an :class:`ResilienceConfig` is set
on the engine config:

* **Admission control** — per-request admit/shed at dispatch time.
  Heuristic baselines live here (``slo_threshold`` sheds requests whose
  estimated response exceeds a bound; ``queue_depth`` sheds when the target
  edge's backlog is too deep); the *trained* admission head
  (``admission="policy"``) is produced by the policy itself — see
  ``core/policy.py::corais_admit`` — and arrives at the engine as the
  second element of the assign-fn's return value.
* **Circuit breaking** — an edge that dies trips a breaker with an
  exponentially growing cooldown; while open the edge is masked out of the
  dispatch instance entirely, and when the cooldown lapses the breaker is
  *half-open*: at most ``breaker_probe`` requests per round may probe it
  until it has stayed healthy for ``breaker_reset_rounds`` rounds.
* **Retry with backoff** — requests orphaned by an edge failure are
  re-admitted at the nearest alive edge (the oracle's failover rule,
  :func:`repro.serving.topology.nearest_alive_edge`, as an argmin); with
  ``retry_backoff_rounds > 0`` each successive retry of the same request
  additionally waits an exponentially growing number of rounds.

This module deliberately imports nothing from ``repro.serving`` — the
engine imports it, and keeping it leaf-level keeps the package import
graph acyclic.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Resilience mechanisms toggled on an ``EngineConfig``.

    ``admission`` selects the shed rule: ``"none"`` (admit everything),
    ``"slo_threshold"`` / ``"queue_depth"`` (heuristics below), or
    ``"policy"`` (the assign fn supplies an admit mask; the engine falls
    back to admit-all if it returns only assignments). ``slo`` is the
    response-time objective used for violation metrics and the
    slo_threshold heuristic's default bound."""

    admission: str = "none"
    admit_threshold: float = 0.0   # slo_threshold bound; 0 -> use ``slo``
    queue_depth: float = 2.0       # max per-replica backlog (phi-seconds)
    slo: float = 3.0               # response-time SLO (seconds)
    retry_backoff_rounds: float = 0.0
    retry_backoff_cap: int = 6
    breaker: bool = False
    breaker_cooldown_rounds: float = 2.0
    breaker_backoff_cap: int = 4
    breaker_reset_rounds: int = 4
    breaker_probe: int = 1

    def __post_init__(self):
        if self.admission not in ("none", "slo_threshold", "queue_depth",
                                  "policy"):
            raise ValueError(f"unknown admission rule {self.admission!r}")


def nearest_alive(w, alive, idx):
    """Failover target per index: the nearest alive edge by distance row
    ``w[idx]`` (itself when alive — w's diagonal is zero). Array twin of
    ``repro.serving.topology.nearest_alive_edge``: both resolve distance
    ties to the lowest edge index. ``alive`` must have at least one edge up
    (FaultSpec.min_alive guarantees it for materialized trajectories)."""
    d = jnp.where(alive[None, :], w[idx], jnp.inf)
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


def est_response(inst, assign):
    """Cheap response-time estimate for dispatching each pending request to
    its assigned edge, from the information a real CC has: eq (2) transfer
    delay, the target's per-replica backlog (c_le + c_in workload features),
    and the phi-estimate execution time."""
    assign = assign.astype(jnp.int32)
    src = inst["req_src"].astype(jnp.int32)
    size = inst["req_size"]
    transfer = jnp.where(assign == src, 0.0,
                         inst["ct"] * size * inst["w"][src, assign])
    backlog = (inst["workload"][..., 0] + inst["workload"][..., 1])[assign]
    exec_t = inst["phi"][assign, 0] * size + inst["phi"][assign, 1]
    return transfer + backlog + exec_t


def admission_mask(res: ResilienceConfig, inst, assign):
    """Heuristic admit mask (A,) bool for this round's pending requests.
    ``"policy"`` admission is decided by the policy head, not here."""
    if res.admission in ("none", "policy"):
        return jnp.ones_like(inst["req_mask"])
    if res.admission == "slo_threshold":
        bound = res.admit_threshold if res.admit_threshold > 0 else res.slo
        return est_response(inst, assign) <= bound
    # queue_depth: shed when the target's backlog is already too deep
    backlog = (inst["workload"][..., 0] + inst["workload"][..., 1])
    return backlog[assign.astype(jnp.int32)] <= res.queue_depth


# -- circuit breaker ---------------------------------------------------------


def breaker_step(open_until, trips, healthy, died, alive, t, dt,
                 res: ResilienceConfig):
    """One round of breaker bookkeeping at fault-application time ``t``.

    A death trips the breaker with cooldown ``cooldown * 2^(trips-1)``
    rounds (capped); an edge that is alive with a lapsed cooldown counts a
    healthy round, and ``breaker_reset_rounds`` consecutive healthy rounds
    reset its trip counter (half-open -> closed)."""
    trips = trips + died.astype(jnp.float32)
    backoff = jnp.exp2(jnp.clip(trips - 1.0, 0.0,
                                float(res.breaker_backoff_cap)))
    cooldown = res.breaker_cooldown_rounds * dt * backoff
    open_until = jnp.where(died, t + cooldown, open_until)
    healthy = jnp.where(alive & (t >= open_until), healthy + 1.0, 0.0)
    trips = jnp.where(healthy >= res.breaker_reset_rounds, 0.0, trips)
    return open_until, trips, healthy


def dispatch_mask(alive, open_until, t):
    """Edges eligible for dispatch: alive with no open breaker. Falls back
    to plain liveness if every alive edge is behind an open breaker (the
    system must keep serving)."""
    m = alive & (t >= open_until)
    return jnp.where(jnp.any(m), m, alive)


def probe_cap(w, assign, req_mask, src, half_open, closed,
              res: ResilienceConfig):
    """Cap dispatches to half-open edges at ``breaker_probe`` per round:
    excess requests fail over to the nearest fully-closed edge (in slot
    order, so the first arrivals get the probes). No-op when no closed
    edge exists."""
    assign = assign.astype(jnp.int32)
    num_edges = w.shape[-1]
    onehot = ((assign[:, None] == jnp.arange(num_edges)[None, :])
              & req_mask[:, None])
    nth = jnp.take_along_axis(jnp.cumsum(onehot, axis=0),
                              assign[:, None], axis=1)[:, 0]
    over = half_open[assign] & (nth > res.breaker_probe) & req_mask
    fallback = nearest_alive(w, closed, src.astype(jnp.int32))
    return jnp.where(over & jnp.any(closed), fallback, assign)
