"""Workload & scenario subsystem: arrival processes, trace record/replay,
and a named scenario registry driving the simulator, instance sampling for
training, and the benchmark sweep."""
from repro.workloads.base import (Arrival, Merged, ServiceMix, SizeSpec,
                                  Workload, edge_weights, merge, workload_rng)
from repro.workloads.batch import (DEADLINE_INF, compile_device_plan,
                                   materialize_round_batch,
                                   materialize_round_batch_device,
                                   materialize_rounds)
from repro.workloads.processes import (DiurnalArrivals, FlashCrowdArrivals,
                                       InhomogeneousPoisson, MMPPArrivals,
                                       PoissonArrivals)
from repro.workloads.trace import (SCHEMA, SCHEMA_V1, SCHEMA_V2, SCHEMA_V3,
                                   FaultEvent, TraceWorkload, read_trace,
                                   record_trace, write_trace)
from repro.workloads.scenarios import (ScenarioSpec,
                                       instance_config_for_scenario,
                                       list_scenarios, register_scenario,
                                       scenario, scenario_cloud_spec,
                                       scenario_fault_spec, scenario_spec)

__all__ = [
    "Arrival", "Merged", "ServiceMix", "SizeSpec", "Workload", "edge_weights",
    "merge", "workload_rng", "DEADLINE_INF", "materialize_rounds",
    "materialize_round_batch", "materialize_round_batch_device",
    "compile_device_plan",
    "PoissonArrivals", "InhomogeneousPoisson", "DiurnalArrivals",
    "FlashCrowdArrivals", "MMPPArrivals",
    "SCHEMA", "SCHEMA_V1", "SCHEMA_V2", "SCHEMA_V3", "FaultEvent",
    "TraceWorkload", "read_trace", "record_trace", "write_trace",
    "ScenarioSpec", "register_scenario", "scenario", "scenario_spec",
    "scenario_fault_spec", "scenario_cloud_spec", "list_scenarios",
    "instance_config_for_scenario",
]
