"""Workload & scenario subsystem: arrival processes, trace record/replay,
and a named scenario registry driving the simulator, instance sampling for
training, and the benchmark sweep."""
from repro.workloads.base import (Arrival, Merged, SizeSpec, Workload,
                                  edge_weights, merge, workload_rng)
from repro.workloads.batch import materialize_round_batch, materialize_rounds
from repro.workloads.processes import (DiurnalArrivals, FlashCrowdArrivals,
                                       InhomogeneousPoisson, MMPPArrivals,
                                       PoissonArrivals)
from repro.workloads.trace import (SCHEMA, SCHEMA_V1, SCHEMA_V2, FaultEvent,
                                   TraceWorkload, read_trace, record_trace,
                                   write_trace)
from repro.workloads.scenarios import (ScenarioSpec,
                                       instance_config_for_scenario,
                                       list_scenarios, register_scenario,
                                       scenario, scenario_fault_spec,
                                       scenario_spec)

__all__ = [
    "Arrival", "Merged", "SizeSpec", "Workload", "edge_weights", "merge",
    "workload_rng", "materialize_rounds", "materialize_round_batch",
    "PoissonArrivals", "InhomogeneousPoisson", "DiurnalArrivals",
    "FlashCrowdArrivals", "MMPPArrivals",
    "SCHEMA", "SCHEMA_V1", "SCHEMA_V2", "FaultEvent", "TraceWorkload",
    "read_trace", "record_trace", "write_trace",
    "ScenarioSpec", "register_scenario", "scenario", "scenario_spec",
    "scenario_fault_spec", "list_scenarios", "instance_config_for_scenario",
]
