"""Workload description layer: arrivals, size laws, and the Workload protocol.

The paper evaluates CoRaiS on i.i.d. uniform request sets (§V.A); real
multi-edge traffic is bursty, diurnal, and skewed. This module is the
vocabulary everything else shares:

* :class:`Arrival` — one request brief hitting one edge at one time.
* :class:`Workload` — anything that can produce a time-ordered arrival
  stream for a cluster of ``num_edges`` edges (generators in
  ``processes.py``, recorded traces in ``trace.py``).
* :class:`SizeSpec` — named data-size distributions (uniform / pareto /
  lognormal / fixed), shared between arrival generators and the static
  instance sampler in ``core/instances.py`` so training and serving draw
  from the same laws.
* :func:`edge_weights` — Zipf-style per-edge popularity skew.
* :func:`merge` — superpose independent workloads into one stream.

Everything is deterministic given the caller's ``numpy.random.Generator``:
the same seed always yields the same arrival sequence, which is what makes
trace record/replay and paired scheduler comparisons exact.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Iterator, Protocol, runtime_checkable

import numpy as np


@dataclasses.dataclass(frozen=True, order=True)
class Arrival:
    """One request brief: at time ``t`` a client of edge ``edge`` submits a
    request of input data size ``size`` for service ``service``.

    Schema-v3 fields (``corais.trace.v3``): ``deadline`` is a *relative*
    response-time budget in seconds (the request's hard SLO is
    ``t + deadline``; 0.0 = no deadline) and ``priority`` is a small
    non-negative importance level (0 = default). Both default to their
    "absent" values so v1/v2 traces and pre-v3 generators are unchanged."""

    t: float
    edge: int
    size: float
    service: int = 0
    deadline: float = 0.0
    priority: int = 0


@runtime_checkable
class Workload(Protocol):
    """A source of arrivals over the horizon [0, until]."""

    def arrivals(self, rng: np.random.Generator, num_edges: int,
                 until: float) -> Iterator[Arrival]:
        """Yield arrivals in nondecreasing time order, all with t <= until."""
        ...


# -- data-size distributions -------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SizeSpec:
    """A named data-size law. ``dist`` selects the family, ``params`` its
    parameters; every family is clipped to (0, cap] so sizes stay on the
    scale the policy/objective were built for (paper sizes are U(0,1)).

    Families:
      uniform(lo=0, hi=1)
      fixed(value)
      pareto(alpha=1.5, scale=0.05)   heavy tail, mean scale*alpha/(alpha-1)
      lognormal(mu=-1.5, sigma=0.8)
    """

    dist: str = "uniform"
    params: tuple = ()
    cap: float = 1.0

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        p = self.params
        if self.dist == "uniform":
            lo, hi = p if p else (0.0, 1.0)
            out = rng.uniform(lo, hi, size=n)
        elif self.dist == "fixed":
            (value,) = p if p else (0.5,)
            out = np.full(n, value, float)
        elif self.dist == "pareto":
            alpha, scale = p if p else (1.5, 0.05)
            out = scale * (1.0 + rng.pareto(alpha, size=n))
        elif self.dist == "lognormal":
            mu, sigma = p if p else (-1.5, 0.8)
            out = rng.lognormal(mu, sigma, size=n)
        else:
            raise ValueError(f"unknown size distribution {self.dist!r}")
        return np.clip(out, 1e-6, self.cap).astype(np.float64)

    def sample_one(self, rng: np.random.Generator) -> float:
        return float(self.sample(rng, 1)[0])


def workload_rng(seed: int) -> np.random.Generator:
    """The canonical generator stream for materializing a workload from
    ``seed``. Both :meth:`MultiEdgeSim.drive` and :func:`record_trace`
    derive it this way, so recording a workload under a seed captures
    exactly the arrivals a live drive under that seed would generate. The
    (seed, constant) key keeps it disjoint from the simulator's topology
    rng (seed) and per-edge rngs ((seed, edge_id))."""
    return np.random.default_rng((seed, 1_000_000_007))


# -- per-edge popularity -----------------------------------------------------

def edge_weights(num_edges: int, skew: float = 0.0,
                 hot_edge: int = 0) -> np.ndarray:
    """Zipf-style edge popularity: weight of the k-th most popular edge is
    (k+1)^-skew. ``skew=0`` is uniform; the hottest rank sits at
    ``hot_edge`` and the rest follow in index order."""
    ranks = np.arange(num_edges, dtype=np.float64)
    w = (ranks + 1.0) ** (-float(skew))
    w = np.roll(w, hot_edge % num_edges)
    return w / w.sum()


def pick_edge(rng: np.random.Generator, probs: np.ndarray) -> int:
    return int(rng.choice(len(probs), p=probs))


# -- service mixes (schema v3) ----------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServiceMix:
    """Wrap any workload with a per-request service law plus optional
    deadline / priority draws — the schema-v3 vocabulary for the edge–cloud
    tier (service caches key on ``service``; deadlines become hard SLOs).

    Services are drawn Zipf-style: popularity of the k-th service is
    (k+1)^-skew (skew=0 uniform). ``deadline=(lo, hi)`` attaches a uniform
    relative response budget to a ``deadline_frac`` fraction of requests;
    ``priorities`` is a weight vector over levels 0..len-1. Draws interleave
    deterministically with the inner generator's rng consumption, so the
    same seed still yields the same stream everywhere (materialized batches,
    ``MultiEdgeSim.drive``, recorded traces)."""

    inner: Workload
    num_services: int = 8
    skew: float = 1.0
    deadline: tuple = ()
    deadline_frac: float = 1.0
    priorities: tuple = ()

    def arrivals(self, rng, num_edges, until):
        ranks = np.arange(max(1, self.num_services), dtype=np.float64)
        probs = (ranks + 1.0) ** (-float(self.skew))
        probs = probs / probs.sum()
        prio_w = np.asarray(self.priorities, np.float64)
        if prio_w.size:
            prio_w = prio_w / prio_w.sum()
        for a in self.inner.arrivals(rng, num_edges, until):
            service = int(rng.choice(len(probs), p=probs))
            d = 0.0
            if self.deadline:
                lo, hi = self.deadline
                take = (self.deadline_frac >= 1.0
                        or rng.random() < self.deadline_frac)
                if take:
                    d = float(rng.uniform(lo, hi))
            pr = int(rng.choice(prio_w.size, p=prio_w)) if prio_w.size else 0
            yield dataclasses.replace(a, service=service, deadline=d,
                                      priority=pr)


# -- composition -------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Merged:
    """Superposition of independent workloads (e.g. steady base traffic plus
    a flash-crowd spike). Each component gets its own child generator spawned
    deterministically from the caller's rng, so the merged stream is as
    reproducible as its parts."""

    parts: tuple

    def arrivals(self, rng, num_edges, until):
        streams = []
        for part in self.parts:
            child = np.random.default_rng(int(rng.integers(0, 2**63)))
            streams.append(part.arrivals(child, num_edges, until))
        yield from heapq.merge(*streams)


def merge(*parts: Workload) -> Workload:
    return Merged(parts=tuple(parts))
