"""Composable arrival-process generators behind the :class:`Workload` protocol.

Each generator is a frozen dataclass (a pure description — cheap to build,
hashable, trivially loggable) whose ``arrivals(rng, num_edges, until)``
yields time-ordered :class:`Arrival` events. Rates are *system-wide*
expected arrivals per unit time; per-edge placement is controlled by
``edge_skew``/``hot_edge`` (Zipf popularity, see base.edge_weights).

Processes:
  PoissonArrivals        homogeneous Poisson(rate)
  InhomogeneousPoisson   rate(t) via Lewis-Shedler thinning
  DiurnalArrivals        sinusoidal rate (day/night cycle)
  FlashCrowdArrivals     steady base + a multiplier spike window at one edge
  MMPPArrivals           Markov-modulated Poisson (bursty regime switching)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.workloads.base import (Arrival, SizeSpec, edge_weights, merge,
                                  pick_edge)


def _emit(rng, t, probs, sizes: SizeSpec, service: int) -> Arrival:
    return Arrival(t=float(t), edge=pick_edge(rng, probs),
                   size=sizes.sample_one(rng), service=service)


@dataclasses.dataclass(frozen=True)
class PoissonArrivals:
    """Homogeneous Poisson process: exponential(1/rate) inter-arrivals."""

    rate: float = 20.0
    sizes: SizeSpec = SizeSpec()
    edge_skew: float = 0.0
    hot_edge: int = 0
    service: int = 0

    def arrivals(self, rng, num_edges, until):
        probs = edge_weights(num_edges, self.edge_skew, self.hot_edge)
        t = 0.0
        while True:
            t += rng.exponential(1.0 / self.rate)
            if t > until:
                return
            yield _emit(rng, t, probs, self.sizes, self.service)


@dataclasses.dataclass(frozen=True)
class InhomogeneousPoisson:
    """Poisson process with time-varying ``rate_fn(t)`` <= ``rate_max``,
    sampled by Lewis-Shedler thinning: candidates at rate_max, kept with
    probability rate_fn(t)/rate_max."""

    rate_fn: Callable[[float], float]
    rate_max: float
    sizes: SizeSpec = SizeSpec()
    edge_skew: float = 0.0
    hot_edge: int = 0
    service: int = 0

    def arrivals(self, rng, num_edges, until):
        probs = edge_weights(num_edges, self.edge_skew, self.hot_edge)
        t = 0.0
        while True:
            t += rng.exponential(1.0 / self.rate_max)
            if t > until:
                return
            keep = rng.uniform() * self.rate_max
            if keep <= max(0.0, float(self.rate_fn(t))):
                yield _emit(rng, t, probs, self.sizes, self.service)


@dataclasses.dataclass(frozen=True)
class DiurnalArrivals:
    """Sinusoidal day/night cycle: rate(t) = base*(1 + amplitude*sin(...))."""

    base_rate: float = 20.0
    amplitude: float = 0.8          # in [0, 1]: 0 = flat, 1 = full swing
    period: float = 4.0
    phase: float = 0.0
    sizes: SizeSpec = SizeSpec()
    edge_skew: float = 0.0
    hot_edge: int = 0
    service: int = 0

    def rate(self, t: float) -> float:
        return self.base_rate * (
            1.0 + self.amplitude * np.sin(2.0 * np.pi * t / self.period
                                          + self.phase))

    def arrivals(self, rng, num_edges, until):
        inner = InhomogeneousPoisson(
            rate_fn=self.rate,
            rate_max=self.base_rate * (1.0 + abs(self.amplitude)),
            sizes=self.sizes, edge_skew=self.edge_skew,
            hot_edge=self.hot_edge, service=self.service)
        yield from inner.arrivals(rng, num_edges, until)


@dataclasses.dataclass(frozen=True)
class FlashCrowdArrivals:
    """Steady base traffic plus a flash crowd: during
    [spike_start, spike_start+spike_duration] an *extra* stream of
    (multiplier-1)*base_rate concentrates on ``spike_edge``."""

    base_rate: float = 20.0
    multiplier: float = 10.0
    spike_start: float = 1.0
    spike_duration: float = 0.5
    spike_edge: int = 0
    sizes: SizeSpec = SizeSpec()
    edge_skew: float = 0.0
    service: int = 0

    def arrivals(self, rng, num_edges, until):
        t0, t1 = self.spike_start, self.spike_start + self.spike_duration
        spike_rate = max(0.0, (self.multiplier - 1.0) * self.base_rate)
        base = PoissonArrivals(rate=self.base_rate, sizes=self.sizes,
                               edge_skew=self.edge_skew, service=self.service)
        spike = InhomogeneousPoisson(
            rate_fn=lambda t: spike_rate if t0 <= t <= t1 else 0.0,
            rate_max=max(spike_rate, 1e-9),
            sizes=self.sizes, edge_skew=64.0,   # ~all spike traffic on one edge
            hot_edge=self.spike_edge, service=self.service)
        yield from merge(base, spike).arrivals(rng, num_edges, until)


@dataclasses.dataclass(frozen=True)
class MMPPArrivals:
    """Markov-modulated Poisson process: the rate switches between hidden
    states (e.g. calm/burst) with exponential sojourn times. The classic
    bursty-traffic model from the edge-scheduling literature."""

    rates: tuple = (5.0, 80.0)          # per-state arrival rate
    mean_sojourn: tuple = (2.0, 0.25)   # per-state expected dwell time
    start_state: int = 0
    sizes: SizeSpec = SizeSpec()
    edge_skew: float = 0.0
    hot_edge: int = 0
    service: int = 0

    def arrivals(self, rng, num_edges, until):
        n = len(self.rates)
        assert n == len(self.mean_sojourn) >= 1
        probs = edge_weights(num_edges, self.edge_skew, self.hot_edge)
        state = self.start_state % n
        t = 0.0
        while t < until:
            dwell = rng.exponential(self.mean_sojourn[state])
            t_end = min(t + dwell, until)
            rate = self.rates[state]
            if rate > 0:
                while True:
                    t += rng.exponential(1.0 / rate)
                    if t > t_end:
                        break
                    yield _emit(rng, t, probs, self.sizes, self.service)
            t = t_end
            if n == 2:
                state = 1 - state       # alternation IS the 2-state chain
            elif n > 2:                 # uniform jump to any *other* state
                state = int(rng.choice([s for s in range(n) if s != state]))
