"""Materialize arrival streams into padded per-round batches for the
array-native engine (:mod:`repro.serving.engine`).

The engine schedules in fixed rounds: round ``r`` (0-indexed) fires at
``(r+1) * round_interval`` and schedules every arrival in the window
``(r*dt, (r+1)*dt]`` — the same windows the event-driven simulator's round
chain induces. :func:`materialize_rounds` buckets a :class:`Workload`'s
stream into those windows and pads each to a fixed width, yielding the
dict of (R, A) arrays ``make_rollout`` scans over:

    t    (R, A) f32   arrival times (submit timestamps)
    src  (R, A) i32   source edge per arrival
    size (R, A) f32   data size per arrival
    mask (R, A) bool  True for real arrivals
    rid  (R, A) i32   global arrival index in time order (== the rid the
                      event simulator assigns when driven by the same
                      (workload, seed), which is what trace-equivalence
                      tests key on)
    service  (R, A) i32  service id per arrival (cache key; 0 default)
    deadline (R, A) f32  absolute hard-SLO time (arrival.t + relative
                      budget); DEADLINE_INF for requests with no deadline
    priority (R, A) f32  importance level (0 default)
    dropped (R,) i32  arrivals clipped from each round by the overflow
                      policy (always 0 with overflow='error'); the engine
                      folds these into its drop accounting so shed-rate
                      metrics stay honest about clipped load

Determinism matches ``MultiEdgeSim.drive``: the stream is drawn from
``workload_rng(seed)``, so materializing and driving the same (workload,
seed) produce the same arrivals.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.workloads.base import Workload, workload_rng

# "No deadline" sentinel in materialized tensors: matches the engine's INF
# (serving.engine.INF) so deadline comparisons stay trivially false in f32.
DEADLINE_INF = 1e30


def _bucketize(workload: Workload, num_edges: int, num_rounds: int,
               round_interval: float, seed: int,
               rng: Optional[np.random.Generator]) -> list[list]:
    until = num_rounds * round_interval
    rng = workload_rng(seed) if rng is None else rng
    buckets: list[list] = [[] for _ in range(num_rounds)]
    rid = 0
    for a in workload.arrivals(rng, num_edges, until):
        if not 0 <= a.edge < num_edges:
            raise ValueError(f"arrival at t={a.t} targets edge {a.edge}, "
                             f"outside 0..{num_edges - 1}")
        # Round windows are (r*dt, (r+1)*dt] over (0, until]; an arrival
        # outside them has no round to fire in, and silently clamping it
        # into row 0 / row R-1 would rewrite its submit time's window (the
        # engine would schedule it rounds away from when it arrived).
        if not 0 < a.t <= until:
            raise ValueError(
                f"arrival at t={a.t} falls outside the scheduling horizon "
                f"(0, {until}] covered by {num_rounds} round(s) of "
                f"{round_interval}; generators must emit 0 < t <= until")
        row = int(np.ceil(a.t / round_interval)) - 1  # window (r*dt, (r+1)*dt]
        # clamp only against float rounding at the window edges (t == until
        # ceil-ing one past R-1, denormal t flooring to -1) — real
        # out-of-horizon arrivals were rejected above
        row = min(max(row, 0), num_rounds - 1)
        deadline = a.t + a.deadline if a.deadline > 0 else DEADLINE_INF
        buckets[row].append((a.t, a.edge, a.size, rid, a.service, deadline,
                             a.priority))
        rid += 1
    return buckets


def _pack(buckets: list[list], width: int, overflow: str) -> dict:
    num_rounds = len(buckets)
    out = {
        "t": np.zeros((num_rounds, width), np.float32),
        "src": np.zeros((num_rounds, width), np.int32),
        "size": np.zeros((num_rounds, width), np.float32),
        "mask": np.zeros((num_rounds, width), bool),
        "rid": np.zeros((num_rounds, width), np.int32),
        "service": np.zeros((num_rounds, width), np.int32),
        "deadline": np.full((num_rounds, width), DEADLINE_INF, np.float32),
        "priority": np.zeros((num_rounds, width), np.float32),
        "dropped": np.zeros(num_rounds, np.int32),
    }
    for r, row in enumerate(buckets):
        if len(row) > width:
            if overflow == "error":
                raise ValueError(
                    f"round {r} holds {len(row)} arrivals but max_per_round "
                    f"is {width}; raise max_per_round or pass "
                    f"overflow='clip'")
            out["dropped"][r] = len(row) - width
            row = row[:width]  # overflow == "clip": drop the tail
        for j, (t, edge, size, rid, service, deadline, prio) in enumerate(row):
            out["t"][r, j] = t
            out["src"][r, j] = edge
            out["size"][r, j] = size
            out["rid"][r, j] = rid
            out["service"][r, j] = service
            out["deadline"][r, j] = deadline
            out["priority"][r, j] = prio
            out["mask"][r, j] = True
    return out


def materialize_rounds(workload: Workload, num_edges: int, num_rounds: int,
                       round_interval: float, *, seed: int = 0,
                       rng: Optional[np.random.Generator] = None,
                       max_per_round: Optional[int] = None,
                       overflow: str = "error") -> dict:
    """Bucket one workload's arrivals over [0, num_rounds * round_interval]
    into padded per-round arrays (see module docstring for the layout).

    ``max_per_round=None`` sizes the width to the busiest round. With an
    explicit width, a busier round raises (``overflow='error'``) or drops
    the excess arrivals (``overflow='clip'`` — acceptable for RL training
    batches, never for equivalence tests).
    """
    if overflow not in ("error", "clip"):
        raise ValueError(f"unknown overflow policy {overflow!r}")
    buckets = _bucketize(workload, num_edges, num_rounds, round_interval,
                         seed, rng)
    width = (max(1, max(len(b) for b in buckets)) if max_per_round is None
             else int(max_per_round))
    return _pack(buckets, width, overflow)


def materialize_round_batch(workload: Workload, num_edges: int,
                            num_rounds: int, round_interval: float,
                            batch: int, *, base_seed: int = 0,
                            max_per_round: Optional[int] = None,
                            overflow: str = "error") -> dict:
    """Stack ``batch`` independent materializations (seeds base_seed + i)
    into (B, R, A) arrays for the vmapped engine. With ``max_per_round=None``
    every element is padded to the batch-wide busiest round."""
    if overflow not in ("error", "clip"):
        raise ValueError(f"unknown overflow policy {overflow!r}")
    all_buckets = [
        _bucketize(workload, num_edges, num_rounds, round_interval,
                   base_seed + i, None)
        for i in range(batch)
    ]
    width = (max(1, max(len(b) for bs in all_buckets for b in bs))
             if max_per_round is None else int(max_per_round))
    packed = [_pack(bs, width, overflow) for bs in all_buckets]
    return {k: np.stack([p[k] for p in packed]) for k in packed[0]}
