"""Materialize arrival streams into padded per-round batches for the
array-native engine (:mod:`repro.serving.engine`).

The engine schedules in fixed rounds: round ``r`` (0-indexed) fires at
``(r+1) * round_interval`` and schedules every arrival in the window
``(r*dt, (r+1)*dt]`` — the same windows the event-driven simulator's round
chain induces. :func:`materialize_rounds` buckets a :class:`Workload`'s
stream into those windows and pads each to a fixed width, yielding the
dict of (R, A) arrays ``make_rollout`` scans over:

    t    (R, A) f32   arrival times (submit timestamps)
    src  (R, A) i32   source edge per arrival
    size (R, A) f32   data size per arrival
    mask (R, A) bool  True for real arrivals
    rid  (R, A) i32   global arrival index in time order (== the rid the
                      event simulator assigns when driven by the same
                      (workload, seed), which is what trace-equivalence
                      tests key on)
    service  (R, A) i32  service id per arrival (cache key; 0 default)
    deadline (R, A) f32  absolute hard-SLO time (arrival.t + relative
                      budget); DEADLINE_INF for requests with no deadline
    priority (R, A) f32  importance level (0 default)
    dropped (R,) i32  arrivals clipped from each round by the overflow
                      policy (always 0 with overflow='error'); the engine
                      folds these into its drop accounting so shed-rate
                      metrics stay honest about clipped load

Determinism matches ``MultiEdgeSim.drive``: the stream is drawn from
``workload_rng(seed)``, so materializing and driving the same (workload,
seed) produce the same arrivals.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.workloads.base import (Merged, ServiceMix, SizeSpec, Workload,
                                  edge_weights, workload_rng)

# "No deadline" sentinel in materialized tensors: matches the engine's INF
# (serving.engine.INF) so deadline comparisons stay trivially false in f32.
DEADLINE_INF = 1e30


def _bucketize(workload: Workload, num_edges: int, num_rounds: int,
               round_interval: float, seed: int,
               rng: Optional[np.random.Generator]) -> list[list]:
    until = num_rounds * round_interval
    rng = workload_rng(seed) if rng is None else rng
    buckets: list[list] = [[] for _ in range(num_rounds)]
    rid = 0
    for a in workload.arrivals(rng, num_edges, until):
        if not 0 <= a.edge < num_edges:
            raise ValueError(f"arrival at t={a.t} targets edge {a.edge}, "
                             f"outside 0..{num_edges - 1}")
        # Round windows are (r*dt, (r+1)*dt] over (0, until]; an arrival
        # outside them has no round to fire in, and silently clamping it
        # into row 0 / row R-1 would rewrite its submit time's window (the
        # engine would schedule it rounds away from when it arrived).
        if not 0 < a.t <= until:
            raise ValueError(
                f"arrival at t={a.t} falls outside the scheduling horizon "
                f"(0, {until}] covered by {num_rounds} round(s) of "
                f"{round_interval}; generators must emit 0 < t <= until")
        row = int(np.ceil(a.t / round_interval)) - 1  # window (r*dt, (r+1)*dt]
        # clamp only against float rounding at the window edges (t == until
        # ceil-ing one past R-1, denormal t flooring to -1) — real
        # out-of-horizon arrivals were rejected above
        row = min(max(row, 0), num_rounds - 1)
        deadline = a.t + a.deadline if a.deadline > 0 else DEADLINE_INF
        buckets[row].append((a.t, a.edge, a.size, rid, a.service, deadline,
                             a.priority))
        rid += 1
    return buckets


def _pack(buckets: list[list], width: int, overflow: str) -> dict:
    num_rounds = len(buckets)
    out = {
        "t": np.zeros((num_rounds, width), np.float32),
        "src": np.zeros((num_rounds, width), np.int32),
        "size": np.zeros((num_rounds, width), np.float32),
        "mask": np.zeros((num_rounds, width), bool),
        "rid": np.zeros((num_rounds, width), np.int32),
        "service": np.zeros((num_rounds, width), np.int32),
        "deadline": np.full((num_rounds, width), DEADLINE_INF, np.float32),
        "priority": np.zeros((num_rounds, width), np.float32),
        "dropped": np.zeros(num_rounds, np.int32),
    }
    for r, row in enumerate(buckets):
        if len(row) > width:
            if overflow == "error":
                raise ValueError(
                    f"round {r} holds {len(row)} arrivals but max_per_round "
                    f"is {width}; raise max_per_round or pass "
                    f"overflow='clip'")
            out["dropped"][r] = len(row) - width
            row = row[:width]  # overflow == "clip": drop the tail
        for j, (t, edge, size, rid, service, deadline, prio) in enumerate(row):
            out["t"][r, j] = t
            out["src"][r, j] = edge
            out["size"][r, j] = size
            out["rid"][r, j] = rid
            out["service"][r, j] = service
            out["deadline"][r, j] = deadline
            out["priority"][r, j] = prio
            out["mask"][r, j] = True
    return out


def materialize_rounds(workload: Workload, num_edges: int, num_rounds: int,
                       round_interval: float, *, seed: int = 0,
                       rng: Optional[np.random.Generator] = None,
                       max_per_round: Optional[int] = None,
                       overflow: str = "error") -> dict:
    """Bucket one workload's arrivals over [0, num_rounds * round_interval]
    into padded per-round arrays (see module docstring for the layout).

    ``max_per_round=None`` sizes the width to the busiest round. With an
    explicit width, a busier round raises (``overflow='error'``) or drops
    the excess arrivals (``overflow='clip'`` — acceptable for RL training
    batches, never for equivalence tests).
    """
    if overflow not in ("error", "clip"):
        raise ValueError(f"unknown overflow policy {overflow!r}")
    buckets = _bucketize(workload, num_edges, num_rounds, round_interval,
                         seed, rng)
    width = (max(1, max(len(b) for b in buckets)) if max_per_round is None
             else int(max_per_round))
    return _pack(buckets, width, overflow)


def materialize_round_batch(workload: Workload, num_edges: int,
                            num_rounds: int, round_interval: float,
                            batch: int, *, base_seed: int = 0,
                            max_per_round: Optional[int] = None,
                            overflow: str = "error") -> dict:
    """Stack ``batch`` independent materializations (seeds base_seed + i)
    into (B, R, A) arrays for the vmapped engine. With ``max_per_round=None``
    every element is padded to the batch-wide busiest round."""
    if overflow not in ("error", "clip"):
        raise ValueError(f"unknown overflow policy {overflow!r}")
    all_buckets = [
        _bucketize(workload, num_edges, num_rounds, round_interval,
                   base_seed + i, None)
        for i in range(batch)
    ]
    width = (max(1, max(len(b) for bs in all_buckets for b in bs))
             if max_per_round is None else int(max_per_round))
    packed = [_pack(bs, width, overflow) for bs in all_buckets]
    return {k: np.stack([p[k] for p in packed]) for k in packed[0]}


# -- device-resident materialization (pure jax.random) -----------------------
#
# ``materialize_round_batch_device`` is the jit-traceable twin of
# ``materialize_round_batch``: the same arrival *laws*, drawn with
# ``jax.random`` inside the trace, so training episodes never leave the
# device. Equivalence to the host sampler is distributional (moment/KS tests
# in tests/test_device_episodes.py), not draw-for-draw — the two consume
# different rng streams.
#
# How a workload compiles to a device plan: every supported generator is a
# superposition of Poisson components with a *static* per-round integrated
# rate Lambda[r] (constant for PoissonArrivals, trapezoid-integrated for
# DiurnalArrivals, window-overlap for FlashCrowdArrivals' spike), plus at
# most one MMPP component whose per-round Lambda is realized in-trace by
# scanning the 2-state chain. Per round: total count ~ Poisson(sum_c
# Lambda_c), each arrival's component ~ Categorical(Lambda_c / sum), edge ~
# that component's Zipf weights, so the superposition law is exact. Arrival
# times within a round are the order statistics of n uniforms on the window
# (exact for homogeneous components; an approximation for diurnal / partial
# spike overlap, where the host law is density-weighted within the window —
# a sub-round-interval effect the engine never observes, since scheduling
# only keys on the round index). Clipping reproduces the host
# overflow="clip" contract exactly: rids count *all* arrivals in time order
# and each round drops its latest count-A arrivals, realized by drawing the
# A-th order statistic of n as Beta(A, n-A+1) and the first A-1 as scaled
# order statistics beneath it.

_MMPP_SUBSTEPS = 8       # max regime switches resolved per round (P(more)
                         # is negligible for registered sojourn scales)
_DIURNAL_GRID = 64       # trapezoid points per round for rate integration


@dataclasses.dataclass(frozen=True)
class _DevicePlan:
    """Static compilation of a workload for in-jit sampling."""

    static_lam: tuple        # (R, Cs) per-round integrated rates, row-major
    edge_probs: tuple        # (C, Q) per-component edge weights (mmpp last)
    service_ids: tuple       # (C,) per-component constant service id
    mmpp: Optional[tuple]    # (rates, mean_sojourn, start_state) or None
    sizes: SizeSpec
    mix: Optional[tuple]     # (svc_probs, deadline, deadline_frac, prio_w)


def _diurnal_round_rates(wl, num_rounds: int, dt: float) -> np.ndarray:
    grid = np.linspace(0.0, dt, _DIURNAL_GRID + 1)
    lam = np.empty(num_rounds)
    for r in range(num_rounds):
        rates = np.maximum([wl.rate(r * dt + g) for g in grid], 0.0)
        lam[r] = getattr(np, "trapezoid", np.trapz)(rates, grid)
    return lam


def _flatten_components(wl, num_edges: int, num_rounds: int, dt: float,
                        out: list, mmpp: list) -> None:
    # local import only to break the module cycle at definition time is not
    # needed: processes imports base only
    from repro.workloads import processes as P

    if isinstance(wl, Merged):
        for part in wl.parts:
            _flatten_components(part, num_edges, num_rounds, dt, out, mmpp)
    elif isinstance(wl, P.PoissonArrivals):
        out.append((np.full(num_rounds, wl.rate * dt),
                    edge_weights(num_edges, wl.edge_skew, wl.hot_edge),
                    wl.service, wl.sizes))
    elif isinstance(wl, P.DiurnalArrivals):
        out.append((_diurnal_round_rates(wl, num_rounds, dt),
                    edge_weights(num_edges, wl.edge_skew, wl.hot_edge),
                    wl.service, wl.sizes))
    elif isinstance(wl, P.FlashCrowdArrivals):
        t0, t1 = wl.spike_start, wl.spike_start + wl.spike_duration
        spike_rate = max(0.0, (wl.multiplier - 1.0) * wl.base_rate)
        edges = np.arange(num_rounds)
        overlap = np.maximum(
            0.0, np.minimum(t1, (edges + 1) * dt) - np.maximum(t0, edges * dt))
        out.append((np.full(num_rounds, wl.base_rate * dt),
                    edge_weights(num_edges, wl.edge_skew, 0),
                    wl.service, wl.sizes))
        out.append((spike_rate * overlap,
                    edge_weights(num_edges, 64.0, wl.spike_edge),
                    wl.service, wl.sizes))
    elif isinstance(wl, P.MMPPArrivals):
        if len(wl.rates) != 2 or len(wl.mean_sojourn) != 2:
            raise ValueError(
                "materialize_round_batch_device supports 2-state MMPP only "
                f"(got {len(wl.rates)} states)")
        if mmpp:
            raise ValueError("at most one MMPP component per device workload")
        mmpp.append((tuple(float(x) for x in wl.rates),
                     tuple(float(x) for x in wl.mean_sojourn),
                     int(wl.start_state) % 2,
                     edge_weights(num_edges, wl.edge_skew, wl.hot_edge),
                     wl.service, wl.sizes))
    else:
        raise ValueError(
            f"workload {type(wl).__name__} has no device sampler; use the "
            f"host materialize_round_batch (supported: Poisson, Diurnal, "
            f"FlashCrowd, 2-state MMPP, ServiceMix/Merged thereof)")


def compile_device_plan(workload: Workload, num_edges: int, num_rounds: int,
                        round_interval: float) -> _DevicePlan:
    """Flatten a workload into the static tables the in-jit sampler needs.
    Raises ValueError for workloads with no device law (traces, custom
    generators, >2-state MMPP)."""
    mix = None
    wl = workload
    if isinstance(wl, ServiceMix):
        ranks = np.arange(max(1, wl.num_services), dtype=np.float64)
        probs = (ranks + 1.0) ** (-float(wl.skew))
        probs = probs / probs.sum()
        prio_w = np.asarray(wl.priorities, np.float64)
        prio_w = prio_w / prio_w.sum() if prio_w.size else None
        deadline = tuple(wl.deadline) if wl.deadline else None
        mix = (tuple(probs), deadline, float(wl.deadline_frac),
               tuple(prio_w) if prio_w is not None else None)
        wl = wl.inner

    comps: list = []
    mmpp_parts: list = []
    _flatten_components(wl, num_edges, num_rounds, round_interval,
                        comps, mmpp_parts)

    sizes = [c[3] for c in comps] + [m[5] for m in mmpp_parts]
    if any(s != sizes[0] for s in sizes[1:]):
        raise ValueError(
            "device sampler requires all merged components to share one "
            f"SizeSpec (got {sizes})")

    static_lam = (np.stack([c[0] for c in comps], axis=1) if comps
                  else np.zeros((num_rounds, 0)))
    edge_probs = [c[1] for c in comps]
    service_ids = [c[2] for c in comps]
    mmpp = None
    if mmpp_parts:
        rates, sojourn, start, eprobs, svc, _ = mmpp_parts[0]
        mmpp = (rates, sojourn, start)
        edge_probs.append(eprobs)
        service_ids.append(svc)
    return _DevicePlan(
        static_lam=tuple(map(tuple, static_lam)),
        edge_probs=tuple(map(tuple, edge_probs)),
        service_ids=tuple(int(s) for s in service_ids),
        mmpp=mmpp, sizes=sizes[0], mix=mix)


def _mmpp_round_lam(key, rates, mean_sojourn, start_state, num_rounds: int,
                    dt: float):
    """Integrated per-round rate of one 2-state MMPP trajectory: scan the
    alternating chain round by round, resolving up to _MMPP_SUBSTEPS regime
    switches inside each round."""
    rates_arr = jnp.asarray(rates, jnp.float32)
    soj_arr = jnp.asarray(mean_sojourn, jnp.float32)
    k0, kseq = jax.random.split(key)
    state0 = jnp.int32(start_state)
    rem0 = jax.random.exponential(k0) * soj_arr[state0]

    def round_body(carry, kr):
        state, rem = carry
        left = jnp.float32(dt)
        lam = jnp.float32(0.0)
        ks = jax.random.split(kr, _MMPP_SUBSTEPS)
        for i in range(_MMPP_SUBSTEPS):
            seg = jnp.minimum(rem, left)
            lam = lam + rates_arr[state] * seg
            left = left - seg
            rem = rem - seg
            switch = rem <= 1e-12
            new_state = 1 - state
            new_rem = jax.random.exponential(ks[i]) * soj_arr[new_state]
            state = jnp.where(switch, new_state, state)
            rem = jnp.where(switch, new_rem, rem)
        lam = lam + rates_arr[state] * jnp.maximum(left, 0.0)
        return (state, rem), lam

    _, lam = lax.scan(round_body, (state0, rem0),
                      jax.random.split(kseq, num_rounds))
    return lam


def _device_sizes(spec: SizeSpec, key, shape):
    """jax.random twin of SizeSpec.sample (same families, same clip)."""
    p = spec.params
    if spec.dist == "uniform":
        lo, hi = p if p else (0.0, 1.0)
        out = jax.random.uniform(key, shape, minval=lo, maxval=hi)
    elif spec.dist == "fixed":
        (value,) = p if p else (0.5,)
        out = jnp.full(shape, value, jnp.float32)
    elif spec.dist == "pareto":
        alpha, scale = p if p else (1.5, 0.05)
        # numpy's rng.pareto is the Lomax (standard Pareto minus one), so
        # host scale*(1+pareto) == device scale*jax Pareto
        out = scale * jax.random.pareto(key, alpha, shape)
    elif spec.dist == "lognormal":
        mu, sigma = p if p else (-1.5, 0.8)
        out = jnp.exp(mu + sigma * jax.random.normal(key, shape))
    else:
        raise ValueError(f"unknown size distribution {spec.dist!r}")
    return jnp.clip(out, 1e-6, spec.cap).astype(jnp.float32)


def _device_element(key, plan: _DevicePlan, num_rounds: int, width: int,
                    dt: float):
    """Sample one episode's (R, A) padded arrival tensors from one PRNG key."""
    R, A = num_rounds, width
    (k_mmpp, k_cnt, k_time, k_beta, k_comp, k_edge, k_size, k_svc, k_dl,
     k_dlu, k_prio) = jax.random.split(key, 11)

    lam = jnp.asarray(plan.static_lam, jnp.float32)        # (R, Cs)
    if plan.mmpp is not None:
        rates, sojourn, start = plan.mmpp
        lam_m = _mmpp_round_lam(k_mmpp, rates, sojourn, start, R, dt)
        lam = jnp.concatenate([lam, lam_m[:, None]], axis=1)
    lam_tot = jnp.sum(lam, axis=1)                          # (R,)

    counts = jax.random.poisson(k_cnt, lam_tot, (R,)).astype(jnp.int32)
    kept = jnp.minimum(counts, A)
    clipped = counts > A
    slot = jnp.arange(A)

    # order-statistic arrival times on (r*dt, (r+1)*dt]
    u = 1.0 - jax.random.uniform(k_time, (R, A))            # (0, 1]
    n_plain = jnp.where(clipped, A - 1, counts)             # plain uniforms
    u = jnp.where(slot[None, :] < n_plain[:, None], u, jnp.inf)
    u = jnp.sort(u, axis=-1)
    u = jnp.where(clipped[:, None] & (slot[None, :] == A - 1), 1.0, u)
    # clipped rounds: slot A-1 is the A-th of n order stats ~ Beta(A, n-A+1);
    # conditioned on it, slots 0..A-2 are scaled order stats beneath it
    b_param = jnp.maximum(counts - A + 1, 1).astype(jnp.float32)
    s = jax.random.beta(k_beta, jnp.float32(A), b_param)
    u = u * jnp.where(clipped, s, 1.0)[:, None]
    mask = slot[None, :] < kept[:, None]
    t = jnp.where(mask, (jnp.arange(R, dtype=jnp.float32)[:, None] + u) * dt,
                  0.0).astype(jnp.float32)

    # component then edge: exact superposition mixture
    frac = lam / jnp.maximum(lam_tot, 1e-12)[:, None]       # (R, C)
    comp = jax.random.categorical(
        k_comp, jnp.log(jnp.maximum(frac, 1e-30))[:, None, :], shape=(R, A))
    eprob = jnp.asarray(plan.edge_probs, jnp.float32)       # (C, Q)
    elogits = jnp.log(jnp.maximum(eprob, 1e-30))[comp]      # (R, A, Q)
    edge = jax.random.categorical(k_edge, elogits).astype(jnp.int32)

    size = _device_sizes(plan.sizes, k_size, (R, A))

    if plan.mix is not None:
        svc_probs, deadline, deadline_frac, prio_w = plan.mix
        service = jax.random.categorical(
            k_svc, jnp.log(jnp.asarray(svc_probs, jnp.float32)),
            shape=(R, A)).astype(jnp.int32)
        if deadline:
            lo, hi = deadline
            d = jax.random.uniform(k_dlu, (R, A), minval=lo, maxval=hi)
            take = (jnp.ones((R, A), bool) if deadline_frac >= 1.0
                    else jax.random.bernoulli(k_dl, deadline_frac, (R, A)))
            dl = jnp.where(mask & take, t + d, DEADLINE_INF)
        else:
            dl = jnp.full((R, A), DEADLINE_INF, jnp.float32)
        if prio_w is not None:
            prio = jax.random.categorical(
                k_prio, jnp.log(jnp.asarray(prio_w, jnp.float32)),
                shape=(R, A)).astype(jnp.float32)
        else:
            prio = jnp.zeros((R, A), jnp.float32)
    else:
        service = jnp.asarray(plan.service_ids, jnp.int32)[comp]
        dl = jnp.full((R, A), DEADLINE_INF, jnp.float32)
        prio = jnp.zeros((R, A), jnp.float32)

    # rids count every arrival (pre-clip) in global time order; each round's
    # kept slots take the first `kept` of its contiguous range — exactly the
    # host clip contract (the latest count-A arrivals of the round drop)
    starts = jnp.cumsum(counts) - counts
    rid = starts[:, None] + slot[None, :]
    zi = jnp.zeros((R, A), jnp.int32)
    return {
        "t": t,
        "src": jnp.where(mask, edge, zi),
        "size": jnp.where(mask, size, 0.0),
        "mask": mask,
        "rid": jnp.where(mask, rid.astype(jnp.int32), zi),
        "service": jnp.where(mask, service, zi),
        "deadline": jnp.where(mask, dl, DEADLINE_INF).astype(jnp.float32),
        "priority": jnp.where(mask, prio, 0.0).astype(jnp.float32),
        "dropped": jnp.maximum(counts - A, 0).astype(jnp.int32),
    }


def materialize_round_batch_device(workload: Workload, num_edges: int,
                                   num_rounds: int, round_interval: float,
                                   batch: Optional[int] = None, *,
                                   key=None, keys=None,
                                   max_per_round: int,
                                   overflow: str = "clip") -> dict:
    """Device twin of :func:`materialize_round_batch`: sample a (B, R, A)
    padded arrival batch with ``jax.random``, traceable inside jit/scan.

    Pass either ``keys`` — (B, 2) per-element PRNG keys, the form the
    sharded trainer uses so every batch element's draw is independent of
    how the batch is split across devices — or ``key`` + ``batch`` (split
    internally). ``max_per_round`` is required (fixed shapes) and only
    ``overflow="clip"`` is supported: counts are traced values, so the host
    sampler's ``overflow="error"`` cannot raise here.
    """
    if overflow != "clip":
        raise ValueError(
            "materialize_round_batch_device supports overflow='clip' only "
            "(counts are traced; 'error' cannot raise inside jit)")
    if max_per_round is None:
        raise ValueError("max_per_round is required (fixed device shapes)")
    plan = compile_device_plan(workload, num_edges, num_rounds,
                               round_interval)
    if keys is None:
        if key is None or batch is None:
            raise ValueError("pass keys=(B, 2) or key= plus batch=")
        keys = jax.random.split(key, batch)
    return jax.vmap(
        lambda k: _device_element(k, plan, num_rounds, int(max_per_round),
                                  float(round_interval)))(keys)
