"""JSONL workload traces: record once, replay against any scheduler backend.

Format (schema-versioned, one JSON object per line):

    {"schema": "corais.trace.v1", "num_edges": 5, "meta": {...}}   # header
    {"t": 0.0123, "edge": 3, "size": 0.4567}                       # events...
    {"t": 0.0456, "edge": 0, "size": 0.9876, "service": 1}

Floats are serialized with ``repr`` (Python's json default), which
round-trips IEEE doubles exactly — so record->replay is bit-identical and a
replayed run reproduces the live run's completion metrics under the same
simulator seed. A :class:`TraceWorkload` satisfies the same ``Workload``
protocol as the synthetic generators, so the three consumers (simulator
``drive``, scenario sweep, examples) cannot tell a trace from a process.

Schema v2 adds an optional ``events`` section to the header — the fault
timeline (failures / recoveries / straggler speed changes) captured as
absolute-time :class:`FaultEvent` records:

    {"schema": "corais.trace.v2", "num_edges": 5, "meta": {...},
     "events": [{"t": 0.75, "kind": "fail", "edge": 2},
                {"t": 1.25, "kind": "recover", "edge": 2},
                {"t": 1.25, "kind": "straggle", "edge": 0, "factor": 4.0}]}

A trace without fault events is always written under the v1 schema, byte
for byte what pre-v2 code produced, and v1 files read back unchanged —
``fault_events`` is just empty. ``repro.resilience.faults`` converts
between these records and the engine's per-round event tensors.

Schema v3 adds optional per-event ``deadline`` (relative response budget in
seconds; the hard SLO is ``t + deadline``) and ``priority`` (small integer
importance level) fields, written only when nonzero:

    {"schema": "corais.trace.v3", "num_edges": 5, "meta": {...}}
    {"t": 0.0123, "edge": 3, "size": 0.4567, "service": 2,
     "deadline": 1.5, "priority": 1}

Downgrade is byte-exact: a stream with no deadlines/priorities writes the
same v2 bytes (faults present) or v1 bytes (fault-free) that pre-v3 code
produced, and every older file reads back unchanged under the v3 reader —
the new :class:`Arrival` fields just hold their defaults.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable, Optional, Sequence

import numpy as np

from repro.workloads.base import Arrival, Workload, workload_rng

SCHEMA_V1 = "corais.trace.v1"
SCHEMA_V2 = "corais.trace.v2"
SCHEMA_V3 = "corais.trace.v3"
SCHEMA = SCHEMA_V1  # default write schema (used when a trace has no faults)
_SUPPORTED_SCHEMAS = (SCHEMA_V1, SCHEMA_V2, SCHEMA_V3)

FAULT_KINDS = ("fail", "recover", "straggle")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One entry of a trace's fault timeline: at wall time ``t`` edge
    ``edge`` fails, recovers, or changes straggler speed to ``factor``
    (``factor`` is only meaningful for kind="straggle"; 1.0 = nominal)."""

    t: float
    kind: str
    edge: int
    factor: float = 1.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"supported: {FAULT_KINDS}")


@dataclasses.dataclass(frozen=True)
class TraceWorkload:
    """A recorded arrival stream. ``arrivals`` ignores the rng (a trace is
    already fully determined) and replays events with t <= until. v2 traces
    additionally carry ``fault_events`` — the recorded fault timeline — for
    consumers that replay the chaos alongside the arrivals."""

    events: tuple
    num_edges: int = 0
    meta: Optional[dict] = None
    schema: str = SCHEMA
    fault_events: tuple = ()

    def arrivals(self, rng, num_edges, until):
        for a in self.events:
            if a.t > until:
                return
            yield a

    def __len__(self):
        return len(self.events)


def write_trace(path: str, arrivals: Iterable[Arrival], *, num_edges: int,
                meta: Optional[dict] = None,
                fault_events: Sequence[FaultEvent] = ()) -> int:
    """Write arrivals (any iterable, consumed once) as a JSONL trace.
    Returns the number of events written. The schema is the lowest version
    that can express the stream: any deadline/priority field stamps
    ``corais.trace.v3``, else ``fault_events`` stamp ``corais.trace.v2``,
    else the file is a byte-identical v1 trace."""
    events = list(arrivals)
    has_v3 = any(a.deadline or a.priority for a in events)
    if has_v3:
        schema = SCHEMA_V3
    elif fault_events:
        schema = SCHEMA_V2
    else:
        schema = SCHEMA_V1
    with open(path, "w") as f:
        header = {"schema": schema,
                  "num_edges": int(num_edges), "meta": meta or {}}
        if fault_events:
            header["events"] = [_fault_row(ev, num_edges)
                                for ev in fault_events]
        f.write(json.dumps(header) + "\n")
        for a in events:
            row = {"t": float(a.t), "edge": int(a.edge),
                   "size": float(a.size)}
            if a.service:
                row["service"] = int(a.service)
            if a.deadline:
                row["deadline"] = float(a.deadline)
            if a.priority:
                row["priority"] = int(a.priority)
            f.write(json.dumps(row) + "\n")
    return len(events)


def record_trace(path: str, workload: Workload, *, num_edges: int,
                 until: float, seed: int = 0,
                 rng: Optional[np.random.Generator] = None,
                 meta: Optional[dict] = None,
                 fault_events: Sequence[FaultEvent] = ()) -> int:
    """Materialize ``workload`` over [0, until] and persist it. The same
    (workload, seed, num_edges, until) always records the same trace, and
    it is the exact stream ``MultiEdgeSim.drive(workload, seed=seed)``
    would generate live (both derive :func:`workload_rng`)."""
    rng = workload_rng(seed) if rng is None else rng
    info = {"until": float(until), "seed": int(seed),
            "workload": repr(workload)}
    info.update(meta or {})
    return write_trace(path, workload.arrivals(rng, num_edges, until),
                       num_edges=num_edges, meta=info,
                       fault_events=fault_events)


def _fault_row(ev: FaultEvent, num_edges: int) -> dict:
    if num_edges and not 0 <= int(ev.edge) < num_edges:
        raise ValueError(f"fault event edge {ev.edge} outside the trace's "
                         f"0..{num_edges - 1}")
    row = {"t": float(ev.t), "kind": ev.kind, "edge": int(ev.edge)}
    if ev.kind == "straggle":
        row["factor"] = float(ev.factor)
    return row


def _parse_fault_events(header: dict, path: str) -> tuple:
    rows = header.get("events") or ()
    out, last_t = [], -np.inf
    for i, row in enumerate(rows):
        try:
            ev = FaultEvent(t=float(row["t"]), kind=str(row["kind"]),
                            edge=int(row["edge"]),
                            factor=float(row.get("factor", 1.0)))
        except (KeyError, ValueError) as exc:
            raise ValueError(f"{path}: bad fault event {i}: {exc}") from None
        n_edges = int(header.get("num_edges", 0))
        if n_edges and not 0 <= ev.edge < n_edges:
            raise ValueError(f"{path}: fault event {i}: edge {ev.edge} "
                             f"outside the trace's 0..{n_edges - 1}")
        if ev.t < last_t:
            raise ValueError(f"{path}: fault events out of order")
        last_t = ev.t
        out.append(ev)
    return tuple(out)


def read_trace(path: str) -> TraceWorkload:
    """Load a JSONL trace; validates the schema header."""
    with open(path) as f:
        first = f.readline()
        if not first.strip():
            raise ValueError(f"{path}: empty trace file")
        header = json.loads(first)
        schema = header.get("schema")
        if schema not in _SUPPORTED_SCHEMAS:
            raise ValueError(
                f"{path}: unsupported trace schema {schema!r} "
                f"(supported: {_SUPPORTED_SCHEMAS})")
        if schema == SCHEMA_V1 and "events" in header:
            raise ValueError(f"{path}: fault events require {SCHEMA_V2}")
        fault_events = _parse_fault_events(header, path)
        events = []
        last_t = -np.inf
        for lineno, line in enumerate(f, start=2):
            if not line.strip():
                continue
            row = json.loads(line)
            if schema != SCHEMA_V3 and ("deadline" in row
                                        or "priority" in row):
                raise ValueError(
                    f"{path}:{lineno}: deadline/priority fields require "
                    f"{SCHEMA_V3}")
            a = Arrival(t=float(row["t"]), edge=int(row["edge"]),
                        size=float(row["size"]),
                        service=int(row.get("service", 0)),
                        deadline=float(row.get("deadline", 0.0)),
                        priority=int(row.get("priority", 0)))
            n_edges = int(header.get("num_edges", 0))
            if n_edges and not 0 <= a.edge < n_edges:
                raise ValueError(f"{path}:{lineno}: edge {a.edge} outside "
                                 f"the trace's 0..{n_edges - 1}")
            if a.t < last_t:
                raise ValueError(f"{path}:{lineno}: arrivals out of order")
            last_t = a.t
            events.append(a)
    return TraceWorkload(events=tuple(events),
                         num_edges=int(header.get("num_edges", 0)),
                         meta=header.get("meta") or {}, schema=schema,
                         fault_events=fault_events)
