"""JSONL workload traces: record once, replay against any scheduler backend.

Format (schema-versioned, one JSON object per line):

    {"schema": "corais.trace.v1", "num_edges": 5, "meta": {...}}   # header
    {"t": 0.0123, "edge": 3, "size": 0.4567}                       # events...
    {"t": 0.0456, "edge": 0, "size": 0.9876, "service": 1}

Floats are serialized with ``repr`` (Python's json default), which
round-trips IEEE doubles exactly — so record->replay is bit-identical and a
replayed run reproduces the live run's completion metrics under the same
simulator seed. A :class:`TraceWorkload` satisfies the same ``Workload``
protocol as the synthetic generators, so the three consumers (simulator
``drive``, scenario sweep, examples) cannot tell a trace from a process.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable, Optional

import numpy as np

from repro.workloads.base import Arrival, Workload, workload_rng

SCHEMA = "corais.trace.v1"
_SUPPORTED_SCHEMAS = (SCHEMA,)


@dataclasses.dataclass(frozen=True)
class TraceWorkload:
    """A recorded arrival stream. ``arrivals`` ignores the rng (a trace is
    already fully determined) and replays events with t <= until."""

    events: tuple
    num_edges: int = 0
    meta: Optional[dict] = None
    schema: str = SCHEMA

    def arrivals(self, rng, num_edges, until):
        for a in self.events:
            if a.t > until:
                return
            yield a

    def __len__(self):
        return len(self.events)


def write_trace(path: str, arrivals: Iterable[Arrival], *, num_edges: int,
                meta: Optional[dict] = None) -> int:
    """Write arrivals (any iterable, consumed once) as a v1 JSONL trace.
    Returns the number of events written."""
    n = 0
    with open(path, "w") as f:
        header = {"schema": SCHEMA, "num_edges": int(num_edges),
                  "meta": meta or {}}
        f.write(json.dumps(header) + "\n")
        for a in arrivals:
            row = {"t": float(a.t), "edge": int(a.edge),
                   "size": float(a.size)}
            if a.service:
                row["service"] = int(a.service)
            f.write(json.dumps(row) + "\n")
            n += 1
    return n


def record_trace(path: str, workload: Workload, *, num_edges: int,
                 until: float, seed: int = 0,
                 rng: Optional[np.random.Generator] = None,
                 meta: Optional[dict] = None) -> int:
    """Materialize ``workload`` over [0, until] and persist it. The same
    (workload, seed, num_edges, until) always records the same trace, and
    it is the exact stream ``MultiEdgeSim.drive(workload, seed=seed)``
    would generate live (both derive :func:`workload_rng`)."""
    rng = workload_rng(seed) if rng is None else rng
    info = {"until": float(until), "seed": int(seed),
            "workload": repr(workload)}
    info.update(meta or {})
    return write_trace(path, workload.arrivals(rng, num_edges, until),
                       num_edges=num_edges, meta=info)


def read_trace(path: str) -> TraceWorkload:
    """Load a JSONL trace; validates the schema header."""
    with open(path) as f:
        first = f.readline()
        if not first.strip():
            raise ValueError(f"{path}: empty trace file")
        header = json.loads(first)
        schema = header.get("schema")
        if schema not in _SUPPORTED_SCHEMAS:
            raise ValueError(
                f"{path}: unsupported trace schema {schema!r} "
                f"(supported: {_SUPPORTED_SCHEMAS})")
        events = []
        last_t = -np.inf
        for lineno, line in enumerate(f, start=2):
            if not line.strip():
                continue
            row = json.loads(line)
            a = Arrival(t=float(row["t"]), edge=int(row["edge"]),
                        size=float(row["size"]),
                        service=int(row.get("service", 0)))
            n_edges = int(header.get("num_edges", 0))
            if n_edges and not 0 <= a.edge < n_edges:
                raise ValueError(f"{path}:{lineno}: edge {a.edge} outside "
                                 f"the trace's 0..{n_edges - 1}")
            if a.t < last_t:
                raise ValueError(f"{path}:{lineno}: arrivals out of order")
            last_t = a.t
            events.append(a)
    return TraceWorkload(events=tuple(events),
                         num_edges=int(header.get("num_edges", 0)),
                         meta=header.get("meta") or {}, schema=schema)
