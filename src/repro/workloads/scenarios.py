"""Named scenario registry — one vocabulary for sim, training, benchmarks.

A *scenario* bundles a workload factory (how requests arrive over time)
with static-instance overrides (how ``core/instances.py`` should condition
its request/backlog sampling so training and Table-III-style generalization
see the same laws). Consumers:

    wl  = scenario("flash_crowd_10x")                   # -> Workload
    sim.drive(wl, until=3.0)                            # serving
    cfg = instance_config_for_scenario("heavy_tail_pareto", base_cfg)
    inst = generate_instance(rng, cfg)                  # training / eval
    PYTHONPATH=src python benchmarks/scenario_sweep.py  # full matrix

Factories accept keyword overrides forwarded to the underlying process
dataclass, e.g. ``scenario("mmpp_bursty", rates=(2.0, 200.0))``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.resilience.faults import FaultSpec
from repro.serving.cache import CacheSpec
from repro.serving.topology import CloudSpec
from repro.workloads.base import ServiceMix, SizeSpec, Workload
from repro.workloads.processes import (DiurnalArrivals, FlashCrowdArrivals,
                                       MMPPArrivals, PoissonArrivals)


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    name: str
    factory: Callable[..., Workload]
    description: str = ""
    # InstanceConfig field overrides (size_dist/size_params/source_skew/...)
    # applied by instance_config_for_scenario for static-instance consumers.
    instance_overrides: Optional[dict] = None
    # Chaos scenarios: the fault process injected alongside the arrivals
    # (materialized per seed by repro.resilience.faults). None = fault-free.
    fault_spec: Optional[FaultSpec] = None
    # Edge–cloud scenarios: the cloud tier + per-edge service-cache laws
    # both engines must be configured with (None = flat single-tier).
    cloud_spec: Optional[CloudSpec] = None
    cache_spec: Optional[CacheSpec] = None


_REGISTRY: dict[str, ScenarioSpec] = {}


def register_scenario(name: str, factory: Callable[..., Workload], *,
                      description: str = "",
                      instance_overrides: Optional[dict] = None,
                      fault_spec: Optional[FaultSpec] = None,
                      cloud_spec: Optional[CloudSpec] = None,
                      cache_spec: Optional[CacheSpec] = None,
                      overwrite: bool = False) -> ScenarioSpec:
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"scenario {name!r} already registered")
    spec = ScenarioSpec(name=name, factory=factory, description=description,
                        instance_overrides=instance_overrides,
                        fault_spec=fault_spec, cloud_spec=cloud_spec,
                        cache_spec=cache_spec)
    _REGISTRY[name] = spec
    return spec


def scenario(name: str, **overrides) -> Workload:
    """Instantiate a registered scenario's workload, with optional keyword
    overrides forwarded to its factory."""
    try:
        spec = _REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown scenario {name!r}; known: {known}") from None
    return spec.factory(**overrides)


def scenario_spec(name: str) -> ScenarioSpec:
    return _REGISTRY[name]


def scenario_fault_spec(name: str) -> Optional[FaultSpec]:
    """The fault process a scenario injects (None for fault-free ones).
    Consumers materialize it per seed via ``resilience.faults`` — e.g.
    ``temporal_train`` fault-injects chaos-scenario episodes automatically,
    and ``benchmarks/scenario_sweep.py`` drives both engines with it."""
    return _REGISTRY[name].fault_spec


def scenario_cloud_spec(name: str):
    """The (CloudSpec, CacheSpec) pair a ``cloud-*`` scenario runs under —
    (None, None) for flat single-tier scenarios. Consumers thread these
    into ``EngineConfig(cloud=, cache=)`` and ``SimConfig(cloud=, cache=)``
    so both engines simulate the identical tiered cluster."""
    spec = _REGISTRY[name]
    return spec.cloud_spec, spec.cache_spec


def list_scenarios() -> dict[str, str]:
    """name -> one-line description, in registration order."""
    return {name: spec.description for name, spec in _REGISTRY.items()}


def instance_config_for_scenario(name: str, base):
    """Condition an :class:`repro.core.InstanceConfig` on a scenario: returns
    ``base`` with the scenario's size-distribution / source-skew overrides
    applied (unchanged if the scenario has none, e.g. purely temporal ones)."""
    spec = _REGISTRY[name]
    if not spec.instance_overrides:
        return base
    return dataclasses.replace(base, **spec.instance_overrides)


# -- built-in scenarios ------------------------------------------------------

register_scenario(
    "uniform_iid",
    lambda **kw: PoissonArrivals(**{"rate": 20.0, **kw}),
    description="Paper §V.A analogue: steady Poisson arrivals, U(0,1) sizes, "
                "uniform edge popularity.",
)

register_scenario(
    "hotspot_skew",
    lambda **kw: PoissonArrivals(**{"rate": 20.0, "edge_skew": 2.0, **kw}),
    description="Zipf(2) edge popularity: most traffic lands on one hot "
                "edge, stressing transfer-aware balancing.",
    instance_overrides={"source_skew": 2.0},
)

register_scenario(
    "heavy_tail_pareto",
    lambda **kw: PoissonArrivals(
        **{"rate": 20.0, "sizes": SizeSpec("pareto", (1.5, 0.05)), **kw}),
    description="Pareto(1.5) data sizes: elephant requests dominate the "
                "makespan.",
    instance_overrides={"size_dist": "pareto", "size_params": (1.5, 0.05)},
)

register_scenario(
    "lognormal_sizes",
    lambda **kw: PoissonArrivals(
        **{"rate": 20.0, "sizes": SizeSpec("lognormal", (-1.5, 0.8)), **kw}),
    description="Lognormal data sizes (multiplicative noise), the common "
                "fit for measured request footprints.",
    instance_overrides={"size_dist": "lognormal", "size_params": (-1.5, 0.8)},
)

register_scenario(
    "diurnal",
    lambda **kw: DiurnalArrivals(**{"base_rate": 20.0, "amplitude": 0.8,
                                    "period": 4.0, **kw}),
    description="Sinusoidal day/night cycle: load swings 10x between trough "
                "and peak.",
)

register_scenario(
    "flash_crowd_10x",
    lambda **kw: FlashCrowdArrivals(**{"base_rate": 10.0, "multiplier": 10.0,
                                       "spike_start": 1.0,
                                       "spike_duration": 0.5, **kw}),
    description="Steady base traffic plus a 10x flash crowd concentrated on "
                "one edge for a short window.",
    instance_overrides={"source_skew": 4.0},
)

register_scenario(
    "mmpp_bursty",
    lambda **kw: MMPPArrivals(**{"rates": (5.0, 80.0),
                                 "mean_sojourn": (2.0, 0.25), **kw}),
    description="2-state Markov-modulated Poisson: calm/burst regime "
                "switching (classic bursty edge traffic).",
)

# -- edge–cloud scenarios (tiered topology + service caches, schema v3) ------
# Arrivals carry service ids, deadlines, and priorities (ServiceMix); the
# registry also pins the cloud tier + cache laws so every consumer (engine,
# oracle, sweep, training) simulates the identical tiered cluster.

register_scenario(
    "cloud-cache-churn",
    lambda **kw: ServiceMix(
        PoissonArrivals(rate=kw.pop("rate", 40.0)),
        **{"num_services": 12, "skew": 0.5, "deadline": (1.0, 3.0), **kw}),
    description="Miss-heavy tier stress: 12 services churning through "
                "2-slot edge caches under overload, every request carrying "
                "a 1-3s deadline. Edges pay 1s cache-aside warm-ups; the "
                "always-hit cloud pays a 0.4s WAN round-trip instead. "
                "Deadline-aware, cache-aware dispatch is the whole game.",
    cloud_spec=CloudSpec(wan_rtt=0.4, wan_dist=1.5, lanes=12,
                         phi_a=0.2, phi_b=0.02),
    cache_spec=CacheSpec(slots=2, miss_penalty=1.0, num_services=12),
)

register_scenario(
    "cloud-burst-offload",
    lambda **kw: ServiceMix(
        MMPPArrivals(rates=kw.pop("rates", (8.0, 90.0)),
                     mean_sojourn=kw.pop("mean_sojourn", (2.0, 0.3))),
        **{"num_services": 6, "skew": 1.2, "deadline": (1.5, 4.0),
           "priorities": (3.0, 1.0), **kw}),
    description="Bursty MMPP traffic against a 16-lane cloud: calm phases "
                "fit on the edges (popular services stay cached), bursts "
                "must spill to the WAN. Tests elastic offload timing under "
                "deadlines and mixed priorities.",
    cloud_spec=CloudSpec(wan_rtt=0.3, wan_dist=1.2, lanes=16,
                         phi_a=0.25, phi_b=0.03),
    cache_spec=CacheSpec(slots=3, miss_penalty=0.6, num_services=6),
)

# -- chaos scenarios (resilience subsystem) ----------------------------------
# Same arrival vocabulary, plus a registered fault process: the engines
# apply the materialized trajectory identically (equivalence-tested), and
# temporal_train injects it into every training episode.

register_scenario(
    "chaos-rolling-failure",
    lambda **kw: PoissonArrivals(**{"rate": 180.0, **kw}),
    description="Overload + a rolling outage: each edge in turn goes down "
                "for two rounds mid-episode, orphaning its queue onto the "
                "survivors while arrivals outrun the degraded capacity. "
                "The admission-control proving ground.",
    fault_spec=FaultSpec(rolling=(2, 2)),
)

register_scenario(
    "chaos-flash-failure",
    lambda **kw: FlashCrowdArrivals(**{"base_rate": 10.0, "multiplier": 10.0,
                                       "spike_start": 1.0,
                                       "spike_duration": 0.5, **kw}),
    description="Flash crowd on edge 0 while that same edge fails during "
                "the spike window: failover and the crowd collide.",
    instance_overrides={"source_skew": 4.0},
    fault_spec=FaultSpec(scripted_failures=((0, 4, 8),)),
)

register_scenario(
    "chaos-straggler-storm",
    lambda **kw: PoissonArrivals(**{"rate": 25.0, **kw}),
    description="Markov straggler churn (5x slowdowns) plus lognormal "
                "per-request runtime jitter: perception must route around "
                "slow edges it was never told about.",
    fault_spec=FaultSpec(straggle_prob=0.2, straggle_recover_prob=0.5,
                         straggle_factor=5.0, jitter_sigma=0.15),
)
