"""Optimizers and gradient utilities (pure JAX, no optax in this container)."""
from repro.optim.adam import adam_init, adam_update, AdamConfig
from repro.optim.adafactor import adafactor_init, adafactor_update, AdafactorConfig
from repro.optim.schedule import warmup_cosine, constant_lr
from repro.optim.grad_utils import (
    clip_by_global_norm,
    global_norm,
    quantize_int8,
    dequantize_int8,
    compressed_psum,
)

__all__ = [
    "adam_init", "adam_update", "AdamConfig",
    "adafactor_init", "adafactor_update", "AdafactorConfig",
    "warmup_cosine", "constant_lr",
    "clip_by_global_norm", "global_norm",
    "quantize_int8", "dequantize_int8", "compressed_psum",
]
