"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def constant_lr(value: float):
    def f(step):
        return jnp.asarray(value, jnp.float32)
    return f


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  floor_frac: float = 0.1):
    def f(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak * (floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)
    return f
