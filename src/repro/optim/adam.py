"""Adam / AdamW with dtype-configurable moment storage.

Moment dtype matters at the 100B+ scale: bf16 moments halve optimizer HBM,
which is one of the distributed-optimization knobs surfaced in configs
(see DESIGN.md §5 and EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float | Callable[[jax.Array], jax.Array] = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    moment_dtype: Any = jnp.float32

    def resolve_lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)


def adam_init(params, cfg: AdamConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def adam_update(params, grads, opt_state, cfg: AdamConfig):
    step = opt_state["step"] + 1
    lr = cfg.resolve_lr(step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32)
        v32 = v.astype(jnp.float32)
        m32 = b1 * m32 + (1 - b1) * g
        v32 = b2 * v32 + (1 - b2) * jnp.square(g)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(cfg.moment_dtype), v32.astype(cfg.moment_dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_params, {"step": step, "m": new_m, "v": new_v}
