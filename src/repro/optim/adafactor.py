"""Adafactor (Shazeer & Stern 2018) — factored second moments.

For >=2-D parameters the second moment is stored as row/col factors,
cutting optimizer state from 2x-fp32 to ~0 extra vs. params. Used by the
100B+ arch configs (mistral-large-123b, llama3-405b, mixtral-8x22b) so the
single-pod (256-chip) training dry-run fits HBM (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float | Callable = 1e-2
    decay: float = 0.8          # beta2 hat: 1 - step^-decay schedule
    eps1: float = 1e-30
    eps2: float = 1e-3
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    min_dim_size_to_factor: int = 128

    def resolve_lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)


def _factored(shape, cfg) -> bool:
    return len(shape) >= 2 and shape[-1] >= cfg.min_dim_size_to_factor \
        and shape[-2] >= cfg.min_dim_size_to_factor


def adafactor_init(params, cfg: AdafactorConfig):
    def slot(p):
        if _factored(p.shape, cfg):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"step": jnp.zeros((), jnp.int32), "v": jax.tree.map(slot, params,
            is_leaf=lambda x: hasattr(x, "shape"))}


def adafactor_update(params, grads, opt_state, cfg: AdafactorConfig):
    step = opt_state["step"] + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-cfg.decay)
    lr = cfg.resolve_lr(step)

    def upd(p, g, slot):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + cfg.eps1
        if "vr" in slot:
            vr = beta2 * slot["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * slot["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom_r = vr / jnp.mean(vr, axis=-1, keepdims=True)
            precond = g * jax.lax.rsqrt(denom_r[..., None]) * jax.lax.rsqrt(vc[..., None, :])
            new_slot = {"vr": vr, "vc": vc}
        else:
            v = beta2 * slot["v"] + (1 - beta2) * g2
            precond = g * jax.lax.rsqrt(v)
            new_slot = {"v": v}
        # update clipping (RMS of the preconditioned update)
        rms = jnp.sqrt(jnp.mean(jnp.square(precond)) + 1e-30)
        precond = precond / jnp.maximum(1.0, rms / cfg.clip_threshold)
        scale = jnp.maximum(cfg.eps2, jnp.sqrt(jnp.mean(jnp.square(p.astype(jnp.float32)))))
        delta = lr * scale * precond
        if cfg.weight_decay:
            delta = delta + lr * cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - delta).astype(p.dtype), new_slot

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    return (treedef.unflatten([o[0] for o in out]),
            {"step": step, "v": treedef.unflatten([o[1] for o in out])})
