"""Gradient utilities: global-norm clipping and int8 gradient compression.

``compressed_psum`` implements the classic distributed-optimization trick of
quantizing gradients to int8 (per-tensor absmax scale) before the cross-pod
all-reduce, then dequantizing: 4x less ICI/DCN traffic on the slowest link.
It is exposed as an opt-in knob in TrainConfig (cross-pod axis only; the
within-pod reduction stays full precision).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    """Scale ``tree`` so its global norm is at most ``max_norm``. Returns
    (clipped tree, raw norm). A non-finite norm (an inf/nan gradient leaf)
    zeroes the whole update instead of poisoning it — ``inf * 0`` under the
    naive scale is nan, which an Adam step would write into every
    parameter; dropping the step keeps training recoverable and the raw
    norm still reports the blow-up."""
    norm = global_norm(tree)
    scale = jnp.where(jnp.isfinite(norm),
                      jnp.minimum(1.0, max_norm / (norm + 1e-12)), 0.0)

    def clip(x):
        c = x.astype(jnp.float32) * scale
        return jnp.where(jnp.isfinite(c), c, 0.0).astype(x.dtype)

    return jax.tree.map(clip, tree), norm


def quantize_int8(x: jax.Array):
    """Per-tensor absmax int8 quantization. Returns (q, scale)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(x: jax.Array, axis_name: str, num_shards: int):
    """int8-compressed all-reduce over ``axis_name`` (inside shard_map).

    Each participant quantizes locally; int8 payloads are summed in int32 to
    avoid overflow (num_shards <= 2**24 safe); scales are maxed so the shared
    dequantization grid is conservative. Mean-preserving up to quantization
    error (bounded by scale/2 per element per shard).
    """
    q, scale = quantize_int8(x)
    scale = jax.lax.pmax(scale, axis_name)
    # Requantize against the shared scale so summation is coherent.
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return (total.astype(jnp.float32) * scale).astype(x.dtype)
