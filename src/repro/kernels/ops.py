"""Jit'd public wrappers for the Pallas kernels.

``INTERPRET`` defaults to True off-TPU: the kernel bodies execute in Python
(emulation) for correctness validation on CPU, and compile to Mosaic on a
real TPU. The pure-jnp oracles live in ref.py; tests sweep shapes/dtypes and
assert allclose kernel-vs-ref.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_fwd
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.mamba_scan import mamba_scan_fwd
from repro.kernels.policy_score import (policy_score_decode_fwd,
                                        policy_score_fwd)

def interpret_mode() -> bool:
    """Lazy: avoids initializing the jax backend at import time (the dry-run
    must set XLA_FLAGS before anything touches jax device state)."""
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(q, k, v, *, causal=True, window=None, bq=128, bk=128):
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               bq=bq, bk=bk, interpret=interpret_mode())


@partial(jax.jit, static_argnames=("window", "bk"))
def decode_attention(q, k_cache, v_cache, slot_pos, pos, *, window=None, bk=128):
    return decode_attention_fwd(q, k_cache, v_cache, slot_pos, pos,
                                window=window, bk=bk, interpret=interpret_mode())


@partial(jax.jit, static_argnames=("chunk", "bd"))
def mamba_scan(u, dt, B_mat, C_mat, A, *, chunk=128, bd=256):
    return mamba_scan_fwd(u, dt, B_mat, C_mat, A, chunk=chunk, bd=bd,
                          interpret=interpret_mode())


@partial(jax.jit, static_argnames=("tanh_clip", "bz"))
def policy_score(c_emb, h_emb, w_px, w_py, edge_mask, *, tanh_clip=10.0, bz=256):
    """Fused eq 16-17 head: any leading batch shape, custom-VJP backward."""
    return policy_score_fwd(c_emb, h_emb, w_px, w_py, edge_mask,
                            tanh_clip=tanh_clip, bz=bz, interpret=interpret_mode())


@partial(jax.jit, static_argnames=("tanh_clip", "k", "normalize", "bz"))
def policy_score_decode(c_emb, h_emb, w_px, w_py, edge_mask, *,
                        tanh_clip=10.0, k=1, normalize=True, bz=1024):
    """Fused score + greedy/top-k decode: (top_idx, top_val), (..., Z, K).

    Never materializes the (Z, Q) log-prob matrix — the sweep block lives
    in VMEM and only K entries per request come back. The default ``bz``
    covers Z <= 1024 in a single sweep."""
    return policy_score_decode_fwd(c_emb, h_emb, w_px, w_py, edge_mask,
                                   tanh_clip=tanh_clip, k=k,
                                   normalize=normalize, bz=bz,
                                   interpret=interpret_mode())


__all__ = ["flash_attention", "decode_attention", "mamba_scan",
           "policy_score", "policy_score_decode", "ref", "interpret_mode"]
