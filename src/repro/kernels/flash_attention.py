"""Pallas TPU flash-attention (forward) kernel.

Grid (B, H, nq, nk): the kv-block axis is innermost and iterated
sequentially on TPU, so the online-softmax accumulators live in VMEM
scratch across the nk sweep. Causal/window block skipping via pl.when —
skipped blocks cost zero MXU work (unlike a masked dense formulation).
GQA is handled by indexing k/v blocks with h // group_size.

Block shapes default to (128, head_dim): MXU-aligned, and the working set
(q, k, v blocks + f32 accumulators) stays well under VMEM (~1 MiB at
hd=128, bq=bk=128).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window, bq: int, bk: int, nk: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level skip: the whole (i, j) tile is dead if its q range is
    # entirely before its kv range (causal) or entirely after the window
    live = jnp.bool_(True)
    if causal:
        live &= (j * bk) <= (i * bq + bq - 1)
    if window is not None:
        live &= (j * bk + bk - 1) > (i * bq - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)
        rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p.astype(v.dtype), v)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window=None,
                        bq: int = 128, bk: int = 128,
                        interpret: bool = False):
    """q: (B, S, H, hd); k, v: (B, S, KV, hd) -> (B, S, H, hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk
    scale = 1.0 / math.sqrt(hd)

    # layout: (B, H, S, hd) blocks
    qT = q.transpose(0, 2, 1, 3)
    kT = k.transpose(0, 2, 1, 3)
    vT = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),   # acc
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running sum
        ],
        interpret=interpret,
    )(qT, kT, vT)
    return out.transpose(0, 2, 1, 3)
