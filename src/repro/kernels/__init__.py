"""Pallas TPU kernels for the perf-critical hot spots, with jnp oracles.

flash_attention  — blocked causal/SWA prefill attention (online softmax)
decode_attention — GQA flash-decode against a rolling KV cache
mamba_scan       — chunked selective scan (mamba-1)
policy_score     — fused CoRaiS policy head (paper eqs 16-17)

Use via repro.kernels.ops (jit'd wrappers; interpret=True off-TPU).
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
