"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """q: (B, S, H, hd); k, v: (B, S, KV, hd) -> (B, S, H, hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bmkd->bkgqm", qg, k.astype(jnp.float32)) / math.sqrt(hd)
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqm,bmkd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, slot_pos, pos, *, window=None):
    """q: (B, H, hd); k/v_cache: (B, W, KV, hd); slot_pos: (B, W); pos: (B,)."""
    B, W, KV, hd = k_cache.shape
    H = q.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bmkd->bkgm", qg, k_cache.astype(jnp.float32)) / math.sqrt(hd)
    valid = (slot_pos >= 0) & (slot_pos <= pos[:, None])
    if window is not None:
        valid &= slot_pos > (pos[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgm,bmkd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)


def mamba_scan_ref(u, dt, B_mat, C_mat, A, h0=None):
    """u, dt: (B, S, d); B_mat, C_mat: (B, S, N); A: (d, N).
    Returns (y (B, S, d) f32, h_last (B, d, N) f32)."""
    b, s, d = u.shape
    n = A.shape[-1]
    dA = jnp.exp(dt[..., None].astype(jnp.float32) * A)          # (B,S,d,N)
    dBu = (dt[..., None] * B_mat[:, :, None, :] * u[..., None]).astype(jnp.float32)

    def step(h, xs):
        da_t, dbu_t, c_t = xs
        h = da_t * h + dbu_t
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    h = jnp.zeros((b, d, n), jnp.float32) if h0 is None else h0
    h_last, ys = jax.lax.scan(
        step, h,
        (dA.swapaxes(0, 1), dBu.swapaxes(0, 1),
         C_mat.astype(jnp.float32).swapaxes(0, 1)))
    return ys.swapaxes(0, 1), h_last


def policy_score_ref(c_emb, h_emb, w_px, w_py, edge_mask, tanh_clip=10.0):
    """Fused CoRaiS policy head (paper eqs 16-17).

    c_emb: (Q, d) context-decoder edge embeddings; h_emb: (Z, d) request
    embeddings; returns log a_qz transposed to (Z, Q)."""
    d = c_emb.shape[-1]
    px = c_emb.astype(jnp.float32) @ w_px.astype(jnp.float32)
    py = h_emb.astype(jnp.float32) @ w_py.astype(jnp.float32)
    u = (py @ px.T) / math.sqrt(d)  # (Z, Q)
    imp = tanh_clip * jnp.tanh(u)
    imp = jnp.where(edge_mask[None, :], imp, -1e9)
    return jax.nn.log_softmax(imp, axis=-1)


def policy_score_xla(c_emb, h_emb, w_px, w_py, edge_mask, tanh_clip=10.0):
    """Batched plain-XLA policy head: the einsum formulation the network
    used before the head was factored out, over any leading batch shape.

    c_emb: (..., Q, d); h_emb: (..., Z, d); edge_mask: (..., Q) or (Q,).
    Returns (..., Z, Q) log a_qz."""
    d = c_emb.shape[-1]
    px = c_emb @ w_px
    py = h_emb @ w_py
    u = jnp.einsum("...zd,...qd->...zq", py, px) / math.sqrt(d)
    imp = tanh_clip * jnp.tanh(u)  # eq (16)
    imp = jnp.where(edge_mask[..., None, :], imp, -1e9)
    return jax.nn.log_softmax(imp, axis=-1)  # eq (17): softmax over edges


def policy_score_decode_ref(c_emb, h_emb, w_px, w_py, edge_mask,
                            tanh_clip=10.0, k=1, normalize=True):
    """Per-instance decode oracle: materialize the (Z, Q) matrix and sort.

    c_emb: (Q, d); h_emb: (Z, d); returns (top_idx, top_val), both (Z, K)
    — lowest-index-first on ties (the jnp.argmax / lax.top_k rule), so the
    fused kernel can be pinned against it exactly. ``normalize=False``
    returns the clipped compatibilities (eq 16) instead of log-probs."""
    d = c_emb.shape[-1]
    px = c_emb.astype(jnp.float32) @ w_px.astype(jnp.float32)
    py = h_emb.astype(jnp.float32) @ w_py.astype(jnp.float32)
    u = (py @ px.T) / math.sqrt(d)  # (Z, Q)
    imp = jnp.where(edge_mask[None, :], tanh_clip * jnp.tanh(u), -1e9)
    # stable argsort of -imp == top-k with ties broken toward lower index
    top_idx = jnp.argsort(-imp, axis=-1)[..., :k].astype(jnp.int32)
    top_val = jnp.take_along_axis(imp, top_idx, axis=-1)
    if normalize:
        top_val = top_val - jax.nn.logsumexp(imp, axis=-1, keepdims=True)
    return top_idx, top_val


def policy_score_decode_xla(c_emb, h_emb, w_px, w_py, edge_mask,
                            tanh_clip=10.0, k=1, normalize=True):
    """Batched plain-XLA decode: ``lax.top_k`` over the materialized head,
    any leading batch shape — the drop-in comparison path for the fused
    decode kernel (same (top_idx, top_val) contract, (..., Z, K))."""
    d = c_emb.shape[-1]
    px = c_emb @ w_px
    py = h_emb @ w_py
    u = jnp.einsum("...zd,...qd->...zq", py, px) / math.sqrt(d)
    imp = jnp.where(edge_mask[..., None, :], tanh_clip * jnp.tanh(u), -1e9)
    top_val, top_idx = jax.lax.top_k(imp, k)
    if normalize:
        top_val = top_val - jax.nn.logsumexp(imp, axis=-1, keepdims=True)
    return top_idx.astype(jnp.int32), top_val
