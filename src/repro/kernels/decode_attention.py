"""Pallas TPU GQA flash-decode kernel.

One query token per sequence attends to a (possibly rolling) KV cache.
Grid (B, KV, nw): the cache-window axis is innermost; online-softmax
accumulators for all G query heads of one kv head live in VMEM scratch.
Slot validity (absolute position per slot, sliding window) is evaluated
in-kernel from the slot_pos block, so rolling caches need no host-side
re-packing. This is the TPU-idiomatic analogue of split-K paged attention
(DESIGN.md §4): on the production mesh the cache's window axis is sharded
over `model`, and the per-shard partial softmax combines via psum.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, sp_ref, o_ref, acc_ref, m_ref, l_ref,
            *, scale: float, window, bk: int, nw: int, G: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)    # (bk, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    slots = sp_ref[0]                            # (bk,) absolute positions
    pos = pos_ref[0]                             # scalar
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (G, bk)
    valid = (slots >= 0) & (slots <= pos)
    if window is not None:
        valid &= slots > pos - window
    s = jnp.where(valid[None, :], s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(p, v)
    m_ref[...] = m_new

    @pl.when(j == nw - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def decode_attention_fwd(q, k_cache, v_cache, slot_pos, pos, *, window=None,
                         bk: int = 128, interpret: bool = False):
    """q: (B, H, hd); k/v_cache: (B, W, KV, hd); slot_pos: (B, W) int32;
    pos: (B,) int32 -> (B, H, hd)."""
    B, W, KV, hd = k_cache.shape
    H = q.shape[1]
    G = H // KV
    bk = min(bk, W)
    assert W % bk == 0, (W, bk)
    nw = W // bk
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KV, G, hd)

    kernel = functools.partial(_kernel, scale=scale, window=window,
                               bk=bk, nw=nw, G=G)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, nw),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,)),                    # pos
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),   # q
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, j: (b, j, h, 0)),  # k
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, j: (b, j, h, 0)),  # v
            pl.BlockSpec((1, bk), lambda b, h, j: (b, j)),               # slot_pos
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        interpret=interpret,
    )(pos, qg, k_cache, v_cache, slot_pos)
    return out.reshape(B, H, hd)
