"""Pallas TPU fused CoRaiS policy-scoring kernel (paper eqs 16-17).

The real-time hot path of the scheduler: two projections, the (Z, Q)
compatibility matmul, C*tanh clipping, edge masking and the log-softmax
over edges — fused into one kernel so the intermediate (Z, Q) score matrix
never round-trips HBM. The kernel carries a leading batch axis (grid
(B, Z-blocks)) and a ``custom_vjp`` backward (also a fused Pallas kernel),
so it composes with ``vmap`` / ``grad`` — batched engine rollouts and
REINFORCE both run straight through it, and interpret mode executes the
same bodies on CPU.

Forward is blocked over requests (Z); the edge-context block (Q <= 128
edges, d <= 512) and both projection matrices stay resident in VMEM across
the sweep. On the Table-II scales (Q <= 10, Z <= 100, d = 256) the entire
problem is a single block. The backward kernel processes one batch element
per grid step (whole (Z, d) block; fine to a few thousand requests at
d = 256 within the ~16 MB VMEM budget) and recomputes the compatibility
matrix flash-attention-style instead of saving it.

Neither kernel body reads ``pl.program_id``: all indexing lives in the
BlockSpec index maps, which keeps the kernels correct under ``vmap``'s
pallas batching rule (it prepends a fresh grid dimension).

The *fused decode* variant (:func:`policy_score_decode_fwd`) goes one step
further for the real-time serving path: greedy argmax and top-k candidate
selection happen inside the kernel, so a decision never materializes the
(Z, Q) log-prob matrix — per Z-block the compatibility tile lives only in
VMEM and the kernel emits ``(edge_index, value)`` pairs (a ``(Z, K)``
candidate set for sampled dispatch). Two algebraic optimizations make it
cheaper than score-then-argmax even before the HBM traffic is counted:

  * the request projection is folded into the edge side —
    ``u = h @ (w_py @ (c @ w_px)^T)`` — turning the (Z, d) x (d, d)
    projection into a (d, Q) one (Q << Z on every paper scale), and
  * with ``normalize=False`` the selection runs in u-space (``tanh`` is
    monotone, so argmax/top-k commute with it) and ``tanh`` is applied to
    the K selected values only, skipping the (Z, Q) transcendental sweep
    and the log-softmax normalizer entirely. ``normalize=True`` keeps the
    eq-17 semantics and emits true log-probabilities.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(c_ref, h_ref, wpx_ref, wpy_ref, mask_ref, o_ref, *,
                scale: float, tanh_clip: float):
    c = c_ref[0].astype(jnp.float32)          # (Q, d)
    h = h_ref[0].astype(jnp.float32)          # (bz, d)
    px = jax.lax.dot(c, wpx_ref[...].astype(jnp.float32))   # (Q, d)
    py = jax.lax.dot(h, wpy_ref[...].astype(jnp.float32))   # (bz, d)
    u = jax.lax.dot_general(py, px, (((1,), (1,)), ((), ()))) * scale  # (bz, Q)
    imp = tanh_clip * jnp.tanh(u)
    imp = jnp.where(mask_ref[0][None, :] > 0.5, imp, -1e9)
    m = jnp.max(imp, axis=1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(imp - m), axis=1, keepdims=True)) + m
    o_ref[0] = (imp - lse).astype(o_ref.dtype)


def _bwd_kernel(g_ref, o_ref, c_ref, h_ref, wpx_ref, wpy_ref, mask_ref,
                dc_ref, dh_ref, dwx_ref, dwy_ref, *,
                scale: float, tanh_clip: float):
    g = g_ref[0].astype(jnp.float32)          # (Z, Q) cotangent of log a
    out = o_ref[0].astype(jnp.float32)        # (Z, Q) saved log-probs
    c = c_ref[0].astype(jnp.float32)          # (Q, d)
    h = h_ref[0].astype(jnp.float32)          # (Z, d)
    wx = wpx_ref[...].astype(jnp.float32)
    wy = wpy_ref[...].astype(jnp.float32)
    keep = mask_ref[0][None, :] > 0.5         # (1, Q)

    # d log_softmax: g - softmax * sum_q g  (softmax = exp(saved log-probs))
    gi = g - jnp.exp(out) * jnp.sum(g, axis=1, keepdims=True)
    # recompute the compatibility matrix (cheaper than saving (Z, Q) twice)
    px = jax.lax.dot(c, wx)                   # (Q, d)
    py = jax.lax.dot(h, wy)                   # (Z, d)
    u = jax.lax.dot_general(py, px, (((1,), (1,)), ((), ()))) * scale
    th = jnp.tanh(u)
    # masked edges saw a constant -1e9: no gradient flows through them
    gu = jnp.where(keep, gi * (tanh_clip * scale) * (1.0 - th * th), 0.0)

    dpy = jax.lax.dot(gu, px)                                          # (Z, d)
    dpx = jax.lax.dot_general(gu, py, (((0,), (0,)), ((), ())))        # (Q, d)
    dc_ref[0] = jax.lax.dot_general(dpx, wx, (((1,), (1,)), ((), ())))
    dh_ref[0] = jax.lax.dot_general(dpy, wy, (((1,), (1,)), ((), ())))
    dwx_ref[0] = jax.lax.dot_general(c, dpx, (((0,), (0,)), ((), ())))
    dwy_ref[0] = jax.lax.dot_general(h, dpy, (((0,), (0,)), ((), ())))


def _pad_z(x, bz: int):
    pad = (-x.shape[1]) % bz
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    return x


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _policy_score(c_emb, h_emb, w_px, w_py, maskf, tanh_clip, bz, interpret):
    out, _ = _policy_score_fwd(c_emb, h_emb, w_px, w_py, maskf,
                               tanh_clip, bz, interpret)
    return out


def _policy_score_fwd(c_emb, h_emb, w_px, w_py, maskf, tanh_clip, bz,
                      interpret):
    b, q, d = c_emb.shape
    z = h_emb.shape[1]
    bz = min(bz, z)
    hp = _pad_z(h_emb, bz)
    nz = hp.shape[1] // bz
    kernel = functools.partial(_fwd_kernel, scale=1.0 / math.sqrt(d),
                               tanh_clip=tanh_clip)
    out = pl.pallas_call(
        kernel,
        grid=(b, nz),
        in_specs=[
            pl.BlockSpec((1, q, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bz, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((d, d), lambda i, j: (0, 0)),
            pl.BlockSpec((d, d), lambda i, j: (0, 0)),
            pl.BlockSpec((1, q), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bz, q), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hp.shape[1], q), jnp.float32),
        interpret=interpret,
    )(c_emb, hp, w_px, w_py, maskf)
    out = out[:, :z]
    return out, (c_emb, h_emb, w_px, w_py, maskf, out)


def _policy_score_bwd(tanh_clip, bz, interpret, res, g):
    c_emb, h_emb, w_px, w_py, maskf, out = res
    b, q, d = c_emb.shape
    z = h_emb.shape[1]
    # Zero-padded rows carry zero cotangent, so they contribute nothing.
    gp = _pad_z(g.astype(jnp.float32), 8)
    op = _pad_z(out, 8)
    hp = _pad_z(h_emb, 8)
    zp = hp.shape[1]
    kernel = functools.partial(_bwd_kernel, scale=1.0 / math.sqrt(d),
                               tanh_clip=tanh_clip)
    dc, dh, dwx, dwy = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, zp, q), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, zp, q), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, zp, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((1, q), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, zp, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d, d), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, q, d), jnp.float32),
            jax.ShapeDtypeStruct((b, zp, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d, d), jnp.float32),
        ],
        interpret=interpret,
    )(gp, op, c_emb, hp, w_px, w_py, maskf)
    return (dc.astype(c_emb.dtype), dh[:, :z].astype(h_emb.dtype),
            jnp.sum(dwx, 0).astype(w_px.dtype),
            jnp.sum(dwy, 0).astype(w_py.dtype), jnp.zeros_like(maskf))


_policy_score.defvjp(_policy_score_fwd, _policy_score_bwd)


def _decode_kernel(c_ref, h_ref, wpx_ref, wpy_ref, mask_ref, ti_ref, tv_ref,
                   *, scale: float, tanh_clip: float, k: int,
                   normalize: bool):
    cc = c_ref[0].astype(jnp.float32)                        # (Q, d)
    hh = h_ref[0].astype(jnp.float32)                        # (bz, d)
    px = jax.lax.dot(cc, wpx_ref[...].astype(jnp.float32))   # (Q, d)
    # fold the request projection into the edge side: (d, Q), so the big
    # matmul is the only one that touches the Z axis
    pxy = jax.lax.dot(wpy_ref[...].astype(jnp.float32), px.T)
    u = jax.lax.dot(hh, pxy) * scale                         # (bz, Q)
    keep = mask_ref[0][None, :] > 0.5
    qn = u.shape[1]
    ids = jax.lax.broadcasted_iota(jnp.int32, u.shape, 1)
    if normalize:
        sel = jnp.where(keep, tanh_clip * jnp.tanh(u), -1e9)
        m = jnp.max(sel, axis=1, keepdims=True)
        lse = jnp.log(jnp.sum(jnp.exp(sel - m), axis=1, keepdims=True)) + m
    else:
        # tanh is monotone: select in u-space, clip only the winners
        sel = jnp.where(keep, u, -jnp.inf)
    idxs, vals = [], []
    cur = sel
    for j in range(k):  # k is static and small: unrolled running top-k
        mj = jnp.max(cur, axis=1)
        # first index attaining the max (jnp.argmax tie rule)
        ij = jnp.min(jnp.where(cur == mj[:, None], ids, qn), axis=1)
        idxs.append(ij)
        vals.append(mj)
        if j + 1 < k:
            cur = jnp.where(ids == ij[:, None], -jnp.inf, cur)
    ti = jnp.stack(idxs, axis=1)                             # (bz, K)
    tv = jnp.stack(vals, axis=1)
    tv = tv - lse if normalize else tanh_clip * jnp.tanh(tv)
    ti_ref[0] = ti.astype(jnp.int32)
    tv_ref[0] = tv.astype(jnp.float32)


def policy_score_decode_fwd(c_emb, h_emb, w_px, w_py, edge_mask, *,
                            tanh_clip: float = 10.0, k: int = 1,
                            normalize: bool = True, bz: int = 1024,
                            interpret: bool = False):
    """Fused score + decode: per-request top-k edges without ever writing
    the (Z, Q) log-prob matrix to HBM.

    Same input contract as :func:`policy_score_fwd`; returns
    ``(top_idx, top_val)`` of shape (..., Z, K) — ``top_idx[..., 0]`` is
    the greedy decision. With ``normalize=True`` the values are true
    eq-17 log-probabilities; with ``normalize=False`` they are the clipped
    compatibilities (eq 16) of the selected edges — the edge ranking is
    identical (softmax and tanh are monotone), which is the serving fast
    path: a dispatch decision needs the index, not the normalizer.
    Candidate slots beyond the number of unmasked edges are undefined —
    keep ``k`` at or below the valid-edge count. Not differentiable (and
    doesn't need to be: training scores, serving decodes)."""
    batch_shape = c_emb.shape[:-2]
    q, d = c_emb.shape[-2:]
    z = h_emb.shape[-2]
    c3 = c_emb.reshape((-1, q, d))
    h3 = h_emb.reshape((-1, z, d))
    maskf = jnp.broadcast_to(edge_mask, batch_shape + (q,))
    maskf = maskf.reshape((-1, q)).astype(jnp.float32)
    b = c3.shape[0]
    bz = min(bz, z)
    hp = _pad_z(h3, bz)
    nz = hp.shape[1] // bz
    kernel = functools.partial(_decode_kernel, scale=1.0 / math.sqrt(d),
                               tanh_clip=tanh_clip, k=k, normalize=normalize)
    ti, tv = pl.pallas_call(
        kernel,
        grid=(b, nz),
        in_specs=[
            pl.BlockSpec((1, q, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bz, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((d, d), lambda i, j: (0, 0)),
            pl.BlockSpec((d, d), lambda i, j: (0, 0)),
            pl.BlockSpec((1, q), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bz, k), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bz, k), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hp.shape[1], k), jnp.int32),
            jax.ShapeDtypeStruct((b, hp.shape[1], k), jnp.float32),
        ],
        interpret=interpret,
    )(c3, hp, w_px, w_py, maskf)
    ti = ti[:, :z].reshape(batch_shape + (z, k))
    tv = tv[:, :z].reshape(batch_shape + (z, k))
    return ti, tv


def policy_score_fwd(c_emb, h_emb, w_px, w_py, edge_mask, *,
                     tanh_clip: float = 10.0, bz: int = 256,
                     interpret: bool = False):
    """Fused log a_qz (paper eq 17) with any leading batch shape.

    c_emb: (..., Q, d) context-decoder edge embeddings; h_emb: (..., Z, d)
    request embeddings; w_px / w_py: (d, d) shared projections; edge_mask:
    (..., Q) or (Q,) bool/float. Returns (..., Z, Q) float32 log-probs.
    Differentiable wrt the embeddings and both projections (custom VJP).
    """
    batch_shape = c_emb.shape[:-2]
    q, d = c_emb.shape[-2:]
    z = h_emb.shape[-2]
    c3 = c_emb.reshape((-1, q, d))
    h3 = h_emb.reshape((-1, z, d))
    maskf = jnp.broadcast_to(edge_mask, batch_shape + (q,))
    maskf = maskf.reshape((-1, q)).astype(jnp.float32)
    out = _policy_score(c3, h3, w_px, w_py, maskf,
                        float(tanh_clip), int(bz), bool(interpret))
    return out.reshape(batch_shape + (z, q))
