"""Pallas TPU fused CoRaiS policy-scoring kernel (paper eqs 16-17).

The real-time hot path of the scheduler: two projections, the (Z, Q)
compatibility matmul, C*tanh clipping, edge masking and the log-softmax
over edges — fused into one kernel so the intermediate (Z, Q) score matrix
never round-trips HBM. The kernel carries a leading batch axis (grid
(B, Z-blocks)) and a ``custom_vjp`` backward (also a fused Pallas kernel),
so it composes with ``vmap`` / ``grad`` — batched engine rollouts and
REINFORCE both run straight through it, and interpret mode executes the
same bodies on CPU.

Forward is blocked over requests (Z); the edge-context block (Q <= 128
edges, d <= 512) and both projection matrices stay resident in VMEM across
the sweep. On the Table-II scales (Q <= 10, Z <= 100, d = 256) the entire
problem is a single block. The backward kernel processes one batch element
per grid step (whole (Z, d) block; fine to a few thousand requests at
d = 256 within the ~16 MB VMEM budget) and recomputes the compatibility
matrix flash-attention-style instead of saving it.

Neither kernel body reads ``pl.program_id``: all indexing lives in the
BlockSpec index maps, which keeps the kernels correct under ``vmap``'s
pallas batching rule (it prepends a fresh grid dimension).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(c_ref, h_ref, wpx_ref, wpy_ref, mask_ref, o_ref, *,
                scale: float, tanh_clip: float):
    c = c_ref[0].astype(jnp.float32)          # (Q, d)
    h = h_ref[0].astype(jnp.float32)          # (bz, d)
    px = jax.lax.dot(c, wpx_ref[...].astype(jnp.float32))   # (Q, d)
    py = jax.lax.dot(h, wpy_ref[...].astype(jnp.float32))   # (bz, d)
    u = jax.lax.dot_general(py, px, (((1,), (1,)), ((), ()))) * scale  # (bz, Q)
    imp = tanh_clip * jnp.tanh(u)
    imp = jnp.where(mask_ref[0][None, :] > 0.5, imp, -1e9)
    m = jnp.max(imp, axis=1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(imp - m), axis=1, keepdims=True)) + m
    o_ref[0] = (imp - lse).astype(o_ref.dtype)


def _bwd_kernel(g_ref, o_ref, c_ref, h_ref, wpx_ref, wpy_ref, mask_ref,
                dc_ref, dh_ref, dwx_ref, dwy_ref, *,
                scale: float, tanh_clip: float):
    g = g_ref[0].astype(jnp.float32)          # (Z, Q) cotangent of log a
    out = o_ref[0].astype(jnp.float32)        # (Z, Q) saved log-probs
    c = c_ref[0].astype(jnp.float32)          # (Q, d)
    h = h_ref[0].astype(jnp.float32)          # (Z, d)
    wx = wpx_ref[...].astype(jnp.float32)
    wy = wpy_ref[...].astype(jnp.float32)
    keep = mask_ref[0][None, :] > 0.5         # (1, Q)

    # d log_softmax: g - softmax * sum_q g  (softmax = exp(saved log-probs))
    gi = g - jnp.exp(out) * jnp.sum(g, axis=1, keepdims=True)
    # recompute the compatibility matrix (cheaper than saving (Z, Q) twice)
    px = jax.lax.dot(c, wx)                   # (Q, d)
    py = jax.lax.dot(h, wy)                   # (Z, d)
    u = jax.lax.dot_general(py, px, (((1,), (1,)), ((), ()))) * scale
    th = jnp.tanh(u)
    # masked edges saw a constant -1e9: no gradient flows through them
    gu = jnp.where(keep, gi * (tanh_clip * scale) * (1.0 - th * th), 0.0)

    dpy = jax.lax.dot(gu, px)                                          # (Z, d)
    dpx = jax.lax.dot_general(gu, py, (((0,), (0,)), ((), ())))        # (Q, d)
    dc_ref[0] = jax.lax.dot_general(dpx, wx, (((1,), (1,)), ((), ())))
    dh_ref[0] = jax.lax.dot_general(dpy, wy, (((1,), (1,)), ((), ())))
    dwx_ref[0] = jax.lax.dot_general(c, dpx, (((0,), (0,)), ((), ())))
    dwy_ref[0] = jax.lax.dot_general(h, dpy, (((0,), (0,)), ((), ())))


def _pad_z(x, bz: int):
    pad = (-x.shape[1]) % bz
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    return x


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _policy_score(c_emb, h_emb, w_px, w_py, maskf, tanh_clip, bz, interpret):
    out, _ = _policy_score_fwd(c_emb, h_emb, w_px, w_py, maskf,
                               tanh_clip, bz, interpret)
    return out


def _policy_score_fwd(c_emb, h_emb, w_px, w_py, maskf, tanh_clip, bz,
                      interpret):
    b, q, d = c_emb.shape
    z = h_emb.shape[1]
    bz = min(bz, z)
    hp = _pad_z(h_emb, bz)
    nz = hp.shape[1] // bz
    kernel = functools.partial(_fwd_kernel, scale=1.0 / math.sqrt(d),
                               tanh_clip=tanh_clip)
    out = pl.pallas_call(
        kernel,
        grid=(b, nz),
        in_specs=[
            pl.BlockSpec((1, q, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bz, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((d, d), lambda i, j: (0, 0)),
            pl.BlockSpec((d, d), lambda i, j: (0, 0)),
            pl.BlockSpec((1, q), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bz, q), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hp.shape[1], q), jnp.float32),
        interpret=interpret,
    )(c_emb, hp, w_px, w_py, maskf)
    out = out[:, :z]
    return out, (c_emb, h_emb, w_px, w_py, maskf, out)


def _policy_score_bwd(tanh_clip, bz, interpret, res, g):
    c_emb, h_emb, w_px, w_py, maskf, out = res
    b, q, d = c_emb.shape
    z = h_emb.shape[1]
    # Zero-padded rows carry zero cotangent, so they contribute nothing.
    gp = _pad_z(g.astype(jnp.float32), 8)
    op = _pad_z(out, 8)
    hp = _pad_z(h_emb, 8)
    zp = hp.shape[1]
    kernel = functools.partial(_bwd_kernel, scale=1.0 / math.sqrt(d),
                               tanh_clip=tanh_clip)
    dc, dh, dwx, dwy = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, zp, q), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, zp, q), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, zp, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((1, q), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, zp, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d, d), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, q, d), jnp.float32),
            jax.ShapeDtypeStruct((b, zp, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d, d), jnp.float32),
        ],
        interpret=interpret,
    )(gp, op, c_emb, hp, w_px, w_py, maskf)
    return (dc.astype(c_emb.dtype), dh[:, :z].astype(h_emb.dtype),
            jnp.sum(dwx, 0).astype(w_px.dtype),
            jnp.sum(dwy, 0).astype(w_py.dtype), jnp.zeros_like(maskf))


_policy_score.defvjp(_policy_score_fwd, _policy_score_bwd)


def policy_score_fwd(c_emb, h_emb, w_px, w_py, edge_mask, *,
                     tanh_clip: float = 10.0, bz: int = 256,
                     interpret: bool = False):
    """Fused log a_qz (paper eq 17) with any leading batch shape.

    c_emb: (..., Q, d) context-decoder edge embeddings; h_emb: (..., Z, d)
    request embeddings; w_px / w_py: (d, d) shared projections; edge_mask:
    (..., Q) or (Q,) bool/float. Returns (..., Z, Q) float32 log-probs.
    Differentiable wrt the embeddings and both projections (custom VJP).
    """
    batch_shape = c_emb.shape[:-2]
    q, d = c_emb.shape[-2:]
    z = h_emb.shape[-2]
    c3 = c_emb.reshape((-1, q, d))
    h3 = h_emb.reshape((-1, z, d))
    maskf = jnp.broadcast_to(edge_mask, batch_shape + (q,))
    maskf = maskf.reshape((-1, q)).astype(jnp.float32)
    out = _policy_score(c3, h3, w_px, w_py, maskf,
                        float(tanh_clip), int(bz), bool(interpret))
    return out.reshape(batch_shape + (z, q))
