"""Pallas TPU fused CoRaiS policy-scoring kernel (paper eqs 16-17).

The real-time hot path of the scheduler: two projections, the (Z, Q)
compatibility matmul, C*tanh clipping, edge masking and the log-softmax
over edges — fused into one kernel so the intermediate (Z, Q) score matrix
never round-trips HBM. Blocked over requests (Z); the edge-context block
(Q <= 128 edges, d <= 512) and both projection matrices stay resident in
VMEM across the sweep. On the Table-II scales (Q <= 10, Z <= 100, d = 256)
the entire problem is a single block.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(c_ref, h_ref, wpx_ref, wpy_ref, mask_ref, o_ref, *,
            scale: float, tanh_clip: float):
    c = c_ref[...].astype(jnp.float32)        # (Q, d)
    h = h_ref[...].astype(jnp.float32)        # (bz, d)
    px = jax.lax.dot(c, wpx_ref[...].astype(jnp.float32))   # (Q, d)
    py = jax.lax.dot(h, wpy_ref[...].astype(jnp.float32))   # (bz, d)
    u = jax.lax.dot_general(py, px, (((1,), (1,)), ((), ()))) * scale  # (bz, Q)
    imp = tanh_clip * jnp.tanh(u)
    imp = jnp.where(mask_ref[...][None, :], imp, -1e9)
    m = jnp.max(imp, axis=1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(imp - m), axis=1, keepdims=True)) + m
    o_ref[...] = (imp - lse).astype(o_ref.dtype)


def policy_score_fwd(c_emb, h_emb, w_px, w_py, edge_mask, *,
                     tanh_clip: float = 10.0, bz: int = 256,
                     interpret: bool = False):
    """c_emb: (Q, d); h_emb: (Z, d); w_px/w_py: (d, d); edge_mask: (Q,) bool.
    Returns log a_qz as (Z, Q)."""
    q, d = c_emb.shape
    z = h_emb.shape[0]
    bz = min(bz, z)
    pad_z = (-z) % bz
    if pad_z:
        h_emb = jnp.pad(h_emb, ((0, pad_z), (0, 0)))
    zp = z + pad_z
    kernel = functools.partial(_kernel, scale=1.0 / math.sqrt(d),
                               tanh_clip=tanh_clip)
    out = pl.pallas_call(
        kernel,
        grid=(zp // bz,),
        in_specs=[
            pl.BlockSpec((q, d), lambda i: (0, 0)),
            pl.BlockSpec((bz, d), lambda i: (i, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((q,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bz, q), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((zp, q), jnp.float32),
        interpret=interpret,
    )(c_emb, h_emb, w_px, w_py, edge_mask)
    return out[:z]
