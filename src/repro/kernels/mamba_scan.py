"""Pallas TPU chunked selective-scan kernel (mamba-1).

TPU adaptation of the fused CUDA selective scan (DESIGN.md §4): grid
(B, nd, nc) where nd blocks d_inner and the chunk axis nc is innermost and
sequential; the (bd, N) hidden state is carried across chunks in VMEM
scratch, and each chunk's discretized (c, bd, N) tensors exist only as
VMEM-resident temporaries inside the kernel. The time loop inside a chunk
is a lax.fori_loop over VPU elementwise ops — mamba is memory-bound, and
this layout streams u/dt/B/C exactly once from HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, hout_ref, h_ref, *,
            chunk: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...]  # (bd, N)

    def step(t, h):
        dt_t = dt_ref[0, t, :]          # (bd,)
        u_t = u_ref[0, t, :]
        b_t = b_ref[0, t, :]            # (N,)
        c_t = c_ref[0, t, :]
        da = jnp.exp(dt_t[:, None] * a)             # (bd, N)
        dbu = (dt_t * u_t)[:, None] * b_t[None, :]  # (bd, N)
        h = da * h + dbu
        y_ref[0, t, :] = jnp.sum(h * c_t[None, :], axis=1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ci == nc - 1)
    def _finish():
        hout_ref[0] = h.astype(hout_ref.dtype)


def mamba_scan_fwd(u, dt, B_mat, C_mat, A, *, chunk: int = 128,
                   bd: int = 256, interpret: bool = False):
    """u, dt: (B, S, d) f32; B_mat, C_mat: (B, S, N) f32; A: (d, N) f32.
    Returns (y (B, S, d) f32, h_last (B, d, N) f32)."""
    b, s, d = u.shape
    n = A.shape[-1]
    chunk = min(chunk, s)
    bd = min(bd, d)
    assert s % chunk == 0 and d % bd == 0, (s, chunk, d, bd)
    nc = s // chunk
    nd = d // bd

    kernel = functools.partial(_kernel, chunk=chunk, nc=nc)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(b, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda bi, di, ci: (bi, ci, di)),  # u
            pl.BlockSpec((1, chunk, bd), lambda bi, di, ci: (bi, ci, di)),  # dt
            pl.BlockSpec((1, chunk, n), lambda bi, di, ci: (bi, ci, 0)),    # B
            pl.BlockSpec((1, chunk, n), lambda bi, di, ci: (bi, ci, 0)),    # C
            pl.BlockSpec((bd, n), lambda bi, di, ci: (di, 0)),              # A
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bd), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, bd, n), lambda bi, di, ci: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(u, dt, B_mat, C_mat, A)
    return y, h_last
