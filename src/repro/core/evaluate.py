"""Evaluation harness: gaps vs. the reference solver (paper §V, eq 22) on
static instances, plus temporal rollout evaluation on the batched engine."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import instances as inst_lib
from repro.core.heuristics import solve_ils, solve_local, solve_random
from repro.core.inference import DecisionSpec, make_decision_fn
from repro.core.objective import makespan_np
from repro.core.policy import PolicyConfig
from repro.serving import engine as engine_lib
from repro.workloads import materialize_round_batch


@dataclasses.dataclass
class MethodResult:
    name: str
    mean_time_s: float
    mean_cost: float
    mean_gap: float
    solved_frac: float = 1.0


def _policy_method(params, state, cfg: PolicyConfig, mode: str, n: int,
                   seed: int, backend: str = None):
    """Returns fn(inst) -> (assign, solve_time). The shared decision path
    (core.inference) jits once and is reused across instances of identical
    padded shape (the paper's real-time setting)."""
    decide = make_decision_fn(params, state, cfg,
                              DecisionSpec(mode=mode, num_samples=n,
                                           backend=backend))
    key_holder = [jax.random.PRNGKey(seed)]

    def run(inst):
        jinst = jax.tree.map(jnp.asarray, inst)
        key_holder[0], sub = jax.random.split(key_holder[0])
        t0 = time.perf_counter()
        assign = np.asarray(jax.block_until_ready(decide(jinst, sub)))
        return assign, time.perf_counter() - t0

    return run


def evaluate_methods(
    instances: list,
    methods: dict[str, Callable],
    reference: str,
) -> dict[str, MethodResult]:
    """Run every method on every instance; gap_b = L(pi|b) / L(pi|REF)."""
    per_method_costs: dict[str, list[float]] = {m: [] for m in methods}
    per_method_times: dict[str, list[float]] = {m: [] for m in methods}
    for inst in instances:
        for name, fn in methods.items():
            t0 = time.perf_counter()
            out = fn(inst)
            if isinstance(out, tuple):
                assign, dt = out
            else:
                assign, dt = out, time.perf_counter() - t0
            per_method_costs[name].append(makespan_np(inst, assign))
            per_method_times[name].append(dt)

    ref_costs = np.asarray(per_method_costs[reference])
    results = {}
    for name in methods:
        costs = np.asarray(per_method_costs[name])
        gaps = costs / np.maximum(ref_costs, 1e-9)
        results[name] = MethodResult(
            name=name,
            mean_time_s=float(np.mean(per_method_times[name])),
            mean_cost=float(np.mean(costs)),
            mean_gap=float(np.mean(gaps)),
        )
    return results


def standard_method_suite(
    params=None,
    state=None,
    policy_cfg: Optional[PolicyConfig] = None,
    ref_budget_s: float = 1.0,
    random_ns=(1, 100, 1000),
    sample_ns=(100, 1000),
):
    """The paper's Table II method set, minus Gurobi (see DESIGN.md §3)."""
    methods: dict[str, Callable] = {}
    methods[f"ILS({ref_budget_s}s)"] = lambda inst: solve_ils(inst, budget_s=ref_budget_s)
    methods["Local"] = solve_local
    for n in random_ns:
        methods[f"Random({n})"] = (lambda n_: lambda inst: solve_random(inst, n_, seed=0))(n)
    if params is not None:
        methods["CoRaiS(greedy)"] = _policy_method(params, state, policy_cfg, "greedy", 0, seed=0)
        for n in sample_ns:
            methods[f"CoRaiS({n})"] = _policy_method(params, state, policy_cfg, "sample", n, seed=n)
    return methods


# ---------------------------------------------------------------------------
# Temporal evaluation: backends compared on whole engine rollouts
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RolloutResult:
    """Aggregate of one backend over a batch of engine rollouts."""

    name: str
    completed: int
    submitted: int
    mean_response: float
    p95_response: float
    makespan: float
    wall_s: float          # whole-batch device time, compile excluded
    metrics: dict = dataclasses.field(default_factory=dict)


def evaluate_rollouts(
    assign_fns: dict[str, engine_lib.AssignFn],
    cfg: engine_lib.EngineConfig,
    workload,
    *,
    batch: int = 8,
    base_seed: int = 0,
    seed: int = 0,
) -> dict[str, RolloutResult]:
    """Run every scheduling backend over the same ``batch`` scenario
    episodes (paired clusters and arrival streams) on the batched engine;
    the temporal counterpart of :func:`evaluate_methods`.

    ``assign_fns`` values may be AssignFns (e.g. from
    ``engine.make_policy_assign``) or registered engine backend names
    (resolved through ``engine.resolve_assign_fn``)."""
    arrivals = materialize_round_batch(
        workload, cfg.num_edges, cfg.num_rounds, cfg.round_interval, batch,
        base_seed=base_seed)
    state0 = engine_lib.init_batch(cfg, range(base_seed, base_seed + batch))
    keys = jax.random.split(jax.random.PRNGKey(seed), batch)
    results = {}
    for name, fn in assign_fns.items():
        if isinstance(fn, str):
            fn = engine_lib.resolve_assign_fn(fn)
        run = engine_lib.make_rollout(cfg, fn, batch=True)
        jax.block_until_ready(run(state0, arrivals, keys))  # compile
        t0 = time.perf_counter()
        final, _ = run(state0, arrivals, keys)
        jax.block_until_ready(final)
        wall = time.perf_counter() - t0
        m = engine_lib.summarize(final)
        results[name] = RolloutResult(
            name=name,
            completed=m["completed"],
            submitted=m["submitted"],
            mean_response=m["mean_response"],
            p95_response=m["p95_response"],
            makespan=m["makespan"],
            wall_s=wall,
            metrics=m,
        )
    return results
