"""CoRaiS matching-on-demand policy network (paper §IV-A, Fig. 6).

Edge encoder (L attention layers) + request encoder (K attention layers)
align heterogeneous features; the context decoder attends the system context
[f_hat, h_hat, f_q] over request embeddings; the policy head scores every
(edge, request) pair with C*tanh compatibilities and softmaxes over edges
(eqs 12-17). One forward pass yields the full factorized scheduling
distribution, so S-sample RL (§IV-B) needs exactly one network evaluation.

The forward is split into two shared entry points used identically by
training, the batched rollout engine, and the serving controller:

    corais_encode  — encoders + context decoder -> (c_emb, h_emb, state)
    corais_score   — the eq 16-17 head, dispatching over SCORE_BACKENDS
                     ("xla" einsum head | "ref" pure-jnp oracle | "pallas"
                     fused kernel with custom VJP); every implementation
                     lives in repro.kernels, nothing re-derives the math.

``corais_apply`` = encode + score and remains the one-call forward.

The encoder sublayer alignment mechanism is pluggable ("mha" | "mlp") to
realize the paper's FC1/FC2/FC3 ablation baselines with parameter-matched
MLPs (see core/ablations.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.nn import (
    batchnorm_apply,
    batchnorm_init,
    layernorm_apply,
    layernorm_init,
    linear_apply,
    linear_init,
    mha_apply,
    mha_init,
)
from repro.nn.module import split_keys, uniform_init

EDGE_FEATURES = 8   # coords(2) + phi coeffs(2) + replicas(1) + workload(3)
REQ_FEATURES = 3    # source coords(2) + data size(1)
# Schema-v3 tier extras (PolicyConfig.tier_features): per-node cloud flag +
# cache locality, per-request deadline slack / priority / source residency.
TIER_EDGE_FEATURES = 2   # tier(1) + cache_frac(1)
TIER_REQ_FEATURES = 3    # req_slack(1) + req_priority(1) + req_cached(1)


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    # d_model=256 lands the parameter count at the paper's "about 4 million
    # learnable parameters" with the stated L=5/K=3/8-head/512-FC layout.
    d_model: int = 256
    num_heads: int = 8
    edge_layers: int = 5        # L (paper: 5)
    request_layers: int = 3     # K (paper: 3)
    ff_hidden: int = 512        # FC hidden dim (paper: 512, ReLU)
    tanh_clip: float = 10.0     # C in eq (16)
    norm: str = "batch"         # "batch" (paper) | "layer" (ablation knob)
    edge_align: str = "mha"     # "mha" (CoRaiS) | "mlp" (FC1/FC3)
    req_align: str = "mha"      # "mha" (CoRaiS) | "mlp" (FC2/FC3)
    feature_scale: float = 0.1  # static input scaling for workload features
    score_backend: str = "xla"  # eq 16-17 head: "xla" | "ref" | "pallas"
    # Admission head (resilience subsystem): a per-request admit logit on
    # top of the shared encoders, trained jointly with dispatch on
    # fault-injected episodes. Off by default so fault-free checkpoints
    # keep their parameter count.
    admit_head: bool = False
    admit_hidden: int = 64
    admit_bias: float = 2.0     # initial logit offset: start near admit-all
    # Edge–cloud tier conditioning (schema v3): widen both encoders'
    # input projections with the tier/cache-locality and deadline-slack/
    # priority features the engine's round_instance exposes (zeros when an
    # instance predates the tier, e.g. oracle snapshots or static training
    # instances). Off by default so flat-tier checkpoints keep their
    # parameter count.
    tier_features: bool = False


# ---------------------------------------------------------------------------
# feature builders (jnp twins of instances.edge_features/request_features)
# ---------------------------------------------------------------------------


def edge_feature_dim(cfg: "PolicyConfig") -> int:
    return EDGE_FEATURES + (TIER_EDGE_FEATURES if cfg.tier_features else 0)


def req_feature_dim(cfg: "PolicyConfig") -> int:
    return REQ_FEATURES + (TIER_REQ_FEATURES if cfg.tier_features else 0)


def _tier_col(inst, key, like) -> jax.Array:
    """A (..., K, 1) tier-feature column, zeros when the instance predates
    schema v3 (oracle snapshots, static training instances)."""
    if key in inst:
        return inst[key][..., None].astype(jnp.float32)
    return jnp.zeros(like.shape[:-1] + (1,), jnp.float32)


def edge_features(inst, cfg: "PolicyConfig" = None) -> jax.Array:
    cols = [
        inst["edge_coords"],
        inst["phi"],
        inst["replicas"][..., None],
        inst["workload"],
    ]
    if cfg is not None and cfg.tier_features:
        cols.append(_tier_col(inst, "tier", inst["phi"]))
        cols.append(_tier_col(inst, "cache_frac", inst["phi"]))
    return jnp.concatenate(cols, axis=-1).astype(jnp.float32)


def request_features(inst, cfg: "PolicyConfig" = None) -> jax.Array:
    src = inst["req_src"][..., None].astype(jnp.int32)
    coords = jnp.take_along_axis(inst["edge_coords"], src, axis=-2)
    size = inst["req_size"][..., None]
    cols = [coords, size]
    if cfg is not None and cfg.tier_features:
        cols.append(_tier_col(inst, "req_slack", size))
        cols.append(_tier_col(inst, "req_priority", size))
        cols.append(_tier_col(inst, "req_cached", size))
    return jnp.concatenate(cols, axis=-1).astype(jnp.float32)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _align_init(key, cfg: PolicyConfig, kind: str):
    """Alignment sublayer: MHA (paper) or parameter-matched token-wise MLP.

    MHA holds 4*d^2 weights; the MLP uses d->2d->d (= 4*d^2) to keep the
    learnable-parameter count matched, as required for FC1/FC2/FC3."""
    d = cfg.d_model
    if kind == "mha":
        return {"mha": mha_init(key, d, cfg.num_heads)}
    k1, k2 = jax.random.split(key)
    return {
        "mlp": {  # bias-free so the count matches MHA's 4*d^2 exactly
            "l1": linear_init(k1, d, 2 * d, bias=False),
            "l2": linear_init(k2, 2 * d, d, bias=False),
        }
    }


def _norm_init(cfg: PolicyConfig):
    if cfg.norm == "batch":
        return batchnorm_init(cfg.d_model)
    return layernorm_init(cfg.d_model), {}


def _encoder_init(key, cfg: PolicyConfig, num_layers: int, align: str):
    layers, states = [], []
    for k in split_keys(key, num_layers):
        ka, kf1, kf2 = split_keys(k, 3)
        n1p, n1s = _norm_init(cfg)
        n2p, n2s = _norm_init(cfg)
        layers.append(
            {
                "align": _align_init(ka, cfg, align),
                "norm1": n1p,
                "fc": {
                    "l1": linear_init(kf1, cfg.d_model, cfg.ff_hidden),
                    "l2": linear_init(kf2, cfg.ff_hidden, cfg.d_model),
                },
                "norm2": n2p,
            }
        )
        states.append({"norm1": n1s, "norm2": n2s})
    return layers, states


def corais_init(key, cfg: PolicyConfig):
    keys = split_keys(key, 8)
    d = cfg.d_model
    edge_layers, edge_states = _encoder_init(keys[2], cfg, cfg.edge_layers, cfg.edge_align)
    req_layers, req_states = _encoder_init(keys[3], cfg, cfg.request_layers, cfg.req_align)
    params = {
        "edge_proj": linear_init(keys[0], edge_feature_dim(cfg), d),
        "req_proj": linear_init(keys[1], req_feature_dim(cfg), d),
        "edge_layers": edge_layers,
        "req_layers": req_layers,
        # eq (15): queries from [f_hat, h_hat, f_q] (3d), kv from requests
        "ctx_mha": mha_init(keys[4], 3 * d, cfg.num_heads, kv_dim=d, out_dim=d),
        "w_px": uniform_init(keys[5], (d, d), fan_in=d),
        "w_py": uniform_init(keys[6], (d, d), fan_in=d),
    }
    if cfg.admit_head:
        ka1, ka2 = jax.random.split(keys[7])
        params["admit"] = {
            # per-request MLP on [h_z ; f_hat]: the request embedding plus
            # the system context it would be admitted into
            "l1": linear_init(ka1, 2 * d, cfg.admit_hidden),
            "l2": linear_init(ka2, cfg.admit_hidden, 1),
        }
    state = {"edge_layers": edge_states, "req_layers": req_states}
    return params, state


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------


def _masked_norm(norm_params, norm_state, x, mask, cfg: PolicyConfig, training: bool):
    """BatchNorm over valid tokens only (batch x nodes), or LayerNorm."""
    if cfg.norm == "layer":
        return layernorm_apply(norm_params, x), norm_state
    m = mask[..., None].astype(jnp.float32)
    cnt = jnp.maximum(jnp.sum(m), 1.0)
    if training:
        mean = jnp.sum(x * m, axis=tuple(range(x.ndim - 1))) / cnt
        var = jnp.sum(jnp.square(x - mean) * m, axis=tuple(range(x.ndim - 1))) / cnt
        momentum = 0.9
        new_state = {
            "mean": momentum * norm_state["mean"] + (1 - momentum) * mean,
            "var": momentum * norm_state["var"] + (1 - momentum) * var,
            "count": norm_state["count"] + 1,
        }
    else:
        trained = norm_state["count"] > 0
        bmean = jnp.sum(x * m, axis=tuple(range(x.ndim - 1))) / cnt
        bvar = jnp.sum(jnp.square(x - bmean) * m, axis=tuple(range(x.ndim - 1))) / cnt
        mean = jnp.where(trained, norm_state["mean"], bmean)
        var = jnp.where(trained, norm_state["var"], bvar)
        new_state = norm_state
    y = (x - mean) * jax.lax.rsqrt(var + 1e-5)
    return y * norm_params["scale"] + norm_params["bias"], new_state


def _align_apply(layer_align, x, mask, num_heads: int):
    if "mha" in layer_align:
        attn_mask = mask[..., None, None, :] & mask[..., None, :, None]
        return mha_apply(layer_align["mha"], x, mask=attn_mask, num_heads=num_heads)
    h = jax.nn.relu(linear_apply(layer_align["mlp"]["l1"], x))
    return linear_apply(layer_align["mlp"]["l2"], h)


def _encoder_apply(layers, states, x, mask, cfg: PolicyConfig, training: bool):
    new_states = []
    for layer, st in zip(layers, states):
        a = _align_apply(layer["align"], x, mask, cfg.num_heads)
        h, st1 = _masked_norm(layer["norm1"], st["norm1"], x + a, mask, cfg, training)
        f = linear_apply(layer["fc"]["l2"], jax.nn.relu(linear_apply(layer["fc"]["l1"], h)))
        x, st2 = _masked_norm(layer["norm2"], st["norm2"], h + f, mask, cfg, training)
        new_states.append({"norm1": st1, "norm2": st2})
        x = x * mask[..., None]
    return x, new_states


def _masked_max(x, mask):
    return jnp.max(jnp.where(mask[..., None], x, -jnp.inf), axis=-2)


def corais_encode(params, state, inst, cfg: PolicyConfig, *,
                  training: bool = False):
    """Encoders + context decoder (eqs 12-15): the mask-invariant, fixed-
    shape front half of the forward.

    Returns (c_emb, h_emb, new_state): c_emb (..., Q, d) context-decoded
    edge embeddings, h_emb (..., Z, d) request embeddings. Feed both to
    :func:`corais_score` for the eq 16-17 head."""
    emask = inst["edge_mask"]
    rmask = inst["req_mask"]

    ef = edge_features(inst, cfg)
    # Static rescale keeps the heavy workload features in a trainable range;
    # the tier extras (flags/fractions in [0,1]) pass through unscaled.
    escale = [1, 1, 1, 1, 1] + [cfg.feature_scale] * 3
    if cfg.tier_features:
        escale += [1] * TIER_EDGE_FEATURES
    ef = ef * jnp.asarray(escale, jnp.float32)
    rf = request_features(inst, cfg)
    if cfg.tier_features:
        # deadline slack (capped upstream) and priority get the same static
        # rescale as the workload features; the 0/1 residency bit passes.
        rscale = [1, 1, 1] + [cfg.feature_scale, cfg.feature_scale, 1]
        rf = rf * jnp.asarray(rscale, jnp.float32)

    f = linear_apply(params["edge_proj"], ef)
    h = linear_apply(params["req_proj"], rf)
    f, est = _encoder_apply(params["edge_layers"], state["edge_layers"], f, emask, cfg, training)
    h, rst = _encoder_apply(params["req_layers"], state["req_layers"], h, rmask, cfg, training)

    f_hat = _masked_max(f, emask)  # (..., d)
    h_hat = _masked_max(h, rmask)
    q_ctx = jnp.concatenate(
        [
            jnp.broadcast_to(f_hat[..., None, :], f.shape),
            jnp.broadcast_to(h_hat[..., None, :], f.shape),
            f,
        ],
        axis=-1,
    )  # (..., Q, 3d)
    ctx_mask = rmask[..., None, None, :]  # attend only real requests
    c = mha_apply(
        params["ctx_mha"], q_ctx, kv_in=h, mask=ctx_mask, num_heads=cfg.num_heads
    )  # (..., Q, d)
    return c, h, {"edge_layers": est, "req_layers": rst}


# ---------------------------------------------------------------------------
# eq 16-17 head: one registry, three backends, zero duplicated math
# ---------------------------------------------------------------------------


def _score_xla(c_emb, h_emb, w_px, w_py, edge_mask, tanh_clip):
    from repro.kernels import ref
    return ref.policy_score_xla(c_emb, h_emb, w_px, w_py, edge_mask,
                                tanh_clip)


def _score_ref(c_emb, h_emb, w_px, w_py, edge_mask, tanh_clip):
    from repro.kernels import ref
    if c_emb.ndim == 2:
        return ref.policy_score_ref(c_emb, h_emb, w_px, w_py, edge_mask,
                                    tanh_clip)
    batch = c_emb.shape[:-2]
    q = c_emb.shape[-2]
    cf = c_emb.reshape((-1,) + c_emb.shape[-2:])
    hf = h_emb.reshape((-1,) + h_emb.shape[-2:])
    mf = jnp.broadcast_to(edge_mask, batch + (q,)).reshape((-1, q))
    out = jax.vmap(
        lambda c, h, m: ref.policy_score_ref(c, h, w_px, w_py, m, tanh_clip)
    )(cf, hf, mf)
    return out.reshape(batch + out.shape[-2:])


def _score_pallas(c_emb, h_emb, w_px, w_py, edge_mask, tanh_clip):
    from repro.kernels import ops
    return ops.policy_score(c_emb, h_emb, w_px, w_py, edge_mask,
                            tanh_clip=tanh_clip)


#: name -> fn(c_emb, h_emb, w_px, w_py, edge_mask, tanh_clip) -> (..., Z, Q)
SCORE_BACKENDS: dict[str, Callable] = {
    "xla": _score_xla,        # batched einsum head (kernels/ref.py)
    "ref": _score_ref,        # per-instance pure-jnp oracle (kernels/ref.py)
    "pallas": _score_pallas,  # fused kernel + custom VJP (kernels/policy_score.py)
}


def register_score_backend(name: str, fn: Callable) -> None:
    """Register a scoring implementation (see SCORE_BACKENDS signature)."""
    SCORE_BACKENDS[name] = fn


def list_score_backends() -> list[str]:
    return sorted(SCORE_BACKENDS)


def corais_score(params, c_emb, h_emb, edge_mask, cfg: PolicyConfig, *,
                 backend: str | None = None):
    """The eq 16-17 head on encoder outputs: log a_qz as (..., Z, Q).

    ``backend`` overrides ``cfg.score_backend``; every implementation is
    registered in :data:`SCORE_BACKENDS` and lives in :mod:`repro.kernels`.
    """
    name = backend or cfg.score_backend
    try:
        fn = SCORE_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown score backend {name!r}; registered: "
            f"{', '.join(list_score_backends())}") from None
    return fn(c_emb, h_emb, params["w_px"], params["w_py"], edge_mask,
              cfg.tanh_clip)


# ---------------------------------------------------------------------------
# fused decode head: score + argmax/top-k without materializing (Z, Q)
# ---------------------------------------------------------------------------


def _decode_xla(c_emb, h_emb, w_px, w_py, edge_mask, tanh_clip, k, normalize):
    from repro.kernels import ref
    return ref.policy_score_decode_xla(c_emb, h_emb, w_px, w_py, edge_mask,
                                       tanh_clip, k, normalize)


def _decode_ref(c_emb, h_emb, w_px, w_py, edge_mask, tanh_clip, k, normalize):
    from repro.kernels import ref
    if c_emb.ndim == 2:
        return ref.policy_score_decode_ref(c_emb, h_emb, w_px, w_py,
                                           edge_mask, tanh_clip, k, normalize)
    batch = c_emb.shape[:-2]
    q = c_emb.shape[-2]
    cf = c_emb.reshape((-1,) + c_emb.shape[-2:])
    hf = h_emb.reshape((-1,) + h_emb.shape[-2:])
    mf = jnp.broadcast_to(edge_mask, batch + (q,)).reshape((-1, q))
    ti, tv = jax.vmap(
        lambda c, h, m: ref.policy_score_decode_ref(c, h, w_px, w_py, m,
                                                    tanh_clip, k, normalize)
    )(cf, hf, mf)
    return (ti.reshape(batch + ti.shape[-2:]),
            tv.reshape(batch + tv.shape[-2:]))


def _decode_pallas(c_emb, h_emb, w_px, w_py, edge_mask, tanh_clip, k,
                   normalize):
    from repro.kernels import ops
    return ops.policy_score_decode(c_emb, h_emb, w_px, w_py, edge_mask,
                                   tanh_clip=tanh_clip, k=k,
                                   normalize=normalize)


#: name -> fn(c_emb, h_emb, w_px, w_py, edge_mask, tanh_clip, k, normalize)
#: -> ((..., Z, K) int32 top edges, (..., Z, K) float32 values)
DECODE_BACKENDS: dict[str, Callable] = {
    "xla": _decode_xla,        # materialized head + lax.top_k (kernels/ref.py)
    "ref": _decode_ref,        # per-instance argsort oracle (kernels/ref.py)
    "pallas": _decode_pallas,  # fused kernel, (Z, Q) never leaves VMEM
}


def corais_score_decode(params, c_emb, h_emb, edge_mask, cfg: PolicyConfig,
                        *, k: int = 1, normalize: bool = True,
                        backend: str | None = None):
    """Fused eq 16-17 head + decode on encoder outputs: per-request top-k
    edges as ``(top_idx, top_val)``, both (..., Z, K). ``top_idx[..., 0]``
    is the greedy decision; with ``normalize=True`` values are eq-17
    log-probs, otherwise the clipped eq-16 compatibilities (same ranking,
    no normalizer — the serving fast path). Backend resolution mirrors
    :func:`corais_score` over :data:`DECODE_BACKENDS`."""
    name = backend or cfg.score_backend
    try:
        fn = DECODE_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown decode backend {name!r}; registered: "
            f"{', '.join(sorted(DECODE_BACKENDS))}") from None
    return fn(c_emb, h_emb, params["w_px"], params["w_py"], edge_mask,
              cfg.tanh_clip, k, normalize)


def corais_admit(params, c_emb, h_emb, edge_mask, cfg: PolicyConfig):
    """Admission-head logits on encoder outputs: (..., Z) per-request
    admit/shed scores (sigmoid -> admit probability; > 0 -> admit under
    greedy decoding). Shares the dispatch encoders — the head sees each
    request embedding next to the pooled cluster context, so "is there
    anywhere this request can still meet its SLO" is one linear readout
    away. ``cfg.admit_bias`` offsets the logits so a fresh head starts
    near admit-all and training has to learn to shed."""
    if "admit" not in params:
        raise ValueError(
            "policy has no admission head; init with "
            "PolicyConfig(admit_head=True)")
    f_hat = _masked_max(c_emb, edge_mask)  # (..., d) cluster context
    x = jnp.concatenate(
        [h_emb, jnp.broadcast_to(f_hat[..., None, :], h_emb.shape)], axis=-1)
    hid = jax.nn.relu(linear_apply(params["admit"]["l1"], x))
    return linear_apply(params["admit"]["l2"], hid)[..., 0] + cfg.admit_bias


def corais_apply(params, state, inst, cfg: PolicyConfig, *,
                 training: bool = False, backend: str | None = None):
    """Full forward = corais_encode + corais_score.

    Returns (log_probs, new_state); log_probs: (..., Z, Q) log a_qz."""
    c, h, new_state = corais_encode(params, state, inst, cfg,
                                    training=training)
    log_probs = corais_score(params, c, h, inst["edge_mask"], cfg,
                             backend=backend)
    return log_probs, new_state
