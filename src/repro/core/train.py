"""S-sample batch REINFORCE for CoRaiS (paper §IV-B, eqs 20-21).

One forward pass per instance yields the full factorized distribution;
S assignments are sampled from it, the shared-baseline advantage
A(pi_s) = L(pi_s) - mean_i L(pi_i) weights the log-prob gradient, and an
entropy bonus (eq 20) keeps exploration alive. Loss (eq 21):

    L(theta|D) = E_g[ C1 * sum_s log p(pi_s) A(pi_s) - C2 * H(g) ]

Paper hyperparameters: Adam lr 1e-5, batch 128 instances, S = 64,
C1 = 10, C2 = 0.5, uniform(-1/sqrt d) init.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import instances as inst_lib
from repro.core.decode import assignment_log_prob, greedy_decode
from repro.core.objective import makespan
from repro.core.policy import (PolicyConfig, corais_admit, corais_encode,
                               corais_init, corais_score)
from repro.optim import AdamConfig, adam_init, adam_update, clip_by_global_norm
from repro.resilience import faults as faults_lib
from repro.resilience.policies import nearest_alive
from repro.serving import engine as engine_lib
from repro.serving.engine import EngineConfig

# NOTE: repro.workloads is imported lazily inside temporal_train —
# workloads.scenarios depends on repro.serving (cloud/cache specs), which
# pulls in repro.core, so a module-level import here would be circular.


@dataclasses.dataclass(frozen=True)
class RLConfig:
    policy: PolicyConfig = PolicyConfig()
    instance: inst_lib.InstanceConfig = inst_lib.InstanceConfig()
    batch_size: int = 128
    num_samples: int = 64          # S
    c1: float = 10.0
    c2: float = 0.5
    lr: float = 1e-5
    grad_clip: float = 1.0
    num_batches: int = 40000
    seed: int = 0
    log_every: int = 10


def rl_loss(params, state, batch, sample_key, cfg: RLConfig):
    """Surrogate loss over a batch of instances. batch leaves have a leading
    batch axis; returns (loss, aux)."""
    # shared inference stack: one encode, one eq 16-17 score (the head's
    # backend — xla / ref / pallas — is cfg.policy.score_backend)
    c_emb, h_emb, new_state = corais_encode(
        params, state, batch, cfg.policy, training=True)
    log_probs = corais_score(params, c_emb, h_emb, batch["edge_mask"],
                             cfg.policy)  # (B, Z, Q)
    rmask = batch["req_mask"]

    # --- S samples from the factorized policy (no grad through sampling).
    # One batched categorical over a split-key axis: identical draws to the
    # per-key loop, but S-fold smaller jaxpr (the unrolled loop dominated
    # trace time at the paper's S=64).
    lp_stop = jax.lax.stop_gradient(log_probs)
    keys = jax.random.split(sample_key, cfg.num_samples)
    samples = jax.vmap(
        lambda k: jax.random.categorical(k, lp_stop, axis=-1)
    )(keys).astype(jnp.int32)  # (S, B, Z)

    costs = jax.vmap(lambda a: makespan(batch, a))(samples)  # (S, B)
    baseline = jnp.mean(costs, axis=0, keepdims=True)
    adv = costs - baseline  # (S, B)

    logp_pi = jax.vmap(lambda a: assignment_log_prob(log_probs, a, rmask))(samples)
    reinforce = jnp.sum(logp_pi * jax.lax.stop_gradient(adv), axis=0)  # (B,)

    # --- entropy (eq 20), over real (request, edge) cells
    probs = jnp.exp(log_probs)
    ent = -jnp.sum(probs * log_probs, axis=-1)  # (B, Z)
    ent = jnp.sum(ent * rmask, axis=-1)  # (B,)

    loss = jnp.mean(cfg.c1 * reinforce - cfg.c2 * ent)
    aux = {
        "cost_mean": jnp.mean(costs),
        "cost_best": jnp.mean(jnp.min(costs, axis=0)),
        "entropy": jnp.mean(ent),
        "state": new_state,
    }
    return loss, aux


def make_train_step(cfg: RLConfig, adam_cfg: Optional[AdamConfig] = None):
    adam_cfg = adam_cfg or AdamConfig(lr=cfg.lr)

    @jax.jit
    def step(params, state, opt_state, batch, key):
        (loss, aux), grads = jax.value_and_grad(rl_loss, has_aux=True)(
            params, state, batch, key, cfg
        )
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        params, opt_state = adam_update(params, grads, opt_state, adam_cfg)
        metrics = {
            "loss": loss,
            "grad_norm": gnorm,
            "cost_mean": aux["cost_mean"],
            "cost_best": aux["cost_best"],
            "entropy": aux["entropy"],
        }
        return params, aux["state"], opt_state, metrics

    return step, adam_cfg


def greedy_eval(params, state, batch, cfg: RLConfig) -> jax.Array:
    """Mean greedy makespan on a batch (no sampling)."""
    c_emb, h_emb, _ = corais_encode(params, state, batch, cfg.policy,
                                    training=False)
    log_probs = corais_score(params, c_emb, h_emb, batch["edge_mask"],
                             cfg.policy)
    return jnp.mean(makespan(batch, greedy_decode(log_probs)))


def train(
    cfg: RLConfig,
    num_batches: Optional[int] = None,
    params=None,
    state=None,
    opt_state=None,
    callback: Optional[Callable] = None,
    checkpointer=None,
    start_batch: int = 0,
):
    """Train CoRaiS on freshly generated synthetic instances (paper §IV-B).

    Returns (params, state, opt_state, history). Resumable: pass the pytrees
    back in (or use ``checkpointer`` for automatic periodic save/restore).
    """
    num_batches = num_batches if num_batches is not None else cfg.num_batches
    rng = np.random.default_rng(cfg.seed + 7919 * start_batch)
    key = jax.random.PRNGKey(cfg.seed)
    if params is None:
        key, sub = jax.random.split(key)
        params, state = corais_init(sub, cfg.policy)
    adam_cfg = AdamConfig(lr=cfg.lr)
    if opt_state is None:
        opt_state = adam_init(params, adam_cfg)
    step_fn, _ = make_train_step(cfg, adam_cfg)

    history = []
    for b in range(start_batch, start_batch + num_batches):
        batch = inst_lib.generate_batch(rng, cfg.instance, cfg.batch_size)
        batch = jax.tree.map(jnp.asarray, batch)
        key, sub = jax.random.split(key)
        t0 = time.perf_counter()
        params, state, opt_state, metrics = step_fn(params, state, opt_state, batch, sub)
        metrics = {k: float(v) for k, v in metrics.items()}
        metrics["batch"] = b
        metrics["sec"] = time.perf_counter() - t0
        history.append(metrics)
        if callback is not None and (b % cfg.log_every == 0):
            callback(metrics)
        if checkpointer is not None and checkpointer.should_save(b):
            checkpointer.save(
                b, {"params": params, "state": state, "opt_state": opt_state}
            )
    return params, state, opt_state, history


# ---------------------------------------------------------------------------
# Temporal REINFORCE on batched engine rollouts
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TemporalRLConfig:
    """REINFORCE over whole serving rollouts instead of i.i.d. static
    snapshots: the policy schedules every round of a scenario-conditioned
    episode inside :mod:`repro.serving.engine`, and the rollout return (mean
    response time over the episode's completed requests) replaces the
    single-round makespan as the learning signal — the temporal state the
    paper's §V-B3 perception claim is actually about."""

    policy: PolicyConfig = PolicyConfig()
    engine: EngineConfig = EngineConfig()
    scenario: str = "uniform_iid"   # repro.workloads scenario registry name
    batch_size: int = 16            # parallel rollouts (vmapped instances)
    c1: float = 1.0
    c2: float = 0.5
    lr: float = 1e-5
    grad_clip: float = 1.0
    num_batches: int = 1000
    seed: int = 0
    log_every: int = 10
    # Resilience training (the chaos-scenario path). Episodes are fault-
    # injected from the scenario's registered FaultSpec (or ``fault_spec``
    # here, which wins); ``admission=True`` samples the policy's admit head
    # per request and trains it jointly with dispatch. With ``slo > 0`` the
    # episode cost adds ``slo_penalty * slo_violation_frac``, where sheds,
    # drops, and stranded requests all count as violations — shedding
    # everything is never a winning strategy.
    fault_spec: Optional[faults_lib.FaultSpec] = None
    admission: bool = False
    slo: float = 0.0
    slo_penalty: float = 0.0
    # Deadline-aware training (schema v3): with ``deadline_penalty > 0``
    # the episode cost adds ``deadline_penalty * deadline_miss_frac`` —
    # the fraction of committed finite-deadline requests that finished
    # past their deadline (or never finished). Pairs with
    # ``policy.tier_features`` so the encoder can see the slack it is
    # being charged for.
    deadline_penalty: float = 0.0
    # Train only the admission head, freezing every other parameter (the
    # warm-started dispatch weights): episode-level REINFORCE at small
    # batch sizes is noisy enough to destroy a good dispatch policy, and
    # the admission decision is learnable on its own on top of it.
    freeze_dispatch: bool = False


def temporal_rl_loss(params, policy_state, sim_state, arrivals, sample_key,
                     cfg: TemporalRLConfig):
    """Surrogate loss over a batch of rollouts. ``sim_state`` is a (B,)-
    batched engine state, ``arrivals`` (B, R, A) padded round batches.
    Actions are sampled per round from the factorized policy; the episode
    return is the mean response time over completed requests, with the
    batch-mean baseline. Returns (loss, aux)."""
    ecfg = cfg.engine
    fault_mode = "alive" in arrivals
    adv_fn = jax.vmap(
        lambda st: engine_lib.advance(st, st["t"] + ecfg.round_interval, ecfg))
    inst_fn = jax.vmap(lambda st, a: engine_lib.round_instance(st, a, ecfg))
    commit_fn = jax.vmap(
        lambda st, a, x, adm, ro: engine_lib.commit(st, a, x, ecfg, admit=adm,
                                                    ready_offset=ro))
    fault_fn = jax.vmap(lambda st, a: engine_lib.apply_faults(st, a, ecfg))
    remap_fn = jax.vmap(
        lambda st, s: nearest_alive(st["w"], st["alive"] > 0, s))
    drain_fn = jax.vmap(
        lambda st: engine_lib.advance(st, engine_lib.DRAIN_HORIZON, ecfg))

    def body(carry, arr):
        sim, key = carry
        key, sub, sub_adm = jax.random.split(key, 3)
        sim = adv_fn(sim)
        ready_offset = jnp.zeros_like(arr["size"])
        if fault_mode:
            # the engine's two-step admission failover (see step_round):
            # arrivals re-admitted by the second step sort after native ones
            arr = dict(arr)
            arr["src"] = remap_fn(
                sim, jnp.clip(arr["src"].astype(jnp.int32), 0,
                              ecfg.num_edges - 1))
            sim = fault_fn(sim, arr)
            readmitted = ~jnp.take_along_axis(
                sim["alive"] > 0, arr["src"], axis=-1)
            ready_offset = engine_lib.RETRY_EPS * readmitted
            arr["src"] = remap_fn(sim, arr["src"])
        inst = inst_fn(sim, arr)
        # eval-mode norm statistics: rounds of one rollout are far from
        # i.i.d., so running batchnorm stats are not updated here.
        c_emb, h_emb, _ = corais_encode(params, policy_state, inst,
                                        cfg.policy, training=False)
        log_probs = corais_score(params, c_emb, h_emb, inst["edge_mask"],
                                 cfg.policy)  # (B, A, Q)
        act = jax.random.categorical(
            sub, jax.lax.stop_gradient(log_probs), axis=-1).astype(jnp.int32)
        rmask = inst["req_mask"]
        probs = jnp.exp(log_probs)
        ent = jnp.sum(-jnp.sum(probs * log_probs, -1) * rmask, -1)
        if cfg.admission:
            logits = corais_admit(params, c_emb, h_emb, inst["edge_mask"],
                                  cfg.policy)  # (B, A)
            admit = jax.random.bernoulli(
                sub_adm, jax.nn.sigmoid(jax.lax.stop_gradient(logits)))
            logp_admit = jnp.sum(
                jnp.where(rmask,
                          jnp.where(admit, jax.nn.log_sigmoid(logits),
                                    jax.nn.log_sigmoid(-logits)), 0.0), -1)
            # a shed request's dispatch never executes: drop it from the
            # dispatch log-prob to cut gradient variance (still unbiased)
            logp = (assignment_log_prob(log_probs, act, rmask & admit)
                    + logp_admit)
        else:
            admit = jnp.ones_like(rmask)
            logp = assignment_log_prob(log_probs, act, rmask)  # (B,)
        sim = commit_fn(sim, arr, act, admit, ready_offset)
        return (sim, key), (logp, ent)

    arr_rb = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), arrivals)
    (sim, _), (logps, ents) = jax.lax.scan(body, (sim_state, sample_key),
                                           arr_rb)
    sim = drain_fn(sim)

    committed = sim["slot_edge"] >= 0                       # (B, Z)
    # a fault trajectory can strand slots on a dead-at-horizon edge with
    # finish == INF; mean response is over realized completions only
    done = committed & (sim["slot_finish"] < engine_lib.INF / 2)
    resp = jnp.where(done, sim["slot_finish"] - sim["slot_submit"], 0.0)
    n_done = jnp.maximum(jnp.sum(done, -1), 1)
    cost = jnp.sum(resp, -1) / n_done                       # (B,) mean response
    aux = {}
    if cfg.slo > 0:
        violations = (jnp.sum(done & (resp > cfg.slo), -1)
                      + jnp.sum(committed & ~done, -1)
                      + sim["shed"] + sim["dropped"])
        total = jnp.maximum(
            jnp.sum(committed, -1) + sim["shed"] + sim["dropped"], 1)
        viol_frac = violations.astype(jnp.float32) / total
        cost = cost + cfg.slo_penalty * viol_frac
        aux["slo_violation_frac"] = jnp.mean(viol_frac)
    if cfg.deadline_penalty > 0:
        finite = committed & (sim["slot_deadline"] < engine_lib.INF / 2)
        missed = finite & (~done
                           | (sim["slot_finish"] > sim["slot_deadline"]))
        miss_frac = (jnp.sum(missed, -1).astype(jnp.float32)
                     / jnp.maximum(jnp.sum(finite, -1), 1))
        cost = cost + cfg.deadline_penalty * miss_frac
        aux["deadline_miss_frac"] = jnp.mean(miss_frac)
    adv = cost - jnp.mean(cost)

    reinforce = jnp.sum(logps, axis=0) * jax.lax.stop_gradient(adv)  # (B,)
    entropy = jnp.mean(jnp.sum(ents, axis=0))
    loss = jnp.mean(cfg.c1 * reinforce) - cfg.c2 * entropy
    aux.update({
        "cost_mean": jnp.mean(cost),
        "cost_best": jnp.min(cost),
        "entropy": entropy,
        "completed": jnp.mean(jnp.sum(done, -1).astype(jnp.float32)),
        "shed": jnp.mean(sim["shed"].astype(jnp.float32)),
    })
    return loss, aux


def make_temporal_train_step(cfg: TemporalRLConfig,
                             adam_cfg: Optional[AdamConfig] = None):
    adam_cfg = adam_cfg or AdamConfig(lr=cfg.lr)

    @jax.jit
    def step(params, policy_state, opt_state, sim_state, arrivals, key):
        (loss, aux), grads = jax.value_and_grad(temporal_rl_loss,
                                                has_aux=True)(
            params, policy_state, sim_state, arrivals, key, cfg
        )
        if cfg.freeze_dispatch:
            if cfg.admission and "admit" in grads:
                grads = {k: (g if k == "admit"
                             else jax.tree.map(jnp.zeros_like, g))
                         for k, g in grads.items()}
            else:
                raise ValueError(
                    "freeze_dispatch requires admission=True and a policy "
                    "with admit_head=True (nothing would train otherwise)")
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        params, opt_state = adam_update(params, grads, opt_state, adam_cfg)
        metrics = {"loss": loss, "grad_norm": gnorm, **aux}
        return params, opt_state, metrics

    return step, adam_cfg


def temporal_train(
    cfg: TemporalRLConfig,
    num_batches: Optional[int] = None,
    params=None,
    state=None,
    opt_state=None,
    callback: Optional[Callable] = None,
):
    """Train CoRaiS on temporal rollouts of a registered workload scenario.

    Every batch samples ``batch_size`` fresh clusters and arrival episodes
    (scenario-conditioned), rolls all of them forward in parallel on device,
    and applies one REINFORCE update on the episode returns. Returns
    (params, state, opt_state, history) like :func:`train`."""
    from repro.workloads import materialize_round_batch, scenario
    from repro.workloads.scenarios import scenario_cloud_spec, scenario_fault_spec

    num_batches = num_batches if num_batches is not None else cfg.num_batches
    ecfg = cfg.engine
    cloud_spec, cache_spec = scenario_cloud_spec(cfg.scenario)
    if cloud_spec is not None and ecfg.cloud is None:
        # cloud-* scenarios pin their tier + cache laws in the registry;
        # thread them into the engine automatically (like fault specs)
        ecfg = dataclasses.replace(ecfg, cloud=cloud_spec, cache=cache_spec)
        cfg = dataclasses.replace(cfg, engine=ecfg)
    wl = scenario(cfg.scenario)
    fspec = cfg.fault_spec
    if fspec is None:
        fspec = scenario_fault_spec(cfg.scenario)
    if fspec is not None and not fspec.has_faults:
        fspec = None
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)
    if params is None:
        key, sub = jax.random.split(key)
        params, state = corais_init(sub, cfg.policy)
    adam_cfg = AdamConfig(lr=cfg.lr)
    if opt_state is None:
        opt_state = adam_init(params, adam_cfg)
    step_fn, _ = make_temporal_train_step(cfg, adam_cfg)

    history = []
    for b in range(num_batches):
        seeds = rng.integers(0, 2**31 - 1, size=cfg.batch_size)
        sim0 = engine_lib.init_batch(ecfg, seeds)
        # overflow="clip": a burst beyond max_per_round drops its tail in
        # *training* episodes (a bounded admission queue), never in evals.
        arrivals = materialize_round_batch(
            wl, ecfg.num_edges, ecfg.num_rounds, ecfg.round_interval,
            cfg.batch_size, base_seed=int(rng.integers(0, 2**31 - 1)),
            max_per_round=ecfg.max_per_round, overflow="clip")
        if fspec is not None:
            arrivals = faults_lib.attach_fault_batch(
                arrivals, fspec, ecfg.num_edges,
                seeds=rng.integers(0, 2**31 - 1, size=cfg.batch_size))
        key, sub = jax.random.split(key)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(
            params, state, opt_state,
            jax.tree.map(jnp.asarray, sim0),
            jax.tree.map(jnp.asarray, arrivals), sub)
        metrics = {k: float(v) for k, v in metrics.items()}
        metrics["batch"] = b
        metrics["sec"] = time.perf_counter() - t0
        history.append(metrics)
        if callback is not None and (b % cfg.log_every == 0):
            callback(metrics)
    return params, state, opt_state, history
