"""S-sample batch REINFORCE for CoRaiS (paper §IV-B, eqs 20-21).

One forward pass per instance yields the full factorized distribution;
S assignments are sampled from it, the shared-baseline advantage
A(pi_s) = L(pi_s) - mean_i L(pi_i) weights the log-prob gradient, and an
entropy bonus (eq 20) keeps exploration alive. Loss (eq 21):

    L(theta|D) = E_g[ C1 * sum_s log p(pi_s) A(pi_s) - C2 * H(g) ]

Paper hyperparameters: Adam lr 1e-5, batch 128 instances, S = 64,
C1 = 10, C2 = 0.5, uniform(-1/sqrt d) init.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import instances as inst_lib
from repro.core.decode import assignment_log_prob, greedy_decode
from repro.core.objective import makespan
from repro.core.policy import (PolicyConfig, corais_admit, corais_encode,
                               corais_init, corais_score)
from repro.optim import AdamConfig, adam_init, adam_update, clip_by_global_norm
from repro.resilience import faults as faults_lib
from repro.resilience.policies import nearest_alive
from repro.serving import engine as engine_lib
from repro.serving.engine import EngineConfig

# NOTE: repro.workloads is imported lazily inside temporal_train —
# workloads.scenarios depends on repro.serving (cloud/cache specs), which
# pulls in repro.core, so a module-level import here would be circular.


@dataclasses.dataclass(frozen=True)
class RLConfig:
    policy: PolicyConfig = PolicyConfig()
    instance: inst_lib.InstanceConfig = inst_lib.InstanceConfig()
    batch_size: int = 128
    num_samples: int = 64          # S
    c1: float = 10.0
    c2: float = 0.5
    lr: float = 1e-5
    grad_clip: float = 1.0
    num_batches: int = 40000
    seed: int = 0
    log_every: int = 10


def rl_loss(params, state, batch, sample_key, cfg: RLConfig):
    """Surrogate loss over a batch of instances. batch leaves have a leading
    batch axis; returns (loss, aux)."""
    # shared inference stack: one encode, one eq 16-17 score (the head's
    # backend — xla / ref / pallas — is cfg.policy.score_backend)
    c_emb, h_emb, new_state = corais_encode(
        params, state, batch, cfg.policy, training=True)
    log_probs = corais_score(params, c_emb, h_emb, batch["edge_mask"],
                             cfg.policy)  # (B, Z, Q)
    rmask = batch["req_mask"]

    # --- S samples from the factorized policy (no grad through sampling).
    # One batched categorical over a split-key axis: identical draws to the
    # per-key loop, but S-fold smaller jaxpr (the unrolled loop dominated
    # trace time at the paper's S=64).
    lp_stop = jax.lax.stop_gradient(log_probs)
    keys = jax.random.split(sample_key, cfg.num_samples)
    samples = jax.vmap(
        lambda k: jax.random.categorical(k, lp_stop, axis=-1)
    )(keys).astype(jnp.int32)  # (S, B, Z)

    costs = jax.vmap(lambda a: makespan(batch, a))(samples)  # (S, B)
    baseline = jnp.mean(costs, axis=0, keepdims=True)
    adv = costs - baseline  # (S, B)

    logp_pi = jax.vmap(lambda a: assignment_log_prob(log_probs, a, rmask))(samples)
    reinforce = jnp.sum(logp_pi * jax.lax.stop_gradient(adv), axis=0)  # (B,)

    # --- entropy (eq 20), over real (request, edge) cells
    probs = jnp.exp(log_probs)
    ent = -jnp.sum(probs * log_probs, axis=-1)  # (B, Z)
    ent = jnp.sum(ent * rmask, axis=-1)  # (B,)

    loss = jnp.mean(cfg.c1 * reinforce - cfg.c2 * ent)
    aux = {
        "cost_mean": jnp.mean(costs),
        "cost_best": jnp.mean(jnp.min(costs, axis=0)),
        "entropy": jnp.mean(ent),
        "state": new_state,
    }
    return loss, aux


def make_train_step(cfg: RLConfig, adam_cfg: Optional[AdamConfig] = None):
    adam_cfg = adam_cfg or AdamConfig(lr=cfg.lr)

    @jax.jit
    def step(params, state, opt_state, batch, key):
        (loss, aux), grads = jax.value_and_grad(rl_loss, has_aux=True)(
            params, state, batch, key, cfg
        )
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        params, opt_state = adam_update(params, grads, opt_state, adam_cfg)
        metrics = {
            "loss": loss,
            "grad_norm": gnorm,
            "cost_mean": aux["cost_mean"],
            "cost_best": aux["cost_best"],
            "entropy": aux["entropy"],
        }
        return params, aux["state"], opt_state, metrics

    return step, adam_cfg


def greedy_eval(params, state, batch, cfg: RLConfig) -> jax.Array:
    """Mean greedy makespan on a batch (no sampling)."""
    c_emb, h_emb, _ = corais_encode(params, state, batch, cfg.policy,
                                    training=False)
    log_probs = corais_score(params, c_emb, h_emb, batch["edge_mask"],
                             cfg.policy)
    return jnp.mean(makespan(batch, greedy_decode(log_probs)))


def train(
    cfg: RLConfig,
    num_batches: Optional[int] = None,
    params=None,
    state=None,
    opt_state=None,
    callback: Optional[Callable] = None,
    checkpointer=None,
    start_batch: int = 0,
):
    """Train CoRaiS on freshly generated synthetic instances (paper §IV-B).

    Returns (params, state, opt_state, history). Resumable: pass the pytrees
    back in (or use ``checkpointer`` for automatic periodic save/restore).
    """
    num_batches = num_batches if num_batches is not None else cfg.num_batches
    rng = np.random.default_rng(cfg.seed + 7919 * start_batch)
    key = jax.random.PRNGKey(cfg.seed)
    if params is None:
        key, sub = jax.random.split(key)
        params, state = corais_init(sub, cfg.policy)
    adam_cfg = AdamConfig(lr=cfg.lr)
    if opt_state is None:
        opt_state = adam_init(params, adam_cfg)
    step_fn, _ = make_train_step(cfg, adam_cfg)

    history = []
    for b in range(start_batch, start_batch + num_batches):
        batch = inst_lib.generate_batch(rng, cfg.instance, cfg.batch_size)
        batch = jax.tree.map(jnp.asarray, batch)
        key, sub = jax.random.split(key)
        t0 = time.perf_counter()
        params, state, opt_state, metrics = step_fn(params, state, opt_state, batch, sub)
        metrics = {k: float(v) for k, v in metrics.items()}
        metrics["batch"] = b
        metrics["sec"] = time.perf_counter() - t0
        history.append(metrics)
        if callback is not None and (b % cfg.log_every == 0):
            callback(metrics)
        if checkpointer is not None and checkpointer.should_save(b):
            checkpointer.save(
                b, {"params": params, "state": state, "opt_state": opt_state}
            )
    return params, state, opt_state, history


# ---------------------------------------------------------------------------
# Temporal REINFORCE on batched engine rollouts
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TemporalRLConfig:
    """REINFORCE over whole serving rollouts instead of i.i.d. static
    snapshots: the policy schedules every round of a scenario-conditioned
    episode inside :mod:`repro.serving.engine`, and the rollout return (mean
    response time over the episode's completed requests) replaces the
    single-round makespan as the learning signal — the temporal state the
    paper's §V-B3 perception claim is actually about."""

    policy: PolicyConfig = PolicyConfig()
    engine: EngineConfig = EngineConfig()
    scenario: str = "uniform_iid"   # repro.workloads scenario registry name
    batch_size: int = 16            # parallel rollouts (vmapped instances)
    c1: float = 1.0
    c2: float = 0.5
    lr: float = 1e-5
    grad_clip: float = 1.0
    num_batches: int = 1000
    seed: int = 0
    log_every: int = 10
    # Resilience training (the chaos-scenario path). Episodes are fault-
    # injected from the scenario's registered FaultSpec (or ``fault_spec``
    # here, which wins); ``admission=True`` samples the policy's admit head
    # per request and trains it jointly with dispatch. With ``slo > 0`` the
    # episode cost adds ``slo_penalty * slo_violation_frac``, where sheds,
    # drops, and stranded requests all count as violations — shedding
    # everything is never a winning strategy.
    fault_spec: Optional[faults_lib.FaultSpec] = None
    admission: bool = False
    slo: float = 0.0
    slo_penalty: float = 0.0
    # Deadline-aware training (schema v3): with ``deadline_penalty > 0``
    # the episode cost adds ``deadline_penalty * deadline_miss_frac`` —
    # the fraction of committed finite-deadline requests that finished
    # past their deadline (or never finished). Pairs with
    # ``policy.tier_features`` so the encoder can see the slack it is
    # being charged for.
    deadline_penalty: float = 0.0
    # Train only the admission head, freezing every other parameter (the
    # warm-started dispatch weights): episode-level REINFORCE at small
    # batch sizes is noisy enough to destroy a good dispatch policy, and
    # the admission decision is learnable on its own on top of it.
    freeze_dispatch: bool = False
    # Device-resident training. With ``device_episodes=True`` arrivals (and
    # fault tensors) are drawn *inside* jit with jax.random
    # (workloads.materialize_round_batch_device), so episode generation
    # never round-trips through host numpy; ``epoch_len`` K > 1 runs K
    # REINFORCE updates per dispatch under one lax.scan with donated
    # params/opt_state buffers. Either setting (or passing ``mesh=`` to
    # temporal_train) routes through the scanned epoch trainer; only
    # scenarios with a device sampling law are supported there.
    device_episodes: bool = False
    epoch_len: int = 1


def temporal_rl_loss(params, policy_state, sim_state, arrivals, sample_key,
                     cfg: TemporalRLConfig, axis_name: Optional[str] = None):
    """Surrogate loss over a batch of rollouts. ``sim_state`` is a (B,)-
    batched engine state, ``arrivals`` (B, R, A) padded round batches.
    Actions are sampled per round from the factorized policy; the episode
    return is the mean response time over completed requests, with the
    batch-mean baseline. Returns (loss, aux).

    ``sample_key`` is either one (2,) key (batch-wide draws) or a (B, 2)
    per-element key stack — per-element draws are what make the data-
    parallel trainer exactly equivalent to single-device training, since an
    element's actions then never depend on how the batch is sharded. With
    ``axis_name`` set (inside shard_map) the REINFORCE baseline and the
    reported aux metrics reduce over the global batch via pmean/pmin; the
    loss itself stays shard-local (the train step pmean-averages grads)."""
    ecfg = cfg.engine
    fault_mode = "alive" in arrivals
    per_elem = sample_key.ndim == 2
    if axis_name is None:
        gmean, gmin = jnp.mean, jnp.min
    else:
        gmean = lambda x: jax.lax.pmean(jnp.mean(x), axis_name)  # noqa: E731
        gmin = lambda x: jax.lax.pmin(jnp.min(x), axis_name)     # noqa: E731
    adv_fn = jax.vmap(
        lambda st: engine_lib.advance(st, st["t"] + ecfg.round_interval, ecfg))
    inst_fn = jax.vmap(lambda st, a: engine_lib.round_instance(st, a, ecfg))
    commit_fn = jax.vmap(
        lambda st, a, x, adm, ro: engine_lib.commit(st, a, x, ecfg, admit=adm,
                                                    ready_offset=ro))
    fault_fn = jax.vmap(lambda st, a: engine_lib.apply_faults(st, a, ecfg))
    remap_fn = jax.vmap(
        lambda st, s: nearest_alive(st["w"], st["alive"] > 0, s))
    drain_fn = jax.vmap(
        lambda st: engine_lib.advance(st, engine_lib.DRAIN_HORIZON, ecfg))

    def body(carry, arr):
        sim, key = carry
        if per_elem:
            ks = jax.vmap(lambda k: jax.random.split(k, 3))(key)  # (B, 3, 2)
            key, sub, sub_adm = ks[:, 0], ks[:, 1], ks[:, 2]
        else:
            key, sub, sub_adm = jax.random.split(key, 3)
        sim = adv_fn(sim)
        ready_offset = jnp.zeros_like(arr["size"])
        if fault_mode:
            # the engine's two-step admission failover (see step_round):
            # arrivals re-admitted by the second step sort after native ones
            arr = dict(arr)
            arr["src"] = remap_fn(
                sim, jnp.clip(arr["src"].astype(jnp.int32), 0,
                              ecfg.num_edges - 1))
            sim = fault_fn(sim, arr)
            readmitted = ~jnp.take_along_axis(
                sim["alive"] > 0, arr["src"], axis=-1)
            ready_offset = engine_lib.RETRY_EPS * readmitted
            arr["src"] = remap_fn(sim, arr["src"])
        inst = inst_fn(sim, arr)
        # eval-mode norm statistics: rounds of one rollout are far from
        # i.i.d., so running batchnorm stats are not updated here.
        c_emb, h_emb, _ = corais_encode(params, policy_state, inst,
                                        cfg.policy, training=False)
        log_probs = corais_score(params, c_emb, h_emb, inst["edge_mask"],
                                 cfg.policy)  # (B, A, Q)
        lp_stop = jax.lax.stop_gradient(log_probs)
        if per_elem:
            act = jax.vmap(
                lambda k, lp: jax.random.categorical(k, lp, axis=-1)
            )(sub, lp_stop).astype(jnp.int32)
        else:
            act = jax.random.categorical(sub, lp_stop,
                                         axis=-1).astype(jnp.int32)
        rmask = inst["req_mask"]
        probs = jnp.exp(log_probs)
        ent = jnp.sum(-jnp.sum(probs * log_probs, -1) * rmask, -1)
        if cfg.admission:
            logits = corais_admit(params, c_emb, h_emb, inst["edge_mask"],
                                  cfg.policy)  # (B, A)
            sig = jax.nn.sigmoid(jax.lax.stop_gradient(logits))
            admit = (jax.vmap(jax.random.bernoulli)(sub_adm, sig)
                     if per_elem else jax.random.bernoulli(sub_adm, sig))
            logp_admit = jnp.sum(
                jnp.where(rmask,
                          jnp.where(admit, jax.nn.log_sigmoid(logits),
                                    jax.nn.log_sigmoid(-logits)), 0.0), -1)
            # a shed request's dispatch never executes: drop it from the
            # dispatch log-prob to cut gradient variance (still unbiased)
            logp = (assignment_log_prob(log_probs, act, rmask & admit)
                    + logp_admit)
        else:
            admit = jnp.ones_like(rmask)
            logp = assignment_log_prob(log_probs, act, rmask)  # (B,)
        sim = commit_fn(sim, arr, act, admit, ready_offset)
        return (sim, key), (logp, ent)

    arr_rb = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), arrivals)
    (sim, _), (logps, ents) = jax.lax.scan(body, (sim_state, sample_key),
                                           arr_rb)
    sim = drain_fn(sim)

    committed = sim["slot_edge"] >= 0                       # (B, Z)
    # a fault trajectory can strand slots on a dead-at-horizon edge with
    # finish == INF; mean response is over realized completions only
    done = committed & (sim["slot_finish"] < engine_lib.INF / 2)
    resp = jnp.where(done, sim["slot_finish"] - sim["slot_submit"], 0.0)
    n_done = jnp.maximum(jnp.sum(done, -1), 1)
    cost = jnp.sum(resp, -1) / n_done                       # (B,) mean response
    aux = {}
    if cfg.slo > 0:
        violations = (jnp.sum(done & (resp > cfg.slo), -1)
                      + jnp.sum(committed & ~done, -1)
                      + sim["shed"] + sim["dropped"])
        total = jnp.maximum(
            jnp.sum(committed, -1) + sim["shed"] + sim["dropped"], 1)
        viol_frac = violations.astype(jnp.float32) / total
        cost = cost + cfg.slo_penalty * viol_frac
        aux["slo_violation_frac"] = gmean(viol_frac)
    if cfg.deadline_penalty > 0:
        finite = committed & (sim["slot_deadline"] < engine_lib.INF / 2)
        missed = finite & (~done
                           | (sim["slot_finish"] > sim["slot_deadline"]))
        miss_frac = (jnp.sum(missed, -1).astype(jnp.float32)
                     / jnp.maximum(jnp.sum(finite, -1), 1))
        cost = cost + cfg.deadline_penalty * miss_frac
        aux["deadline_miss_frac"] = gmean(miss_frac)
    # global-batch baseline: under shard_map every shard subtracts the same
    # mean, so pmean-averaged grads equal the single-device grads exactly
    adv = cost - gmean(cost)

    reinforce = jnp.sum(logps, axis=0) * jax.lax.stop_gradient(adv)  # (B,)
    ent_sum = jnp.sum(ents, axis=0)                                  # (B,)
    # loss is shard-local (adv is stop-gradiented, so no autodiff crosses
    # the collective); the train step pmean-averages grads
    loss = jnp.mean(cfg.c1 * reinforce) - cfg.c2 * jnp.mean(ent_sum)
    aux.update({
        "cost_mean": gmean(cost),
        "cost_best": gmin(cost),
        "entropy": gmean(ent_sum),
        "completed": gmean(jnp.sum(done, -1).astype(jnp.float32)),
        "shed": gmean(sim["shed"].astype(jnp.float32)),
    })
    return loss, aux


def _temporal_update(params, policy_state, opt_state, sim_state, arrivals,
                     sample_key, cfg: TemporalRLConfig, adam_cfg: AdamConfig,
                     axis_name: Optional[str] = None):
    """One REINFORCE update (loss → grads → clip → adam). Shared by the
    per-batch jitted step, the scanned epoch step, and the sharded trainer
    (``axis_name`` set: grads/loss pmean over the batch shards)."""
    (loss, aux), grads = jax.value_and_grad(temporal_rl_loss, has_aux=True)(
        params, policy_state, sim_state, arrivals, sample_key, cfg, axis_name
    )
    if cfg.freeze_dispatch:
        if cfg.admission and "admit" in grads:
            grads = {k: (g if k == "admit"
                         else jax.tree.map(jnp.zeros_like, g))
                     for k, g in grads.items()}
        else:
            raise ValueError(
                "freeze_dispatch requires admission=True and a policy "
                "with admit_head=True (nothing would train otherwise)")
    if axis_name is not None:
        grads = jax.lax.pmean(grads, axis_name)
        loss = jax.lax.pmean(loss, axis_name)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    params, opt_state = adam_update(params, grads, opt_state, adam_cfg)
    metrics = {"loss": loss, "grad_norm": gnorm, **aux}
    return params, opt_state, metrics


def make_temporal_train_step(cfg: TemporalRLConfig,
                             adam_cfg: Optional[AdamConfig] = None):
    adam_cfg = adam_cfg or AdamConfig(lr=cfg.lr)

    @jax.jit
    def step(params, policy_state, opt_state, sim_state, arrivals, key):
        return _temporal_update(params, policy_state, opt_state, sim_state,
                                arrivals, key, cfg, adam_cfg)

    return step, adam_cfg


def resolve_temporal_config(cfg: TemporalRLConfig):
    """Thread the scenario's registered CloudSpec/CacheSpec into the engine
    config and resolve the effective fault spec (``cfg.fault_spec`` wins
    over the registry; spec with no faults drops to None). Idempotent —
    both trainer entry points and the benchmarks share it."""
    from repro.workloads.scenarios import (scenario_cloud_spec,
                                           scenario_fault_spec)

    ecfg = cfg.engine
    cloud_spec, cache_spec = scenario_cloud_spec(cfg.scenario)
    if cloud_spec is not None and ecfg.cloud is None:
        # cloud-* scenarios pin their tier + cache laws in the registry;
        # thread them into the engine automatically (like fault specs)
        ecfg = dataclasses.replace(ecfg, cloud=cloud_spec, cache=cache_spec)
        cfg = dataclasses.replace(cfg, engine=ecfg)
    fspec = cfg.fault_spec
    if fspec is None:
        fspec = scenario_fault_spec(cfg.scenario)
    if fspec is not None and not fspec.has_faults:
        fspec = None
    return cfg, fspec


def make_temporal_epoch_step(cfg: TemporalRLConfig,
                             adam_cfg: Optional[AdamConfig] = None, *,
                             mesh=None, axis: str = "fleet",
                             donate: Optional[bool] = None):
    """Scanned multi-update epoch step: one jit dispatch runs K sequential
    REINFORCE updates with episodes — arrivals and fault tensors — drawn
    *inside* the trace by the device samplers, so the host only supplies
    cluster states and PRNG keys.

    The returned ``step(params, policy_state, opt_state, sim0, elem_keys)``
    takes a (K, B, ...) stack of initial engine states and (K, B, 2)
    per-element keys (episode randomness derives from each element's key:
    fold_in 1 → arrivals, 2 → action sampling, 3 → faults), and returns
    ``(params, opt_state, metrics)`` with every metric stacked (K,) on
    device — nothing blocks until the caller drains them.

    With ``mesh`` the batch axis is sharded over the 1-D ``(axis,)`` device
    mesh (``launch.make_fleet_mesh``) under shard_map: params/opt_state are
    replicated, grads pmean-averaged, and per-element keys make the result
    equivalent to single-device training (pinned at 1e-5 by
    tests/test_train_multidevice.py). ``donate`` donates params/opt_state
    buffers to the dispatch; the default enables it off-CPU only (CPU jax
    warns and copies on donation — same contract as serving.fastpath).
    """
    from repro.workloads import materialize_round_batch_device, scenario
    from repro.workloads.batch import compile_device_plan

    adam_cfg = adam_cfg or AdamConfig(lr=cfg.lr)
    cfg, fspec = resolve_temporal_config(cfg)
    ecfg = cfg.engine
    wl = scenario(cfg.scenario)
    # fail fast (and outside jit) on scenarios with no device sampling law
    compile_device_plan(wl, ecfg.num_edges, ecfg.num_rounds,
                        ecfg.round_interval)
    if donate is None:
        donate = jax.default_backend() != "cpu"
    axis_name = axis if mesh is not None else None

    def epoch(params, policy_state, opt_state, sim0, elem_keys):
        def one_update(carry, xs):
            params, opt_state = carry
            sim, ekeys = xs
            arr_keys = jax.vmap(lambda k: jax.random.fold_in(k, 1))(ekeys)
            arrivals = materialize_round_batch_device(
                wl, ecfg.num_edges, ecfg.num_rounds, ecfg.round_interval,
                keys=arr_keys, max_per_round=ecfg.max_per_round)
            if fspec is not None:
                fkeys = jax.vmap(lambda k: jax.random.fold_in(k, 3))(ekeys)
                arrivals = faults_lib.attach_fault_batch_device(
                    arrivals, fspec, ecfg.num_edges, fkeys)
            skeys = jax.vmap(lambda k: jax.random.fold_in(k, 2))(ekeys)
            params, opt_state, metrics = _temporal_update(
                params, policy_state, opt_state, sim, arrivals, skeys,
                cfg, adam_cfg, axis_name=axis_name)
            return (params, opt_state), metrics

        (params, opt_state), metrics = jax.lax.scan(
            one_update, (params, opt_state), (sim0, elem_keys))
        return params, opt_state, metrics

    donate_args = (0, 2) if donate else ()
    if mesh is None:
        return jax.jit(epoch, donate_argnums=donate_args), adam_cfg

    try:
        shard_map = jax.shard_map
    except AttributeError:  # jax < 0.5
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    cache: dict = {}

    def step(params, policy_state, opt_state, sim0, elem_keys):
        sig = jax.tree.structure(sim0)
        fn = cache.get(sig)
        if fn is None:
            batched = lambda x: PartitionSpec(  # noqa: E731
                None, axis, *(None,) * (x.ndim - 2))
            fn = jax.jit(
                shard_map(
                    epoch, mesh=mesh,
                    in_specs=(PartitionSpec(), PartitionSpec(),
                              PartitionSpec(), jax.tree.map(batched, sim0),
                              PartitionSpec(None, axis, None)),
                    out_specs=(PartitionSpec(), PartitionSpec(),
                               PartitionSpec()),
                    check_rep=False),
                donate_argnums=donate_args)
            cache[sig] = fn
        return fn(params, policy_state, opt_state, sim0, elem_keys)

    return step, adam_cfg


#: rng-stream salts deriving per-batch episode randomness from
#: (cfg.seed, batch index) — order-free, so a checkpoint resume at any
#: batch replays exactly the stream an uninterrupted run would consume.
_CLUSTER_SALT = 0xC1
_ARRIVAL_SALT = 0xA7
_FAULT_SEED_SALT = 0xFA


def _cluster_seeds(cfg: TemporalRLConfig, b: int) -> np.ndarray:
    return np.random.default_rng((cfg.seed, _CLUSTER_SALT, b)).integers(
        0, 2**31 - 1, size=cfg.batch_size)


def _element_keys(base_key, b: int, batch: int):
    """(B, 2) per-element PRNG keys for batch index ``b``."""
    kb = jax.random.fold_in(base_key, b)
    return jax.vmap(lambda i: jax.random.fold_in(kb, i))(
        jnp.arange(batch, dtype=jnp.uint32))


def temporal_train(
    cfg: TemporalRLConfig,
    num_batches: Optional[int] = None,
    params=None,
    state=None,
    opt_state=None,
    callback: Optional[Callable] = None,
    *,
    mesh=None,
    checkpointer=None,
    start_batch: int = 0,
    adam_cfg: Optional[AdamConfig] = None,
):
    """Train CoRaiS on temporal rollouts of a registered workload scenario.

    Every batch samples ``batch_size`` fresh clusters and arrival episodes
    (scenario-conditioned), rolls all of them forward in parallel on device,
    and applies one REINFORCE update on the episode returns. Returns
    (params, state, opt_state, history) like :func:`train`.

    Two execution paths share one update rule (:func:`_temporal_update`):

    * host loop (default: ``device_episodes=False``, ``epoch_len<=1``, no
      mesh) — one jitted step per batch on host-materialized episodes;
      metrics stay device arrays in-loop and drain every ``log_every``.
    * scanned epoch (``device_episodes=True`` or ``epoch_len>1`` or
      ``mesh=``) — :func:`make_temporal_epoch_step`: K updates per
      dispatch, in-jit episode generation, optional batch sharding over
      the fleet mesh. ``callback`` then fires once per drained epoch (with
      that epoch's last batch row), not per batch.

    Per-batch randomness (clusters, arrivals, faults, action sampling)
    derives from ``(cfg.seed, batch index)`` rather than a sequentially
    consumed stream, so resuming from a ``checkpointer`` snapshot at any
    batch replays exactly what the uninterrupted run would have drawn —
    save→resume is bit-identical. With ``checkpointer`` set, parameters
    auto-restore from its latest snapshot (saved under step = number of
    completed batches) unless explicit ``params`` are passed."""
    from repro.workloads import materialize_round_batch, scenario

    cfg, fspec = resolve_temporal_config(cfg)
    num_batches = num_batches if num_batches is not None else cfg.num_batches
    ecfg = cfg.engine
    wl = scenario(cfg.scenario)
    key = jax.random.PRNGKey(cfg.seed)
    adam_cfg = adam_cfg or AdamConfig(lr=cfg.lr)
    if checkpointer is not None and params is None:
        template = jax.eval_shape(
            lambda: corais_init(jax.random.split(key)[1], cfg.policy))
        opt_template = jax.eval_shape(
            lambda: adam_init(template[0], adam_cfg))
        restored = checkpointer.restore_latest(
            {"params": template[0], "state": template[1],
             "opt_state": opt_template})
        if restored is not None:
            params = restored["tree"]["params"]
            state = restored["tree"]["state"]
            opt_state = restored["tree"]["opt_state"]
            start_batch = int(restored["step"])
    if params is None:
        params, state = corais_init(jax.random.split(key)[1], cfg.policy)
    if opt_state is None:
        opt_state = adam_init(params, adam_cfg)

    use_epoch = (cfg.device_episodes or cfg.epoch_len > 1
                 or mesh is not None)
    end = start_batch + num_batches
    history: list = []
    pending: list = []  # (batch ids, sec per batch, device metrics)

    def drain():
        rows = []
        for bs, sec, mets in pending:
            host = jax.device_get(mets)
            for i, b_i in enumerate(bs):
                row = {k: float(v[i]) if np.ndim(v) else float(v)
                       for k, v in host.items()}
                row["batch"], row["sec"] = b_i, sec
                history.append(row)
                rows.append(row)
        pending.clear()
        return rows

    def save(step_idx):
        if checkpointer is not None and checkpointer.should_save(step_idx):
            checkpointer.save(step_idx, {"params": params, "state": state,
                                         "opt_state": opt_state})
            return True
        return False

    if not use_epoch:
        step_fn, _ = make_temporal_train_step(cfg, adam_cfg)
        for b in range(start_batch, end):
            sim0 = engine_lib.init_batch(ecfg, _cluster_seeds(cfg, b))
            # overflow="clip": a burst beyond max_per_round drops its tail
            # in *training* episodes (a bounded admission queue), never in
            # evals.
            arrivals = materialize_round_batch(
                wl, ecfg.num_edges, ecfg.num_rounds, ecfg.round_interval,
                cfg.batch_size,
                base_seed=int(np.random.default_rng(
                    (cfg.seed, _ARRIVAL_SALT, b)).integers(0, 2**31 - 1)),
                max_per_round=ecfg.max_per_round, overflow="clip")
            if fspec is not None:
                arrivals = faults_lib.attach_fault_batch(
                    arrivals, fspec, ecfg.num_edges,
                    seeds=np.random.default_rng(
                        (cfg.seed, _FAULT_SEED_SALT, b)).integers(
                            0, 2**31 - 1, size=cfg.batch_size))
            skeys = _element_keys(key, b, cfg.batch_size)
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(
                params, state, opt_state,
                jax.tree.map(jnp.asarray, sim0),
                jax.tree.map(jnp.asarray, arrivals), skeys)
            pending.append(([b], time.perf_counter() - t0, metrics))
            # metrics stay on device between drains: no per-batch sync
            if b % cfg.log_every == 0 or b == end - 1:
                rows = drain()
                if callback is not None and rows and b % cfg.log_every == 0:
                    callback(rows[-1])
            save(b + 1)
        drain()
        return params, state, opt_state, history

    if mesh is not None:
        shards = int(np.prod([d for d in mesh.devices.shape]))
        if cfg.batch_size % shards:
            raise ValueError(
                f"batch_size {cfg.batch_size} does not divide over the "
                f"{shards}-device mesh")
    step_fn, _ = make_temporal_epoch_step(cfg, adam_cfg, mesh=mesh)
    epoch_len = max(1, cfg.epoch_len)
    b = start_batch
    while b < end:
        k_len = min(epoch_len, end - b)
        if checkpointer is not None:
            # land chunk boundaries exactly on checkpoint steps so a resume
            # replays the same chunking (bit-identical histories)
            k_len = min(k_len,
                        checkpointer.every - b % checkpointer.every)
        bs = list(range(b, b + k_len))
        stacks = [engine_lib.init_batch(ecfg, _cluster_seeds(cfg, bi))
                  for bi in bs]
        sim0 = {k: jnp.asarray(np.stack([s[k] for s in stacks]))
                for k in stacks[0]}
        ekeys = jnp.stack([_element_keys(key, bi, cfg.batch_size)
                           for bi in bs])
        t0 = time.perf_counter()
        params, opt_state, mets = step_fn(params, state, opt_state, sim0,
                                          ekeys)
        pending.append((bs, (time.perf_counter() - t0) / k_len, mets))
        b += k_len
        n_pending = sum(len(p[0]) for p in pending)
        if callback is not None or n_pending >= cfg.log_every or b >= end:
            rows = drain()
            if callback is not None and rows:
                callback(rows[-1])  # per-epoch logging
        save(b)
    drain()
    return params, state, opt_state, history
