"""Unified real-time decision path (paper §IV-C + Fig. 2 step iv).

Every consumer of the trained policy — the batched rollout engine, the
event-driven serving controller, and the evaluation harness — makes a
scheduling decision the same way: one mask-invariant, fixed-shape forward
(:func:`repro.core.policy.corais_encode` + the eq 16-17 head) followed by a
decode (greedy argmax or best-of-n sampling). This module is that single
path; nothing outside it re-implements "forward + decode".

How a decision is configured — :class:`DecisionSpec`:

    spec = DecisionSpec(mode="sample", num_samples=32, fused_decode=True)
    policy_decide(key, params, state, inst, cfg, spec)
    make_policy_assign(params, state, cfg, spec=spec)
    make_decision_fn(params, state, cfg, spec=spec)

One frozen dataclass holds every decode knob (mode, num_samples, backend,
admission, fused_decode, num_candidates, normalize); all entry points, the
serving fast path (``serving/fastpath.py``), and the controller consume it.
The pre-spec keyword flags (``policy_decide(..., mode=, fused_decode=, ...)``)
still work as a deprecated shim — they are folded into a DecisionSpec
internally — but new code should build the spec once and pass it around.
Passing both a spec and legacy keywords is an error.

Two decode routes through the head:

    materialized (``fused_decode=False``) — :func:`corais_score` emits the
        full (Z, Q) log-prob matrix; greedy argmaxes it, sampled dispatch
        takes ``lax.top_k`` of it. The training path (REINFORCE needs the
        matrix) and the parity oracle.
    fused (``fused_decode=True``) — :func:`corais_score_decode` performs
        argmax/top-k inside the scoring kernel, so the decision path never
        materializes (Z, Q); the kernel emits per-request (edge, value)
        pairs directly. The serving fast path (see serving/fastpath.py).

Sampled dispatch draws from a (Z, K) candidate set either way — per-sample
cost O(Z*K), not O(Z*Q) — and with ``num_candidates=None`` (K = Q) the
sampling distribution is exactly the paper's eq 19 factorized policy.

Three entry points, one semantics:

    policy_decide     — pure function, safe under jit/vmap/scan (the
                        engine's per-round scheduler body)
    make_policy_assign— closure matching the engine's AssignFn signature
                        (registered as ``ASSIGN_FNS["policy"]``; the
                        ``"policy-fused"`` alias is the same factory with
                        ``DecisionSpec(fused_decode=True)`` defaults)
    make_decision_fn  — jitted host-side decision function for the
                        controller / fast path / latency benchmarks (fixed
                        padded shapes, compile once, reuse every round;
                        ``donate=True`` donates the instance buffers)
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.decode import (greedy_decode, sampling_decode,
                               topk_sampling_decode)
from repro.core.policy import (PolicyConfig, corais_admit, corais_encode,
                               corais_score, corais_score_decode)

DECODE_MODES = ("greedy", "sample")

__all__ = ["DECODE_MODES", "DecisionSpec", "policy_decide",
           "make_policy_assign", "make_policy_assign_fused",
           "make_assign_factory", "make_decision_fn", "sampling_decode"]


@dataclasses.dataclass(frozen=True)
class DecisionSpec:
    """Every knob of one scheduling decision, in one hashable value.

    Fields mirror the historical ``policy_decide`` keywords:

    mode            "greedy" (argmax, ignores the PRNG key) or "sample"
                    (best-of-``num_samples`` eq-19 dispatch).
    num_samples     complete decisions drawn in sample mode.
    backend         score/decode kernel backend name (None = default; see
                    core.policy.SCORE_BACKENDS / DECODE_BACKENDS).
    admission       also threshold the admission head; decisions become
                    ``(assign, admit)`` pairs (requires ``admit_head=True``).
    fused_decode    decode inside the scoring kernel; never materializes
                    the (Z, Q) log-prob matrix.
    num_candidates  per-request candidate-set size K for sampled dispatch
                    (None = all edges, the exact eq-19 distribution).
    normalize       greedy only: False skips the log-softmax normalizer
                    (identical argmax, cheapest serving path).

    Frozen and hashable, so a spec can key compile caches; ``replace``
    derives variants (``spec.replace(mode="sample")``).
    """

    mode: str = "greedy"
    num_samples: int = 64
    backend: Optional[str] = None
    admission: bool = False
    fused_decode: bool = False
    num_candidates: Optional[int] = None
    normalize: bool = True

    def __post_init__(self):
        if self.mode not in DECODE_MODES:
            raise ValueError(f"unknown decode mode {self.mode!r}; "
                             f"supported: {', '.join(DECODE_MODES)}")

    def replace(self, **changes) -> "DecisionSpec":
        return dataclasses.replace(self, **changes)


_LEGACY_FLAGS = ("mode", "num_samples", "backend", "admission",
                 "fused_decode", "num_candidates", "normalize")


def _as_spec(spec: Optional[DecisionSpec], legacy: dict,
             base: Optional[DecisionSpec] = None) -> DecisionSpec:
    """Fold pre-DecisionSpec keyword flags into a spec (deprecated shim).

    ``legacy`` holds only the flags the caller explicitly passed. A spec
    and legacy flags together is ambiguous and raises; legacy flags alone
    are applied on top of ``base`` (the entry point's default spec) with a
    DeprecationWarning."""
    legacy = {k: v for k, v in legacy.items() if v is not _UNSET}
    if spec is not None:
        if not isinstance(spec, DecisionSpec):
            raise TypeError(f"spec must be a DecisionSpec, got "
                            f"{type(spec).__name__}; legacy flags go after "
                            f"it as keywords")
        if legacy:
            raise TypeError(
                f"pass either spec=DecisionSpec(...) or the legacy keyword "
                f"flags, not both (got spec and {sorted(legacy)})")
        return spec
    base = DecisionSpec() if base is None else base
    if legacy:
        warnings.warn(
            "per-call decision keywords (mode=, fused_decode=, ...) are "
            "deprecated; build a repro.core.inference.DecisionSpec and "
            "pass spec=", DeprecationWarning, stacklevel=3)
        return dataclasses.replace(base, **legacy)
    return base


class _Unset:
    def __repr__(self):  # keep help()/signature output readable
        return "<unset>"


_UNSET = _Unset()


def policy_decide(key, params, policy_state, inst, cfg: PolicyConfig,
                  spec: Optional[DecisionSpec] = None, *,
                  mode=_UNSET, num_samples=_UNSET, backend=_UNSET,
                  admission=_UNSET, fused_decode=_UNSET,
                  num_candidates=_UNSET, normalize=_UNSET):
    """One full scheduling decision on a frozen instance: (Z,) int32
    execution edge per request, configured by ``spec`` (see
    :class:`DecisionSpec`; the trailing keywords are the deprecated
    pre-spec shim). ``mode="greedy"`` ignores ``key``; ``mode="sample"``
    draws ``num_samples`` complete decisions from the per-request
    top-``num_candidates`` candidate set and keeps the cheapest (eq 19),
    greedy included as a candidate.

    With ``admission=True`` (requires a policy built with
    ``admit_head=True``) the same encoder pass also thresholds the
    admission head, and the decision is an ``(assign, admit)`` pair —
    the engine's extended AssignFn contract."""
    spec = _as_spec(spec, dict(mode=mode, num_samples=num_samples,
                               backend=backend, admission=admission,
                               fused_decode=fused_decode,
                               num_candidates=num_candidates,
                               normalize=normalize))
    c_emb, h_emb, _ = corais_encode(params, policy_state, inst, cfg,
                                    training=False)
    emask = inst["edge_mask"]
    if spec.mode == "greedy":
        if spec.fused_decode:
            ti, _ = corais_score_decode(params, c_emb, h_emb, emask, cfg,
                                        k=1, normalize=spec.normalize,
                                        backend=spec.backend)
            assign = ti[..., 0]
        else:
            log_probs = corais_score(params, c_emb, h_emb, emask, cfg,
                                     backend=spec.backend)
            assign = greedy_decode(log_probs)
    else:
        k = spec.num_candidates or emask.shape[-1]
        if spec.fused_decode:
            ti, tv = corais_score_decode(params, c_emb, h_emb, emask, cfg,
                                         k=k, normalize=True,
                                         backend=spec.backend)
        else:
            log_probs = corais_score(params, c_emb, h_emb, emask, cfg,
                                     backend=spec.backend)
            tv, ti = jax.lax.top_k(log_probs, k)
        assign, _ = topk_sampling_decode(key, inst, ti.astype(jnp.int32),
                                         tv, spec.num_samples)
    assign = assign.astype(jnp.int32)
    if not spec.admission:
        return assign
    admit = corais_admit(params, c_emb, h_emb, emask, cfg) > 0
    return assign, admit & inst["req_mask"]


def make_assign_factory(defaults: DecisionSpec):
    """Build an engine scheduler factory around a default
    :class:`DecisionSpec` — the single registration point behind every
    policy entry in ``engine.ASSIGN_FNS`` (``"policy"`` and
    ``"policy-fused"`` are the same factory with different defaults).

    The returned factory has the AssignFn-factory signature
    ``(params, policy_state, policy_cfg, spec=None, **legacy_flags)`` and
    yields an un-jitted closure ``fn(key, inst)`` the engine traces inside
    its own jitted/vmapped rollout; the whole rollout then compiles
    end-to-end over the instance axis, fused scoring kernel included."""

    def factory(params, policy_state, policy_cfg: PolicyConfig,
                spec: Optional[DecisionSpec] = None, **legacy):
        bad = set(legacy) - set(_LEGACY_FLAGS)
        if bad:
            raise TypeError(f"unknown decision flags {sorted(bad)}; "
                            f"DecisionSpec fields: {_LEGACY_FLAGS}")
        resolved = _as_spec(spec, legacy, base=defaults)

        def fn(key, inst):
            return policy_decide(key, params, policy_state, inst,
                                 policy_cfg, resolved)

        return fn

    # engine.resolve_assign_fn treats registry entries tagged this way as
    # factories to be built with policy kwargs rather than called per round
    factory._assign_factory = True
    factory._decision_defaults = defaults
    return factory


#: The CoRaiS policy as an engine scheduler factory (``ASSIGN_FNS["policy"]``).
make_policy_assign = make_assign_factory(DecisionSpec())

#: Same factory with the fused in-kernel decode on by default
#: (``ASSIGN_FNS["policy-fused"]``).
make_policy_assign_fused = make_assign_factory(
    DecisionSpec(fused_decode=True))

make_policy_assign.__name__ = "make_policy_assign"
make_policy_assign_fused.__name__ = "make_policy_assign_fused"


def make_decision_fn(params, policy_state, cfg: PolicyConfig,
                     spec: Optional[DecisionSpec] = None, *,
                     donate: bool = False,
                     mode=_UNSET, num_samples=_UNSET, backend=_UNSET,
                     fused_decode=_UNSET, num_candidates=_UNSET,
                     normalize=_UNSET):
    """Compile-once decision function ``decide(inst, key) -> (Z,) int32``
    for the real-time serving path: pad snapshots to a constant shape and
    every round after the first runs at kernel latency. Configured by
    ``spec`` (legacy keywords remain as the deprecated shim).

    ``donate=True`` donates the instance buffers to the call (the fast
    path's double-buffered loop re-stages fresh device buffers each round,
    so XLA can reuse the memory in place; unsupported-donation backends
    like CPU just warn and copy)."""
    spec = _as_spec(spec, dict(mode=mode, num_samples=num_samples,
                               backend=backend, fused_decode=fused_decode,
                               num_candidates=num_candidates,
                               normalize=normalize))

    def decide(inst, key):
        return policy_decide(key, params, policy_state, inst, cfg, spec)

    return jax.jit(decide, donate_argnums=(0,) if donate else ())
