"""Unified real-time decision path (paper §IV-C + Fig. 2 step iv).

Every consumer of the trained policy — the batched rollout engine, the
event-driven serving controller, and the evaluation harness — makes a
scheduling decision the same way: one mask-invariant, fixed-shape forward
(:func:`repro.core.policy.corais_encode` + the eq 16-17 head) followed by a
decode (greedy argmax or best-of-n sampling). This module is that single
path; nothing outside it re-implements "forward + decode".

Two decode routes through the head:

    materialized (``fused_decode=False``) — :func:`corais_score` emits the
        full (Z, Q) log-prob matrix; greedy argmaxes it, sampled dispatch
        takes ``lax.top_k`` of it. The training path (REINFORCE needs the
        matrix) and the parity oracle.
    fused (``fused_decode=True``) — :func:`corais_score_decode` performs
        argmax/top-k inside the scoring kernel, so the decision path never
        materializes (Z, Q); the kernel emits per-request (edge, value)
        pairs directly. The serving fast path (see serving/fastpath.py).

Sampled dispatch draws from a (Z, K) candidate set either way — per-sample
cost O(Z*K), not O(Z*Q) — and with ``num_candidates=None`` (K = Q) the
sampling distribution is exactly the paper's eq 19 factorized policy.

Three entry points, one semantics:

    policy_decide     — pure function, safe under jit/vmap/scan (the
                        engine's per-round scheduler body)
    make_policy_assign— closure matching the engine's AssignFn signature
                        (registered as ``ASSIGN_FNS["policy"]``; the
                        ``"policy-fused"`` entry defaults fused_decode on)
    make_decision_fn  — jitted host-side decision function for the
                        controller / fast path / latency benchmarks (fixed
                        padded shapes, compile once, reuse every round;
                        ``donate=True`` donates the instance buffers)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.decode import (greedy_decode, sampling_decode,
                               topk_sampling_decode)
from repro.core.policy import (PolicyConfig, corais_admit, corais_encode,
                               corais_score, corais_score_decode)

DECODE_MODES = ("greedy", "sample")

__all__ = ["DECODE_MODES", "policy_decide", "make_policy_assign",
           "make_policy_assign_fused", "make_decision_fn",
           "sampling_decode"]


def policy_decide(key, params, policy_state, inst, cfg: PolicyConfig, *,
                  mode: str = "greedy", num_samples: int = 64,
                  backend: Optional[str] = None,
                  admission: bool = False,
                  fused_decode: bool = False,
                  num_candidates: Optional[int] = None,
                  normalize: bool = True):
    """One full scheduling decision on a frozen instance: (Z,) int32
    execution edge per request. ``mode="greedy"`` ignores ``key``;
    ``mode="sample"`` draws ``num_samples`` complete decisions from the
    per-request top-``num_candidates`` candidate set and keeps the
    cheapest (eq 19), greedy included as a candidate
    (``num_candidates=None`` keeps every edge, i.e. the exact eq-19
    distribution; a small K truncates the tail for O(Z*K) sampling).

    ``fused_decode=True`` decodes inside the scoring kernel — the (Z, Q)
    log-prob matrix is never materialized. ``normalize=False`` (greedy
    only) additionally skips the log-softmax normalizer: identical edge
    choice, cheapest serving path.

    With ``admission=True`` (requires a policy built with
    ``admit_head=True``) the same encoder pass also thresholds the
    admission head, and the decision is an ``(assign, admit)`` pair —
    the engine's extended AssignFn contract."""
    if mode not in DECODE_MODES:
        raise ValueError(f"unknown decode mode {mode!r}; "
                         f"supported: {', '.join(DECODE_MODES)}")
    c_emb, h_emb, _ = corais_encode(params, policy_state, inst, cfg,
                                    training=False)
    emask = inst["edge_mask"]
    if mode == "greedy":
        if fused_decode:
            ti, _ = corais_score_decode(params, c_emb, h_emb, emask, cfg,
                                        k=1, normalize=normalize,
                                        backend=backend)
            assign = ti[..., 0]
        else:
            log_probs = corais_score(params, c_emb, h_emb, emask, cfg,
                                     backend=backend)
            assign = greedy_decode(log_probs)
    else:
        k = num_candidates or emask.shape[-1]
        if fused_decode:
            ti, tv = corais_score_decode(params, c_emb, h_emb, emask, cfg,
                                         k=k, normalize=True,
                                         backend=backend)
        else:
            log_probs = corais_score(params, c_emb, h_emb, emask, cfg,
                                     backend=backend)
            tv, ti = jax.lax.top_k(log_probs, k)
        assign, _ = topk_sampling_decode(key, inst, ti.astype(jnp.int32),
                                         tv, num_samples)
    assign = assign.astype(jnp.int32)
    if not admission:
        return assign
    admit = corais_admit(params, c_emb, h_emb, emask, cfg) > 0
    return assign, admit & inst["req_mask"]


def make_policy_assign(params, policy_state, policy_cfg: PolicyConfig,
                       mode: str = "greedy", num_samples: int = 64,
                       backend: Optional[str] = None,
                       admission: bool = False,
                       fused_decode: bool = False,
                       num_candidates: Optional[int] = None,
                       normalize: bool = True):
    """The CoRaiS policy as an engine scheduler: AssignFn(key, inst).

    The closure stays un-jitted so the engine can trace it inside its own
    jitted/vmapped rollout; the whole rollout then compiles end-to-end over
    the instance axis, fused scoring kernel included. ``admission=True``
    returns (assign, admit) pairs — see :func:`policy_decide`."""

    def fn(key, inst):
        return policy_decide(key, params, policy_state, inst, policy_cfg,
                             mode=mode, num_samples=num_samples,
                             backend=backend, admission=admission,
                             fused_decode=fused_decode,
                             num_candidates=num_candidates,
                             normalize=normalize)

    return fn


# engine.resolve_assign_fn treats registry entries tagged this way as
# factories to be built with policy kwargs rather than called per round
make_policy_assign._assign_factory = True


def make_policy_assign_fused(params, policy_state, policy_cfg: PolicyConfig,
                             **kwargs):
    """``make_policy_assign`` with the fused in-kernel decode on by default
    (the engine's ``ASSIGN_FNS["policy-fused"]`` entry)."""
    kwargs.setdefault("fused_decode", True)
    return make_policy_assign(params, policy_state, policy_cfg, **kwargs)


make_policy_assign_fused._assign_factory = True


def make_decision_fn(params, policy_state, cfg: PolicyConfig, *,
                     mode: str = "greedy", num_samples: int = 64,
                     backend: Optional[str] = None,
                     fused_decode: bool = False,
                     num_candidates: Optional[int] = None,
                     normalize: bool = True,
                     donate: bool = False):
    """Compile-once decision function ``decide(inst, key) -> (Z,) int32``
    for the real-time serving path: pad snapshots to a constant shape and
    every round after the first runs at kernel latency.

    ``donate=True`` donates the instance buffers to the call (the fast
    path's double-buffered loop re-stages fresh device buffers each round,
    so XLA can reuse the memory in place; unsupported-donation backends
    like CPU just warn and copy)."""

    def decide(inst, key):
        return policy_decide(key, params, policy_state, inst, cfg,
                             mode=mode, num_samples=num_samples,
                             backend=backend, fused_decode=fused_decode,
                             num_candidates=num_candidates,
                             normalize=normalize)

    return jax.jit(decide, donate_argnums=(0,) if donate else ())
