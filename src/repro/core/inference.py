"""Unified real-time decision path (paper §IV-C + Fig. 2 step iv).

Every consumer of the trained policy — the batched rollout engine, the
event-driven serving controller, and the evaluation harness — makes a
scheduling decision the same way: one mask-invariant, fixed-shape forward
(:func:`repro.core.policy.corais_encode` + :func:`corais_score`) followed
by a decode (greedy argmax or best-of-n sampling). This module is that
single path; nothing outside it re-implements "forward + decode".

Three entry points, one semantics:

    policy_decide     — pure function, safe under jit/vmap/scan (the
                        engine's per-round scheduler body)
    make_policy_assign— closure matching the engine's AssignFn signature
                        (registered as ``ASSIGN_FNS["policy"]``)
    make_decision_fn  — jitted host-side decision function for the
                        controller / latency benchmarks (fixed padded
                        shapes, compile once, reuse every round)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.decode import greedy_decode, sampling_decode
from repro.core.policy import (PolicyConfig, corais_admit, corais_encode,
                               corais_score)

DECODE_MODES = ("greedy", "sample")


def policy_decide(key, params, policy_state, inst, cfg: PolicyConfig, *,
                  mode: str = "greedy", num_samples: int = 64,
                  backend: Optional[str] = None,
                  admission: bool = False):
    """One full scheduling decision on a frozen instance: (Z,) int32
    execution edge per request. ``mode="greedy"`` ignores ``key``;
    ``mode="sample"`` draws ``num_samples`` complete decisions and keeps
    the cheapest (eq 19), greedy included as a candidate.

    With ``admission=True`` (requires a policy built with
    ``admit_head=True``) the same encoder pass also thresholds the
    admission head, and the decision is an ``(assign, admit)`` pair —
    the engine's extended AssignFn contract."""
    if mode not in DECODE_MODES:
        raise ValueError(f"unknown decode mode {mode!r}; "
                         f"supported: {', '.join(DECODE_MODES)}")
    c_emb, h_emb, _ = corais_encode(params, policy_state, inst, cfg,
                                    training=False)
    log_probs = corais_score(params, c_emb, h_emb, inst["edge_mask"], cfg,
                             backend=backend)
    if mode == "greedy":
        assign = greedy_decode(log_probs)
    else:
        assign, _ = sampling_decode(key, inst, log_probs, num_samples)
        assign = assign.astype(jnp.int32)
    if not admission:
        return assign
    admit = corais_admit(params, c_emb, h_emb, inst["edge_mask"], cfg) > 0
    return assign, admit & inst["req_mask"]


def make_policy_assign(params, policy_state, policy_cfg: PolicyConfig,
                       mode: str = "greedy", num_samples: int = 64,
                       backend: Optional[str] = None,
                       admission: bool = False):
    """The CoRaiS policy as an engine scheduler: AssignFn(key, inst).

    The closure stays un-jitted so the engine can trace it inside its own
    jitted/vmapped rollout; the whole rollout then compiles end-to-end over
    the instance axis, fused scoring kernel included. ``admission=True``
    returns (assign, admit) pairs — see :func:`policy_decide`."""

    def fn(key, inst):
        return policy_decide(key, params, policy_state, inst, policy_cfg,
                             mode=mode, num_samples=num_samples,
                             backend=backend, admission=admission)

    return fn


# engine.resolve_assign_fn treats registry entries tagged this way as
# factories to be built with policy kwargs rather than called per round
make_policy_assign._assign_factory = True


def make_decision_fn(params, policy_state, cfg: PolicyConfig, *,
                     mode: str = "greedy", num_samples: int = 64,
                     backend: Optional[str] = None):
    """Compile-once decision function ``decide(inst, key) -> (Z,) int32``
    for the real-time serving path: pad snapshots to a constant shape and
    every round after the first runs at kernel latency."""

    @jax.jit
    def decide(inst, key):
        return policy_decide(key, params, policy_state, inst, cfg,
                             mode=mode, num_samples=num_samples,
                             backend=backend)

    return decide
