"""Learning-based ablation baselines FC1/FC2/FC3 (paper §V-A Baselines).

Each keeps the MoD architecture and I/O of CoRaiS but replaces the
multi-head-attention alignment mechanism with a parameter-matched MLP in:
FC1 - the edge encoder; FC2 - the request encoder; FC3 - both.
"""
from __future__ import annotations

import dataclasses

from repro.core.policy import PolicyConfig

VARIANTS = ("corais", "fc1", "fc2", "fc3")


def variant_config(base: PolicyConfig, variant: str) -> PolicyConfig:
    variant = variant.lower()
    if variant == "corais":
        return dataclasses.replace(base, edge_align="mha", req_align="mha")
    if variant == "fc1":
        return dataclasses.replace(base, edge_align="mlp", req_align="mha")
    if variant == "fc2":
        return dataclasses.replace(base, edge_align="mha", req_align="mlp")
    if variant == "fc3":
        return dataclasses.replace(base, edge_align="mlp", req_align="mlp")
    raise ValueError(f"unknown variant {variant!r}; expected one of {VARIANTS}")
