"""System-level state evaluation model (paper §III-C).

Shields heterogeneous hardware behind two service-oriented indicators —
the computation-time estimation function ``phi(x)`` and the replica count
``zeta`` — plus the three workload features (c_le, c_in, t_in) computed from
the live queues of Fig. 5. The serving runtime (src/repro/serving) keeps one
:class:`EdgeServiceState` per (edge, service) and re-evaluates it before
every scheduling round; the evaluation feeds both the jnp objective and the
CoRaiS policy inputs.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class PhiEstimator:
    """Affine phi(x) = a*x + b fitted online from (data_size, runtime) pairs
    by least squares over a sliding window of the most recent observations
    (the paper's numpy.polyfit procedure, §III-C1). Only *local* history is
    used, preserving per-edge heterogeneity.

    The fit is maintained through running sums (n, Sx, Sy, Sxx, Sxy) with
    O(1) eviction at the window edge, so ``observe`` is O(1) per completed
    request instead of an O(n) refit; the closed-form coefficients equal
    ``np.polyfit(window, 1)`` (pinned by a test). Set ``frozen`` to pin the
    coefficients (oracle mode for engine-equivalence runs).
    """

    a: float = 1.0
    b: float = 0.0
    min_samples: int = 8
    window: int = 512
    frozen: bool = False
    _xs: list = dataclasses.field(default_factory=list)
    _ys: list = dataclasses.field(default_factory=list)
    _sx: float = 0.0
    _sy: float = 0.0
    _sxx: float = 0.0
    _sxy: float = 0.0
    _n: int = 0

    def observe(self, data_size: float, runtime: float) -> None:
        if self.frozen:
            return
        x, y = float(data_size), float(runtime)
        self._xs.append(x)
        self._ys.append(y)
        if len(self._xs) > 2 * (self.window + 1):
            # amortized O(1) trim: only the trailing window+1 samples are
            # ever read again (the eviction below indexes from the end)
            del self._xs[: len(self._xs) - (self.window + 1)]
            del self._ys[: len(self._ys) - (self.window + 1)]
        self._sx += x
        self._sy += y
        self._sxx += x * x
        self._sxy += x * y
        self._n += 1
        if self._n > self.window:  # evict the sample leaving the window
            xo = self._xs[len(self._xs) - self.window - 1]
            yo = self._ys[len(self._ys) - self.window - 1]
            self._sx -= xo
            self._sy -= yo
            self._sxx -= xo * xo
            self._sxy -= xo * yo
            self._n -= 1
        n = self._n
        if n < self.min_samples:
            return
        var = max(self._sxx / n - (self._sx / n) ** 2, 0.0)
        if var < 1e-18:
            return  # constant-size history: the affine fit is degenerate
        a = (self._sxy - self._sx * self._sy / n) / (self._sxx - self._sx**2 / n)
        b = (self._sy - a * self._sx) / n
        if np.isfinite(a) and np.isfinite(b) and a > 0:
            self.a, self.b = float(a), float(max(b, 0.0))

    def __call__(self, data_size) -> float:
        return self.a * np.asarray(data_size) + self.b

    @property
    def coefficients(self) -> tuple[float, float]:
        return self.a, self.b


@dataclasses.dataclass
class QueuedRequest:
    """Brief of a request (paper §III-A): description only, no payload."""

    rid: int
    data_size: float
    source_edge: int
    service: int = 0
    submit_time: float = 0.0
    # Schema-v3 fields: absolute hard-SLO time (inf = no deadline) and an
    # importance level the scheduler may condition on.
    deadline: float = float("inf")
    priority: int = 0
    # Filled by the runtime:
    exec_edge: int = -1
    start_time: float = -1.0
    finish_time: float = -1.0
    # Cache-aside warm-up charged at dispatch when the execution node's
    # service cache missed (repro.serving.cache); 0.0 on a hit.
    miss_penalty: float = 0.0


@dataclasses.dataclass
class EdgeServiceState:
    """Per-(edge, service) view used for workload evaluation eqs (1)-(3)."""

    edge_id: int
    coords: tuple[float, float]
    phi: PhiEstimator
    replicas: int
    q_le: list = dataclasses.field(default_factory=list)   # to execute locally
    q_in: list = dataclasses.field(default_factory=list)   # inbound transfers
    q_out: list = dataclasses.field(default_factory=list)  # outbound transfers
    q_r: list = dataclasses.field(default_factory=list)    # awaiting scheduling
    q_f: list = dataclasses.field(default_factory=list)    # finished

    def workload(self, w_row: np.ndarray, ct: float) -> tuple[float, float, float]:
        """(c_le, c_in, t_in) per eqs (1)-(3). ``w_row[j]`` is the distance
        from edge j to this edge."""
        c_le = sum(float(self.phi(r.data_size)) for r in self.q_le) / self.replicas
        c_in = sum(float(self.phi(r.data_size)) for r in self.q_in) / self.replicas
        t_in = max(
            (ct * r.data_size * float(w_row[r.source_edge]) for r in self.q_in),
            default=0.0,
        )
        return c_le, c_in, t_in


def slot_workload_features(
    phi_est,
    replicas,
    w,
    ct,
    slot_size,
    slot_src,
    slot_edge,
    slot_ready,
    slot_start,
    t,
):
    """Array twin of :meth:`EdgeServiceState.workload`: evaluate (c_le, c_in,
    t_in) per eqs (1)-(3) for every edge directly from a batched engine's
    request slot table at time ``t``. jnp, jit/vmap-safe.

    Slot-queue membership mirrors the live queues of Fig. 5: a committed slot
    (``slot_edge >= 0``) whose data has not yet arrived (``ready > t``) is in
    Q^in; one whose data arrived but whose execution has not started
    (``ready <= t < start``) is in Q^le. Started/finished slots contribute
    nothing, exactly like the oracle's queues at a scheduling round.

    Shapes: phi_est (Q, 2), replicas (Q,), w (Q, Q); slot_* (Z,); returns
    (Q, 3) float32.
    """
    import jax.numpy as jnp

    num_edges = w.shape[-1]
    committed = slot_edge >= 0
    e = jnp.clip(slot_edge, 0, num_edges - 1)
    in_transfer = committed & (slot_ready > t)
    waiting = committed & (slot_ready <= t) & (slot_start > t)
    comp = phi_est[e, 0] * slot_size + phi_est[e, 1]          # (Z,) phi(f_z)
    zeros = jnp.zeros(num_edges, jnp.float32)
    c_le = zeros.at[e].add(jnp.where(waiting, comp, 0.0)) / replicas     # eq (1)
    c_in = zeros.at[e].add(jnp.where(in_transfer, comp, 0.0)) / replicas  # eq (3)
    trans = ct * slot_size * w[slot_src, e]                   # eq (2) terms
    t_in = zeros.at[e].max(jnp.where(in_transfer, trans, 0.0))
    return jnp.stack([c_le, c_in, t_in], axis=-1).astype(jnp.float32)


def snapshot_instance(
    edges: Sequence[EdgeServiceState],
    pending: Sequence[QueuedRequest],
    w: np.ndarray,
    ct: float,
    q_pad: int | None = None,
    z_pad: int | None = None,
    w_global: np.ndarray | None = None,
):
    """Freeze the live system into a scheduling instance (the CC's step (iv)).

    Returns the same pytree layout as instances.generate_instance, so the
    policy and every solver run unchanged on live serving state.

    ``w`` indexes the *provided* edges (e.g. the alive subset); backlog
    requests in Q^in may reference global edge ids, so pass ``w_global``
    (full distance matrix) for workload evaluation after failures.
    """
    q = len(edges)
    z = len(pending)
    qp = q_pad or q
    zp = z_pad or max(z, 1)
    coords = np.zeros((qp, 2), np.float32)
    phi = np.zeros((qp, 2), np.float32)
    reps = np.ones(qp, np.float32)
    wl = np.zeros((qp, 3), np.float32)
    wpad = np.zeros((qp, qp), np.float32)
    wpad[:q, :q] = w
    for i, e in enumerate(edges):
        coords[i] = e.coords
        phi[i] = e.phi.coefficients
        reps[i] = e.replicas
        w_row = (w_global[:, e.edge_id] if w_global is not None else w[:, i])
        wl[i] = e.workload(w_row, ct)
    req_src = np.zeros(zp, np.int32)
    req_size = np.zeros(zp, np.float32)
    for j, r in enumerate(pending):
        req_src[j] = r.source_edge
        req_size[j] = r.data_size
    edge_mask = np.arange(qp) < q
    req_mask = np.arange(zp) < z
    return {
        "edge_coords": coords,
        "phi": phi,
        "replicas": reps,
        "workload": wl,
        "w": wpad,
        "ct": np.float32(ct),
        "req_src": req_src,
        "req_size": req_size,
        "edge_mask": edge_mask,
        "req_mask": req_mask,
    }
