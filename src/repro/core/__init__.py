"""The paper's primary contribution: system-level state model, ILP
formulation, and the CoRaiS learning-based real-time scheduler."""
from repro.core.instances import InstanceConfig, generate_batch, generate_instance
from repro.core.objective import makespan, makespan_np, per_edge_times, per_edge_times_np
from repro.core.policy import (SCORE_BACKENDS, PolicyConfig, corais_apply,
                               corais_encode, corais_init, corais_score,
                               list_score_backends, register_score_backend)
from repro.core.decode import greedy_decode, sampling_decode, assignment_log_prob
from repro.core.inference import (DecisionSpec, make_decision_fn,
                                  make_policy_assign, policy_decide)
from repro.core.train import RLConfig, make_train_step, train
from repro.core.ablations import variant_config
from repro.core.state import EdgeServiceState, PhiEstimator, QueuedRequest, snapshot_instance

__all__ = [
    "InstanceConfig", "generate_batch", "generate_instance",
    "makespan", "makespan_np", "per_edge_times", "per_edge_times_np",
    "PolicyConfig", "corais_apply", "corais_init",
    "corais_encode", "corais_score", "SCORE_BACKENDS",
    "register_score_backend", "list_score_backends",
    "DecisionSpec", "make_decision_fn", "make_policy_assign", "policy_decide",
    "greedy_decode", "sampling_decode", "assignment_log_prob",
    "RLConfig", "make_train_step", "train",
    "variant_config",
    "EdgeServiceState", "PhiEstimator", "QueuedRequest", "snapshot_instance",
]
