"""CoMEC / CoR instance representation and synthetic generation (paper §V.A).

An *instance* is one scheduling round: the service-oriented subsystem state
CoMEC = (E, W, V, P, I) plus the request set CoR = (R, L, F). Instances are
plain dict pytrees with fixed (padded) shapes so they batch under vmap/jit:

    edge_coords : (Q, 2) f32   edge positions, U(0,1)^2
    phi         : (Q, 2) f32   phi_q(x) = phi[q,0] * x + phi[q,1]
    replicas    : (Q,)  f32    service replica count zeta_q, U{1..4}
    workload    : (Q, 3) f32   (c_le, c_in, t_in) from eqs (1)-(3)
    w           : (Q, Q) f32   transmission distance matrix (w_ii = 0)
    ct          : ()    f32    transmission speed constant C_t
    req_src     : (Z,)  i32    source edge index of each request
    req_size    : (Z,)  f32    input data size f_z, U(0,1)
    edge_mask   : (Q,)  bool   True for real (non-padding) edges
    req_mask    : (Z,)  bool   True for real requests

Padding lets one jitted policy/objective handle mixed system scales, which
is exactly the generalization axis the paper evaluates (Table III).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.workloads.base import SizeSpec, edge_weights

Instance = dict


@dataclasses.dataclass(frozen=True)
class InstanceConfig:
    num_edges: int = 5                 # Q (EN in the paper's tables)
    num_requests: int = 50             # Z (RN in the paper's tables)
    max_edges: Optional[int] = None    # padded Q (defaults to num_edges)
    max_requests: Optional[int] = None
    max_replicas: int = 4              # zeta ~ U{1..max_replicas}
    backlog_high: int = 100            # |Q^le|, |Q^in| ~ U(0, backlog_high)
    ct: float = 1.0                    # C_t
    phi_low: float = 0.0               # phi coefficients ~ U(phi_low, phi_high)
    phi_high: float = 1.0
    # Scenario conditioning (repro.workloads): data-size law for requests AND
    # backlogs, plus Zipf source skew. Defaults reproduce the paper's §V.A
    # i.i.d. uniform regime exactly.
    size_dist: str = "uniform"         # uniform | fixed | pareto | lognormal
    size_params: tuple = ()            # family parameters (see SizeSpec)
    size_cap: float = 1.0
    source_skew: float = 0.0           # Zipf exponent over source edges
    hot_edge: int = 0                  # which edge holds the top rank

    @property
    def q_pad(self) -> int:
        return self.max_edges or self.num_edges

    @property
    def z_pad(self) -> int:
        return self.max_requests or self.num_requests

    @property
    def size_spec(self) -> SizeSpec:
        return SizeSpec(self.size_dist, self.size_params, self.size_cap)


def _phi_eval(phi_row: np.ndarray, x: np.ndarray) -> np.ndarray:
    return phi_row[0] * x + phi_row[1]


def _sample_sources(rng: np.random.Generator, cfg: InstanceConfig, n: int,
                    exclude: Optional[int] = None) -> np.ndarray:
    """Source-edge indices under the scenario's Zipf popularity skew.
    ``source_skew=0`` keeps the paper's uniform draw (and its exact rng
    stream). ``exclude`` drops one edge (backlog Q^in senders != receiver)."""
    q = cfg.num_edges
    if cfg.source_skew == 0.0:
        if exclude is None:
            return rng.integers(0, q, size=(n,)).astype(np.int32)
        cands = [j for j in range(q) if j != exclude]
        return rng.choice(cands, size=n).astype(np.int32)
    probs = edge_weights(q, cfg.source_skew, cfg.hot_edge)
    if exclude is not None:
        probs = probs.copy()
        probs[exclude] = 0.0
        probs = probs / probs.sum()
    return rng.choice(q, size=n, p=probs).astype(np.int32)


def generate_instance(rng: np.random.Generator, cfg: InstanceConfig) -> Instance:
    """Sample one instance per the paper's rules (§V.A), optionally
    conditioned on a workload scenario (non-uniform sizes / skewed sources)
    via the cfg's ``size_dist``/``size_params``/``source_skew`` fields."""
    q, z = cfg.num_edges, cfg.num_requests
    size_spec = cfg.size_spec
    qp, zp = cfg.q_pad, cfg.z_pad
    assert q <= qp and z <= zp

    coords = rng.uniform(0.0, 1.0, size=(qp, 2)).astype(np.float32)
    # phi(x) = a x + b with heterogeneous coefficients ~ U(0, 1)
    phi = rng.uniform(cfg.phi_low, cfg.phi_high, size=(qp, 2)).astype(np.float32)
    replicas = rng.integers(1, cfg.max_replicas + 1, size=(qp,)).astype(np.float32)
    w = np.linalg.norm(coords[:, None, :] - coords[None, :, :], axis=-1).astype(np.float32)
    np.fill_diagonal(w, 0.0)

    # Backlogs -> workload features via eqs (1)-(3).
    c_le = np.zeros(qp, np.float32)
    c_in = np.zeros(qp, np.float32)
    t_in = np.zeros(qp, np.float32)
    for i in range(q):
        n_le = rng.integers(0, cfg.backlog_high)
        n_in = rng.integers(0, cfg.backlog_high)
        if n_le:
            sizes = size_spec.sample(rng, n_le).astype(np.float32)
            c_le[i] = _phi_eval(phi[i], sizes).sum() / replicas[i]          # eq (1)
        if n_in:
            sizes = size_spec.sample(rng, n_in).astype(np.float32)
            srcs = _sample_sources(rng, cfg, n_in, exclude=i)
            c_in[i] = _phi_eval(phi[i], sizes).sum() / replicas[i]          # eq (3)
            t_in[i] = float(np.max(cfg.ct * sizes * w[srcs, i]))            # eq (2)

    req_src = _sample_sources(rng, cfg, zp)
    req_size = size_spec.sample(rng, zp).astype(np.float32)

    edge_mask = np.zeros(qp, bool)
    edge_mask[:q] = True
    req_mask = np.zeros(zp, bool)
    req_mask[:z] = True
    # Padding hygiene: dead edges get no requests and zero features.
    req_src[z:] = 0
    req_size[z:] = 0.0
    phi[q:] = 0.0
    replicas[q:] = 1.0
    coords[q:] = 0.0

    return {
        "edge_coords": coords,
        "phi": phi,
        "replicas": replicas,
        "workload": np.stack([c_le, c_in, t_in], axis=-1),
        "w": w,
        "ct": np.float32(cfg.ct),
        "req_src": req_src,
        "req_size": req_size,
        "edge_mask": edge_mask,
        "req_mask": req_mask,
    }


def generate_batch(rng: np.random.Generator, cfg: InstanceConfig, batch: int) -> Instance:
    """Stack ``batch`` instances into one pytree with a leading batch axis."""
    insts = [generate_instance(rng, cfg) for _ in range(batch)]
    return {k: np.stack([inst[k] for inst in insts]) for k in insts[0]}


def edge_features(inst: Instance) -> np.ndarray:
    """Paper §IV-A edge encoder inputs: coords, phi coefficients, replicas,
    workload vector I_q. Shape (..., Q, 8)."""
    return np.concatenate(
        [
            inst["edge_coords"],
            inst["phi"],
            inst["replicas"][..., None],
            inst["workload"],
        ],
        axis=-1,
    )


def request_features(inst: Instance) -> np.ndarray:
    """Paper §IV-A request encoder inputs: source-edge coords + data size.
    Shape (..., Z, 3)."""
    src = inst["req_src"]
    coords = np.take_along_axis(
        inst["edge_coords"], src[..., None].astype(np.int64), axis=-2
    )
    return np.concatenate([coords, inst["req_size"][..., None]], axis=-1)
