"""Exact solvers and the ILP formulation (paper §III-D, eqs 4-11).

The min-max objective linearizes exactly (all max terms appear on the
minimized side):

    min T
    s.t.  T   >= m_q + eta_q(x)                  (eq 9)
          m_q >= mu_q(x)                         (eq 9 max arm 1)
          m_q >= Ct * v_q ;  m_q >= t_in_q       (eq 8)
          v_q >= f_z * w[src_z, q] * x_zq  ∀z    (eq 7)
          sum_q x_zq = 1 ∀z ;  x binary          (eqs 10, 11)

:func:`write_lp` exports this model in CPLEX LP format for external solvers
(Gurobi is not available in this offline container; see DESIGN.md §3).
:func:`solve_enumerate` and :func:`solve_branch_and_bound` are the in-repo
exact methods for small instances; B&B is validated against enumeration.
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.core.objective import makespan_np, per_edge_times_np


def _problem_arrays(inst):
    zs = np.nonzero(np.asarray(inst["req_mask"]))[0]
    qs = np.nonzero(np.asarray(inst["edge_mask"]))[0]
    phi = np.asarray(inst["phi"], np.float64)
    sizes = np.asarray(inst["req_size"], np.float64)
    src = np.asarray(inst["req_src"])
    w = np.asarray(inst["w"], np.float64)
    wl = np.asarray(inst["workload"], np.float64)
    reps = np.asarray(inst["replicas"], np.float64)
    ct = float(inst["ct"])
    return zs, qs, phi, sizes, src, w, wl, reps, ct


def solve_enumerate(inst, limit: int = 5_000_000) -> np.ndarray:
    """Exhaustive search over Q^Z assignments (tiny instances only)."""
    zs, qs, *_ = _problem_arrays(inst)
    if len(qs) ** len(zs) > limit:
        raise ValueError(f"search space {len(qs)}^{len(zs)} exceeds limit {limit}")
    assign = np.asarray(inst["req_src"], np.int32).copy()
    best, best_cost = None, np.inf
    for combo in itertools.product(qs, repeat=len(zs)):
        assign[zs] = combo
        cost = makespan_np(inst, assign)
        if cost < best_cost:
            best, best_cost = assign.copy(), cost
    return best


def solve_branch_and_bound(inst, node_limit: int = 2_000_000,
                           incumbent: np.ndarray | None = None) -> np.ndarray:
    """Depth-first B&B over request->edge assignments.

    Requests are branched in decreasing size order. The bound exploits that
    every term of T_q (eqs 5-9) is monotone nondecreasing in the assigned
    request set: the makespan of a partial assignment (unassigned requests
    ignored) is a valid lower bound on any completion. A per-request
    admissible increment (its best-case solo placement) tightens it.
    """
    zs, qs, phi, sizes, src, w, wl, reps, ct = _problem_arrays(inst)
    order = zs[np.argsort(-sizes[zs])]

    # best-case contribution of each unassigned request alone on its best edge
    solo = {}
    for z in order:
        best = np.inf
        for q in qs:
            comp = (phi[q, 0] * sizes[z] + phi[q, 1]) / reps[q]
            tx = ct * sizes[z] * w[src[z], q] if q != src[z] else 0.0
            # completing this request alone needs at least comp after tx/backlog
            lb = max(tx, wl[q, 2]) * 0 + comp  # comp always adds to mu or eta
            best = min(best, lb)
        solo[int(z)] = best

    from repro.core.heuristics import solve_greedy

    if incumbent is None:
        incumbent = solve_greedy(inst)
    best_assign = incumbent.copy()
    best_cost = makespan_np(inst, incumbent)

    assign = np.asarray(inst["req_src"], np.int32).copy()
    nodes = 0

    def partial_cost(upto: int) -> float:
        """Makespan counting only the first ``upto`` requests in order."""
        mask_backup = np.asarray(inst["req_mask"]).copy()
        m = np.zeros_like(mask_backup)
        m[order[:upto]] = True
        tmp = dict(inst)
        tmp["req_mask"] = m
        return makespan_np(tmp, assign)

    def dfs(i: int):
        nonlocal best_cost, best_assign, nodes
        nodes += 1
        if nodes > node_limit:
            raise TimeoutError("B&B node limit reached")
        if i == len(order):
            cost = partial_cost(len(order))
            if cost < best_cost - 1e-12:
                best_cost = cost
                best_assign = assign.copy()
            return
        z = order[i]
        # try edges by locally best completion estimate
        scored = []
        for q in qs:
            assign[z] = q
            lb = partial_cost(i + 1)
            scored.append((lb, q))
        scored.sort()
        for lb, q in scored:
            if lb >= best_cost - 1e-12:
                continue  # prune: bound is monotone
            assign[z] = q
            dfs(i + 1)
        assign[z] = src[z]

    dfs(0)
    return best_assign


def write_lp(inst, path: str) -> None:
    """Export the exact linearized ILP in CPLEX LP format."""
    zs, qs, phi, sizes, src, w, wl, reps, ct = _problem_arrays(inst)
    lines = ["Minimize", " obj: T", "Subject To"]
    # T >= m_q + eta_q(x):  T - m_q - sum coef*x >= c_in_q
    for q in qs:
        terms = " ".join(
            f"- {(phi[q,0]*sizes[z]+phi[q,1])/reps[q]:.9f} x_{z}_{q}"
            for z in zs
            if src[z] != q
        )
        lines.append(f" r_T_{q}: T - m_{q} {terms} >= {wl[q,1]:.9f}")
        # m_q >= mu_q(x)
        terms = " ".join(
            f"- {(phi[q,0]*sizes[z]+phi[q,1])/reps[q]:.9f} x_{z}_{q}"
            for z in zs
            if src[z] == q
        )
        lines.append(f" r_mu_{q}: m_{q} {terms} >= {wl[q,0]:.9f}")
        # m_q >= Ct v_q ; m_q >= t_in_q
        lines.append(f" r_kv_{q}: m_{q} - {ct:.9f} v_{q} >= 0")
        lines.append(f" r_kt_{q}: m_{q} >= {wl[q,2]:.9f}")
        # v_q >= f_z w[src_z,q] x_zq
        for z in zs:
            coef = sizes[z] * w[src[z], q]
            if coef > 0:
                lines.append(f" r_v_{q}_{z}: v_{q} - {coef:.9f} x_{z}_{q} >= 0")
    for z in zs:
        terms = " + ".join(f"x_{z}_{q}" for q in qs)
        lines.append(f" r_one_{z}: {terms} = 1")
    lines.append("Bounds")
    for q in qs:
        lines.append(f" m_{q} >= 0")
        lines.append(f" v_{q} >= 0")
    lines.append("Binaries")
    lines.append(" " + " ".join(f"x_{z}_{q}" for z in zs for q in qs))
    lines.append("End")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
