"""Non-learning baselines (paper §V-A) plus the time-budgeted reference.

* :func:`solve_local`   — every request executes at its source edge.
* :func:`solve_random`  — best of n uniform assignments (Random(n)).
* :func:`solve_greedy`  — size-descending greedy insertion (ours; also the
  serving controller's fallback when no policy checkpoint is loaded).
* :func:`solve_ils`     — iterated local search with a wall-clock budget.
  This is the offline-container stand-in for Gurobi(x s): it is what gaps
  are computed against (labelled REF in EXPERIMENTS.md, never "optimal").

All operate on a single (optionally padded) instance in numpy.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.objective import makespan_np


def _real_indices(inst):
    zs = np.nonzero(np.asarray(inst["req_mask"]))[0]
    qs = np.nonzero(np.asarray(inst["edge_mask"]))[0]
    return zs, qs


def solve_local(inst) -> np.ndarray:
    return np.asarray(inst["req_src"], np.int32).copy()


def solve_random(inst, num_samples: int = 1, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    zs, qs = _real_indices(inst)
    best, best_cost = None, np.inf
    assign = solve_local(inst)
    for _ in range(num_samples):
        cand = assign.copy()
        cand[zs] = rng.choice(qs, size=len(zs))
        cost = makespan_np(inst, cand)
        if cost < best_cost:
            best, best_cost = cand, cost
    return best


def solve_greedy(inst) -> np.ndarray:
    """Assign requests in decreasing data size, each to the edge that
    minimizes the incremental makespan."""
    zs, qs = _real_indices(inst)
    sizes = np.asarray(inst["req_size"])
    order = zs[np.argsort(-sizes[zs])]
    assign = solve_local(inst)
    assign[zs] = -1
    # makespan_np ignores unassigned only if we park them somewhere valid:
    # build up incrementally instead.
    cur = solve_local(inst)
    for z in order:
        best_q, best_cost = None, np.inf
        for q in qs:
            cur_z = cur[z]
            cur[z] = q
            # evaluate with all later (not-yet-decided) requests at source
            cost = makespan_np(inst, cur)
            cur[z] = cur_z
            if cost < best_cost:
                best_q, best_cost = q, cost
        cur[z] = best_q
    return cur


def _local_search(inst, assign, zs, qs, deadline) -> tuple[np.ndarray, float]:
    """Best-improvement single-request moves until a local optimum."""
    cost = makespan_np(inst, assign)
    improved = True
    while improved and time.perf_counter() < deadline:
        improved = False
        for z in zs:
            if time.perf_counter() >= deadline:
                break
            cur_q = assign[z]
            best_q, best_cost = cur_q, cost
            for q in qs:
                if q == cur_q:
                    continue
                assign[z] = q
                c = makespan_np(inst, assign)
                if c < best_cost - 1e-12:
                    best_q, best_cost = q, c
            assign[z] = best_q
            if best_q != cur_q:
                cost = best_cost
                improved = True
    return assign, cost


def solve_ils(inst, budget_s: float = 1.0, seed: int = 0,
              perturb_frac: float = 0.15) -> np.ndarray:
    """Iterated local search: greedy start, then (perturb -> local search)
    restarts keeping the best, until the wall-clock budget expires."""
    rng = np.random.default_rng(seed)
    zs, qs = _real_indices(inst)
    deadline = time.perf_counter() + budget_s
    assign = solve_greedy(inst)
    assign, cost = _local_search(inst, assign, zs, qs, deadline)
    best, best_cost = assign.copy(), cost
    k = max(1, int(perturb_frac * len(zs)))
    while time.perf_counter() < deadline:
        cand = best.copy()
        moved = rng.choice(zs, size=min(k, len(zs)), replace=False)
        cand[moved] = rng.choice(qs, size=len(moved))
        cand, cost = _local_search(inst, cand, zs, qs, deadline)
        if cost < best_cost - 1e-12:
            best, best_cost = cand.copy(), cost
    return best


SOLVERS = {
    "local": solve_local,
    "greedy": solve_greedy,
}
