"""Decode strategies (paper §IV-C): greedy and best-of-n sampling."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.objective import makespan


def greedy_decode(log_probs) -> jax.Array:
    """argmax_q a_qz per request. log_probs: (..., Z, Q) -> (..., Z)."""
    return jnp.argmax(log_probs, axis=-1).astype(jnp.int32)


def sample_assignments(key, log_probs, num_samples: int) -> jax.Array:
    """Draw S assignments from the factorized policy.

    log_probs: (Z, Q) -> (S, Z). For batched instances vmap this.
    """
    return jax.random.categorical(
        key, log_probs[None, :, :], axis=-1, shape=(num_samples,) + log_probs.shape[:-1]
    ).astype(jnp.int32)


def sampling_decode(key, inst, log_probs, num_samples: int):
    """Best-of-n sampling decode: sample n complete decisions, evaluate
    eq (19) for each, return (best_assignment, best_makespan).

    Always includes the greedy decision as one candidate (costless and
    guards the tail of the sampling distribution).
    """
    samples = sample_assignments(key, log_probs, num_samples)  # (S, Z)
    samples = jnp.concatenate([greedy_decode(log_probs)[None], samples], axis=0)
    costs = jax.vmap(lambda a: makespan(inst, a))(samples)
    best = jnp.argmin(costs)
    return samples[best], costs[best]


def topk_sampling_decode(key, inst, top_idx, top_lp, num_samples: int):
    """Best-of-n sampling from a (Z, K) candidate set instead of the dense
    (Z, Q) matrix: per-sample cost is O(Z*K).

    ``top_idx`` / ``top_lp``: per-request top-k edges and their log-probs
    (kernel or ``lax.top_k`` output; ``jax.random.categorical``
    renormalizes, so with K = Q this draws from exactly the same
    distribution as :func:`sampling_decode`). The greedy decision
    (``top_idx[..., 0]``) is always included as a candidate, matching
    :func:`sampling_decode`. Returns (best_assignment, best_makespan)."""
    slots = jax.random.categorical(
        key, top_lp[None, :, :], axis=-1,
        shape=(num_samples,) + top_lp.shape[:-1])          # (S, Z) in [0, K)
    samples = jnp.take_along_axis(top_idx[None, :, :], slots[..., None],
                                  axis=-1)[..., 0]         # (S, Z) edges
    samples = jnp.concatenate([top_idx[None, :, 0], samples], axis=0)
    samples = samples.astype(jnp.int32)
    costs = jax.vmap(lambda a: makespan(inst, a))(samples)
    best = jnp.argmin(costs)
    return samples[best], costs[best]


def assignment_log_prob(log_probs, assign, req_mask) -> jax.Array:
    """log p(pi) = sum_z log a_{x_z, z} over real requests.

    log_probs: (..., Z, Q); assign: (..., Z) -> (...)."""
    lp = jnp.take_along_axis(log_probs, assign[..., None].astype(jnp.int32), axis=-1)
    lp = jnp.squeeze(lp, -1) * req_mask.astype(lp.dtype)
    return jnp.sum(lp, axis=-1)
