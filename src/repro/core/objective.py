"""The paper's scheduling objective — eqs (4)-(11) / reward eqs (18)-(19).

Two implementations, cross-validated by property tests:

* :func:`makespan` — batched jnp, differentiable-through-none (pure eval),
  used as the RL reward and as the objective the ILP/solvers optimize.
* :func:`makespan_np` — scalar numpy mirror used by the exact solvers and
  heuristics (cheap incremental recomputation per edge).

Conventions: assignment ``x`` maps each request to an edge index;
``T_q = max(kappa_q, mu_q) + eta_q`` (eq 9); objective = max_q T_q (eq 4).
Note eq (7)'s transmission max over z includes local requests with
w[src,src] = 0, so masking src != q is equivalent.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG = -1e9


def phi_eval(phi, sizes):
    """phi: (..., Q, 2); sizes: (..., Z) -> (..., Z, Q) computation times."""
    return phi[..., None, :, 0] * sizes[..., :, None] + phi[..., None, :, 1]


def per_edge_times(inst, assign):
    """All per-edge terms for one assignment. assign: (..., Z) int32.

    Returns dict with mu, eta, kappa, T each (..., Q).
    """
    q_pad = inst["phi"].shape[-2]
    sizes = inst["req_size"]
    src = inst["req_src"]
    rmask = inst["req_mask"].astype(jnp.float32)

    onehot = jax.nn.one_hot(assign, q_pad, dtype=jnp.float32) * rmask[..., None]
    local = (assign == src).astype(jnp.float32)  # (..., Z)

    comp = phi_eval(inst["phi"], sizes)  # (..., Z, Q)
    # eq (5): locally-executed new work + local backlog
    mu = (
        jnp.einsum("...zq,...zq->...q", onehot * local[..., None], comp)
        / inst["replicas"]
        + inst["workload"][..., 0]
    )
    # eq (6): transferred-in new work + transferred-in backlog
    eta = (
        jnp.einsum("...zq,...zq->...q", onehot * (1.0 - local[..., None]), comp)
        / inst["replicas"]
        + inst["workload"][..., 1]
    )
    # eq (7): slowest incoming transfer among newly transferred requests
    w_src = jnp.take_along_axis(
        inst["w"], src[..., :, None].astype(jnp.int32), axis=-2
    )  # (..., Z, Q) distance from each request's source to every edge
    trans = sizes[..., :, None] * w_src * onehot  # zero where not assigned
    v = jnp.max(trans, axis=-2)  # (..., Q)
    # eq (8): include still-in-flight backlog transfers
    kappa = jnp.maximum(inst["ct"][..., None] * v, inst["workload"][..., 2])
    # eq (9)
    T = jnp.maximum(kappa, mu) + eta
    return {"mu": mu, "eta": eta, "kappa": kappa, "T": T}


def makespan(inst, assign) -> jax.Array:
    """Objective eq (4) / reward L(pi) = -u_hat of eq (19): max_q T_q over
    real edges. assign: (..., Z). Returns (...) f32."""
    T = per_edge_times(inst, assign)["T"]
    T = jnp.where(inst["edge_mask"], T, NEG)
    return jnp.max(T, axis=-1)


def makespan_batch_samples(inst, assigns) -> jax.Array:
    """inst: single instance (no batch axis); assigns: (S, Z). -> (S,)"""
    return jax.vmap(lambda a: makespan(inst, a))(assigns)


# ---------------------------------------------------------------------------
# numpy mirror (scalar, for solvers)
# ---------------------------------------------------------------------------


def per_edge_times_np(inst, assign: np.ndarray) -> dict:
    phi = np.asarray(inst["phi"])
    q_pad = phi.shape[0]
    sizes = np.asarray(inst["req_size"])
    src = np.asarray(inst["req_src"])
    rmask = np.asarray(inst["req_mask"])
    w = np.asarray(inst["w"])
    wl = np.asarray(inst["workload"])
    reps = np.asarray(inst["replicas"])
    ct = float(inst["ct"])

    mu = wl[:, 0].copy()
    eta = wl[:, 1].copy()
    v = np.zeros(q_pad, np.float64)
    for z in np.nonzero(rmask)[0]:
        q = int(assign[z])
        t = float(phi[q, 0] * sizes[z] + phi[q, 1])
        if q == src[z]:
            mu[q] += t / reps[q]
        else:
            eta[q] += t / reps[q]
            v[q] = max(v[q], float(sizes[z] * w[src[z], q]))
    kappa = np.maximum(ct * v, wl[:, 2])
    T = np.maximum(kappa, mu) + eta
    return {"mu": mu, "eta": eta, "kappa": kappa, "T": T}


def makespan_np(inst, assign: np.ndarray) -> float:
    T = per_edge_times_np(inst, assign)["T"]
    emask = np.asarray(inst["edge_mask"])
    return float(np.max(np.where(emask, T, -np.inf)))
