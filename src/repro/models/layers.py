"""Per-layer blocks: attention (train/prefill + decode), MLP/MoE, hybrid
attn+SSM combination (hymba), and whisper encoder/decoder layers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models.common import norm_apply, norm_init, position_encode, rms_head_norm
from repro.models.moe import moe_apply, moe_init
from repro.models.ssm import ssm_apply, ssm_decode_step, ssm_init
from repro.nn.module import normal_init, split_keys


# ---------------------------------------------------------------------------
# attention block
# ---------------------------------------------------------------------------


def attn_init(key, cfg: ModelConfig, dtype, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    kq, kk, kvk, ko = split_keys(key, 4)
    p = {
        "wq": normal_init(kq, (d, h * hd), stddev=0.02, dtype=dtype),
        "wk": normal_init(kk, (d, kv * hd), stddev=0.02, dtype=dtype),
        "wv": normal_init(kvk, (d, kv * hd), stddev=0.02, dtype=dtype),
        "wo": normal_init(ko, (h * hd, d), stddev=0.02, dtype=dtype),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _project_qkv(p, x, cfg: ModelConfig, positions, *, rope: bool):
    b = x.shape[0]
    s = x.shape[1]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, kv, hd)
    v = (x @ p["wv"]).reshape(b, s, kv, hd)
    if "q_norm" in p:
        q = rms_head_norm(p["q_norm"], q)
        k = rms_head_norm(p["k_norm"], k)
    if rope:
        q = position_encode(cfg, q, positions)
        k = position_encode(cfg, k, positions)
    return q, k, v


def attn_forward(p, x, positions, cfg: ModelConfig, *, causal: bool = True):
    """Full-sequence attention (train/prefill). Returns (out, (k, v))."""
    # whisper uses absolute position embeddings added at embed time, no rope
    rope = cfg.family != "audio"
    q, k, v = _project_qkv(p, x, cfg, positions, rope=rope)
    out = attn_lib.flash_attention(
        q, k, v,
        chunk=cfg.attn_chunk,
        causal=causal,
        window=cfg.sliding_window,
        logit_softcap=cfg.attn_logit_softcap,
        unroll=cfg.attn_unroll,
    )
    b, s = x.shape[0], x.shape[1]
    out = out.reshape(b, s, cfg.num_heads * cfg.head_dim) @ p["wo"]
    return out, (k, v)


def attn_decode(p, x_t, layer_cache, slot_pos, pos, cfg: ModelConfig):
    """One-token attention. x_t: (B, D); layer_cache: {"k","v"}: (B, W, KV, hd).
    Returns (out (B, D), new_layer_cache, (k_t, v_t))."""
    b = x_t.shape[0]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    rope = cfg.family != "audio"
    x1 = x_t[:, None, :]
    if cfg.mrope:
        positions = pos  # (3, B) -> handled as (3, B, 1) inside
        positions = positions[..., None]
        pos_scalar = pos[0]
    else:
        positions = pos[:, None]
        pos_scalar = pos
    q, k, v = _project_qkv(p, x1, cfg, positions, rope=rope)
    q = q[:, 0]  # (B, H, hd)
    k_t, v_t = k[:, 0], v[:, 0]  # (B, KV, hd)

    w = layer_cache["k"].shape[1]
    slot = pos_scalar % w  # (B,)
    onehot = jax.nn.one_hot(slot, w, dtype=layer_cache["k"].dtype)[:, :, None, None]
    new_k = layer_cache["k"] * (1 - onehot) + k_t[:, None] * onehot
    new_v = layer_cache["v"] * (1 - onehot) + v_t[:, None] * onehot
    from repro.sharding.ctx import current as _shard_ctx
    ctx = _shard_ctx()
    if cfg.decode_flash_shardmap and ctx is not None:
        out = attn_lib.sharded_decode_attention(
            q, new_k, new_v, slot_pos, pos_scalar,
            logit_softcap=cfg.attn_logit_softcap,
            window=cfg.sliding_window, ctx=ctx)
    else:
        out = attn_lib.decode_attention(
            q, new_k, new_v, slot_pos, pos_scalar,
            logit_softcap=cfg.attn_logit_softcap,
            window=cfg.sliding_window,
        )
    out = out.reshape(b, h * hd) @ p["wo"]
    return out, {"k": new_k, "v": new_v}


def cross_attn_forward(p, x, enc_out, cfg: ModelConfig):
    """Decoder->encoder cross attention (whisper). No rope, no causality."""
    b, s = x.shape[0], x.shape[1]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (enc_out @ p["wk"]).reshape(b, enc_out.shape[1], kv, hd)
    v = (enc_out @ p["wv"]).reshape(b, enc_out.shape[1], kv, hd)
    if s == 1:  # decode step: one naive block beats a 1-wide chunk scan
        out = attn_lib.naive_attention(q, k, v, causal=False)
    else:
        out = attn_lib.flash_attention(q, k, v, chunk=cfg.attn_chunk,
                                       causal=False, unroll=cfg.attn_unroll)
    return out.reshape(b, s, h * hd) @ p["wo"]


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.act == "silu":
        kg, ku, ko = split_keys(key, 3)
        return {
            "wg": normal_init(kg, (d, f), stddev=0.02, dtype=dtype),
            "wu": normal_init(ku, (d, f), stddev=0.02, dtype=dtype),
            "wo": normal_init(ko, (f, d), stddev=0.02, dtype=dtype),
        }
    ki, ko = split_keys(key, 2)
    return {
        "wi": normal_init(ki, (d, f), stddev=0.02, dtype=dtype),
        "wo": normal_init(ko, (f, d), stddev=0.02, dtype=dtype),
    }


def mlp_apply(p, x, cfg: ModelConfig):
    if "wg" in p:
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wo"]
    return jax.nn.gelu(x @ p["wi"]) @ p["wo"]


# ---------------------------------------------------------------------------
# decoder layer (dense / moe / ssm / hybrid)
# ---------------------------------------------------------------------------


def layer_init(key, cfg: ModelConfig, dtype):
    keys = split_keys(key, 5)
    p = {"ln1": norm_init(cfg, cfg.d_model)}
    if cfg.family == "ssm":
        p["ssm"] = ssm_init(keys[0], cfg, dtype)
        return p
    p["attn"] = attn_init(keys[0], cfg, dtype)
    if cfg.hybrid:
        p["ssm"] = ssm_init(keys[1], cfg, dtype)
        p["attn_branch_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["ssm_branch_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    p["ln2"] = norm_init(cfg, cfg.d_model)
    if cfg.num_experts:
        p["moe"] = moe_init(keys[2], cfg, dtype)
    else:
        p["mlp"] = mlp_init(keys[2], cfg, dtype)
    return p


def _branch_rms(scale, x):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + 1e-6) * scale).astype(x.dtype)


def layer_forward(p, x, positions, cfg: ModelConfig, dp_groups: int = 1):
    """Full-sequence decoder layer.

    Returns (x, kv_or_None, ssm_state_or_None, aux_loss)."""
    h = norm_apply(cfg, p["ln1"], x)
    aux = jnp.zeros((), jnp.float32)
    kv, ssm_state = None, None
    if cfg.family == "ssm":
        y, ssm_state = ssm_apply(p["ssm"], h, cfg)
        return x + y, None, ssm_state, aux
    a, kv = attn_forward(p["attn"], h, positions, cfg, causal=True)
    if cfg.hybrid:
        s, ssm_state = ssm_apply(p["ssm"], h, cfg)
        a = 0.5 * (_branch_rms(p["attn_branch_norm"], a)
                   + _branch_rms(p["ssm_branch_norm"], s))
    x = x + a
    h2 = norm_apply(cfg, p["ln2"], x)
    if cfg.num_experts:
        y, aux = moe_apply(p["moe"], h2, cfg, dp_groups)
    else:
        y = mlp_apply(p["mlp"], h2, cfg)
    return x + y, kv, ssm_state, aux


def layer_decode(p, x_t, layer_cache, slot_pos, pos, cfg: ModelConfig,
                 dp_groups: int = 1):
    """One-token decoder layer. x_t: (B, D). Returns (x_t, new_layer_cache)."""
    h = norm_apply(cfg, p["ln1"], x_t)
    new_cache = dict(layer_cache)
    if cfg.family == "ssm":
        y, ssm_state = ssm_decode_step(
            p["ssm"], h, {"h": layer_cache["h"], "conv": layer_cache["conv"]}, cfg)
        new_cache.update(ssm_state)
        return x_t + y, new_cache  # noqa: single-branch ssm layer
    a, kv_cache = attn_decode(
        p["attn"], h, {"k": layer_cache["k"], "v": layer_cache["v"]},
        slot_pos, pos, cfg)
    new_cache.update(kv_cache)
    if cfg.hybrid:
        y, ssm_state = ssm_decode_step(
            p["ssm"], h, {"h": layer_cache["h"], "conv": layer_cache["conv"]}, cfg)
        new_cache.update(ssm_state)
        a = 0.5 * (_branch_rms(p["attn_branch_norm"], a)
                   + _branch_rms(p["ssm_branch_norm"], y))
    x_t = x_t + a
    h2 = norm_apply(cfg, p["ln2"], x_t)
    if cfg.num_experts:
        y2, _ = moe_apply(p["moe"], h2[:, None, :], cfg, dp_groups)
        y2 = y2[:, 0]
    else:
        y2 = mlp_apply(p["mlp"], h2, cfg)
    return x_t + y2, new_cache


# ---------------------------------------------------------------------------
# whisper encoder / decoder layers
# ---------------------------------------------------------------------------


def enc_layer_init(key, cfg: ModelConfig, dtype):
    k1, k2 = split_keys(key, 2)
    return {
        "ln1": norm_init(cfg, cfg.d_model),
        "attn": attn_init(k1, cfg, dtype),
        "ln2": norm_init(cfg, cfg.d_model),
        "mlp": mlp_init(k2, cfg, dtype),
    }


def enc_layer_forward(p, x, positions, cfg: ModelConfig):
    h = norm_apply(cfg, p["ln1"], x)
    a, _ = attn_forward(p["attn"], h, positions, cfg, causal=False)
    x = x + a
    h2 = norm_apply(cfg, p["ln2"], x)
    return x + mlp_apply(p["mlp"], h2, cfg)


def dec_layer_init(key, cfg: ModelConfig, dtype):
    k1, k2, k3 = split_keys(key, 3)
    return {
        "ln1": norm_init(cfg, cfg.d_model),
        "attn": attn_init(k1, cfg, dtype),
        "ln_x": norm_init(cfg, cfg.d_model),
        "xattn": attn_init(k2, cfg, dtype, cross=True),
        "ln2": norm_init(cfg, cfg.d_model),
        "mlp": mlp_init(k3, cfg, dtype),
    }


def dec_layer_forward(p, x, enc_out, positions, cfg: ModelConfig):
    h = norm_apply(cfg, p["ln1"], x)
    a, kv = attn_forward(p["attn"], h, positions, cfg, causal=True)
    x = x + a
    hx = norm_apply(cfg, p["ln_x"], x)
    x = x + cross_attn_forward(p["xattn"], hx, enc_out, cfg)
    h2 = norm_apply(cfg, p["ln2"], x)
    return x + mlp_apply(p["mlp"], h2, cfg), kv


def dec_layer_decode(p, x_t, enc_out, layer_cache, slot_pos, pos, cfg: ModelConfig):
    h = norm_apply(cfg, p["ln1"], x_t)
    a, kv_cache = attn_decode(
        p["attn"], h, {"k": layer_cache["k"], "v": layer_cache["v"]},
        slot_pos, pos, cfg)
    x_t = x_t + a
    hx = norm_apply(cfg, p["ln_x"], x_t)
    xa = cross_attn_forward(p["xattn"], hx[:, None, :], enc_out, cfg)[:, 0]
    x_t = x_t + xa
    h2 = norm_apply(cfg, p["ln2"], x_t)
    new_cache = dict(layer_cache)
    new_cache.update(kv_cache)
    return x_t + mlp_apply(p["mlp"], h2, cfg), new_cache
