"""Mixtral-style MoE layer: top-k routing with grouped capacity dispatch.

TPU adaptation (DESIGN.md §4): instead of emulating GPU all-to-all expert
parallelism, tokens are dispatched into a dense (groups, experts, capacity,
d_model) buffer — one group per data shard, realized by reshaping the token
axis to (dp_groups, local_tokens) and vmapping the dispatch. Every op is
then embarrassingly parallel along the sharded group axis under pjit (no
cross-shard scatter), and the expert FFN is a batched matmul that is
TP-sharded over d_ff. Compute = top_k * capacity_factor * useful FLOPs.

The routing problem itself is a miniature of the paper's scheduling problem
(heterogeneous "edges" = experts, capacity = replicas); the analogy stops
there — CoRaiS operates at the serving layer (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.module import normal_init, split_keys


def moe_init(key, cfg: ModelConfig, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    kr, kg, ku, ko = split_keys(key, 4)
    return {
        "router": normal_init(kr, (d, e), stddev=0.02, dtype=jnp.float32),
        "wg": normal_init(kg, (e, d, f), stddev=0.02, dtype=dtype),
        "wu": normal_init(ku, (e, d, f), stddev=0.02, dtype=dtype),
        "wo": normal_init(ko, (e, f, d), stddev=0.02, dtype=dtype),
    }


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(n_tokens * cfg.experts_per_token / cfg.num_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)


def _dispatch_ffn(x, p, cfg: ModelConfig):
    """Per-group dispatch + expert FFN + combine. x: (N, D)."""
    n, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    cap = _capacity(n, cfg)

    logits = (x.astype(jnp.float32) @ p["router"])  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(logits, k)  # (N, k)
    gates = jax.nn.softmax(vals, axis=-1)  # renormalized over chosen experts

    flat_e = idx.reshape(-1)  # (N*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    rank = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.sum(rank * onehot, axis=-1)  # rank within expert
    keep = (pos < cap).astype(x.dtype)
    pos_c = jnp.minimum(pos, cap - 1)

    token_idx = jnp.repeat(jnp.arange(n), k)
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[flat_e, pos_c].add(x[token_idx] * keep[:, None])

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["wu"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"])

    y = out_buf[flat_e, pos_c] * (keep * gates.reshape(-1).astype(x.dtype))[:, None]
    y = y.reshape(n, k, d).sum(axis=1)

    # Switch-style load-balance aux loss: E * sum_e f_e * P_e
    f_e = jnp.mean(jnp.sum(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=1), axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e / k * p_e)
    return y, aux


def _dense_moe(x, p, cfg: ModelConfig):
    """Small-token path (decode): compute every expert densely and combine
    by gate weight. k/E of the FLOPs are useful (4x waste for top-2-of-8),
    but the token axis stays batch-sharded, there is no dispatch machinery
    or capacity-floor padding, and no tokens are ever dropped — at decode
    batch sizes the step is parameter-streaming-bound anyway (§Perf)."""
    e, k = cfg.num_experts, cfg.experts_per_token
    logits = x.astype(jnp.float32) @ p["router"]  # (B, S, E)
    vals, idx = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(vals, axis=-1)
    combine = jnp.sum(
        jax.nn.one_hot(idx, e, dtype=jnp.float32) * gates[..., None], axis=-2)
    # keep weights as bf16 dot operands (f32 only as the dot accumulator) —
    # an f32 upcast would double the parameter-streaming traffic
    h = jax.nn.silu(jnp.einsum("bsd,edf->bsef", x, p["wg"])) * jnp.einsum(
        "bsd,edf->bsef", x, p["wu"])
    h = h.astype(x.dtype)
    out = jnp.einsum("bsef,efd->bsed", h, p["wo"],
                     preferred_element_type=jnp.float32)
    y = jnp.einsum("bsed,bse->bsd", out, combine)
    probs = jax.nn.softmax(logits, axis=-1)
    f_e = jnp.mean(jnp.sum(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=-2),
                   axis=(0, 1))
    aux = e * jnp.sum(f_e / k * jnp.mean(probs, axis=(0, 1)))
    return y.astype(x.dtype), aux


def moe_apply(p, x, cfg: ModelConfig, dp_groups: int = 1):
    """x: (B, S, D) -> (y, aux_loss). ``dp_groups`` must divide B*S and
    match the data-parallel sharding of the token axis so dispatch stays
    shard-local under pjit. Token counts too small to amortize the capacity
    dispatch fall through to the dense path."""
    b, s, d = x.shape
    tokens = b * s
    if cfg.moe_dense_decode and tokens <= 256:
        return _dense_moe(x, p, cfg)
    g = dp_groups if tokens % dp_groups == 0 else 1
    xg = x.reshape(g, tokens // g, d)
    y, aux = jax.vmap(lambda t: _dispatch_ffn(t, p, cfg))(xg)
    return y.reshape(b, s, d), jnp.mean(aux)
