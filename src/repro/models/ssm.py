"""Mamba-1 selective SSM block (falcon-mamba; the SSM branch of hymba).

TPU adaptation (DESIGN.md §4): the fused CUDA selective-scan kernel becomes
a *chunked* scan — sequential lax.scan over sequence chunks carrying the
(B, d_inner, d_state) hidden state, with an associative scan inside each
chunk. The (B, chunk, d_inner, d_state) discretized tensors exist only per
chunk, bounding live memory to VMEM-friendly tiles; d_inner is TP-sharded.
repro.kernels.mamba_scan implements the same chunking as a Pallas kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import causal_depthwise_conv, conv_step
from repro.nn.module import normal_init, split_keys, uniform_init


def ssm_init(key, cfg: ModelConfig, dtype):
    d, di, ds, dr, k = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                        cfg.ssm_dt_rank, cfg.ssm_conv)
    keys = split_keys(key, 6)
    # S4D-real initialization for A; dt bias init so softplus(dt) ~ U(1e-3, 1e-1)
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt = jnp.exp(
        jax.random.uniform(keys[5], (di,)) * (jnp.log(0.1) - jnp.log(1e-3))
        + jnp.log(1e-3)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "in_proj": normal_init(keys[0], (d, 2 * di), stddev=0.02, dtype=dtype),
        "conv_w": normal_init(keys[1], (di, k), stddev=0.02, dtype=jnp.float32),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": normal_init(keys[2], (di, dr + 2 * ds), stddev=0.02, dtype=dtype),
        "dt_proj": uniform_init(keys[3], (dr, di), fan_in=dr, dtype=jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": normal_init(keys[4], (di, d), stddev=0.02, dtype=dtype),
    }


def _chunk_combine(h0, dA, dBu):
    """Associative scan of h_t = dA_t * h_{t-1} + dBu_t within one chunk.

    h0: (B, d, N); dA, dBu: (B, c, d, N). Returns (h_last, h_all)."""

    def op(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_r * a_l, a_r * b_l + b_r

    a, b = jax.lax.associative_scan(op, (dA, dBu), axis=1)
    h = a * h0[:, None] + b
    return h[:, -1], h


def ssm_scan(u, dt, B_mat, C_mat, A, chunk: int = 256, unroll: bool = False,
             scan_dtype=jnp.float32):
    """Selective scan. u, dt: (B, S, d); B_mat, C_mat: (B, S, N); A: (d, N).
    Returns (y: (B, S, d) fp32, h_last: (B, d, N)). ``unroll`` statically
    unrolls the chunk loop (dry-run cost probes). ``scan_dtype`` controls
    the discretized (B, c, d, N) tensors — bf16 halves the dominant memory
    traffic of the memory-bound SSM cells (§Perf variant)."""
    b, s, d = u.shape
    n = A.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    def body(h, xs):
        u_c, dt_c, b_c, c_c = xs  # (B, c, ...)
        dA = jnp.exp(dt_c[..., None] * A).astype(scan_dtype)  # (B, c, d, N)
        dBu = (dt_c[..., None] * b_c[:, :, None, :]
               * u_c[..., None]).astype(scan_dtype)
        h_last, h_all = _chunk_combine(h, dA, dBu)
        y_c = jnp.einsum("bcdn,bcn->bcd", h_all.astype(jnp.float32), c_c)
        return h_last, y_c

    xs = (
        u.reshape(b, nc, chunk, d).swapaxes(0, 1),
        dt.reshape(b, nc, chunk, d).swapaxes(0, 1),
        B_mat.reshape(b, nc, chunk, n).swapaxes(0, 1),
        C_mat.reshape(b, nc, chunk, n).swapaxes(0, 1),
    )
    h0 = jnp.zeros((b, d, n), scan_dtype)
    if unroll:
        h, ys_list = h0, []
        for i in range(nc):
            h, y_c = body(h, jax.tree.map(lambda a: a[i], xs))
            ys_list.append(y_c)
        return jnp.concatenate(ys_list, axis=1), h.astype(jnp.float32)
    h_last, ys = jax.lax.scan(body, h0, xs)
    return ys.swapaxes(0, 1).reshape(b, s, d), h_last.astype(jnp.float32)


def ssm_apply(p, x, cfg: ModelConfig):
    """Full-sequence mamba block. x: (B, S, D) -> (out (B, S, D), state).

    ``state`` matches :func:`ssm_decode_step`'s format so prefill can hand
    directly into decode."""
    chunk = cfg.ssm_chunk
    di, dr, ds = cfg.d_inner, cfg.ssm_dt_rank, cfg.ssm_state
    k = cfg.ssm_conv
    uz = x @ p["in_proj"]
    u_raw, z = jnp.split(uz, 2, axis=-1)
    u_raw = u_raw.astype(jnp.float32)
    u = jax.nn.silu(causal_depthwise_conv(u_raw, p["conv_w"], p["conv_b"]))
    xdbc = u.astype(x.dtype) @ p["x_proj"]
    dt_low = xdbc[..., :dr].astype(jnp.float32)
    B_mat = xdbc[..., dr:dr + ds].astype(jnp.float32)
    C_mat = xdbc[..., dr + ds:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_low @ p["dt_proj"] + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, h_last = ssm_scan(u, dt, B_mat, C_mat, A, chunk=chunk,
                         unroll=cfg.ssm_unroll,
                         scan_dtype=jnp.dtype(cfg.ssm_scan_dtype))
    y = y + p["D"] * u
    y = y * jax.nn.silu(z.astype(jnp.float32))
    # conv state = last K-1 raw (pre-conv) inputs, as consumed by conv_step
    s_len = u_raw.shape[1]
    if s_len >= k - 1:
        conv_state = u_raw[:, s_len - (k - 1):, :]
    else:
        conv_state = jnp.pad(u_raw, ((0, 0), (k - 1 - s_len, 0), (0, 0)))
    state = {"h": h_last, "conv": conv_state}
    return (y.astype(x.dtype)) @ p["out_proj"], state


def ssm_decode_step(p, x_t, state, cfg: ModelConfig):
    """One-token step. x_t: (B, D); state: {"h": (B, d, N), "conv": (B, K-1, d)}.
    Returns (y_t (B, D), new_state)."""
    di, dr, ds = cfg.d_inner, cfg.ssm_dt_rank, cfg.ssm_state
    uz = x_t @ p["in_proj"]
    u, z = jnp.split(uz, 2, axis=-1)
    u_c, conv_state = conv_step(u.astype(jnp.float32), state["conv"], p["conv_w"], p["conv_b"])
    u_c = jax.nn.silu(u_c)
    xdbc = u_c.astype(x_t.dtype) @ p["x_proj"]
    dt_low = xdbc[..., :dr].astype(jnp.float32)
    B_mat = xdbc[..., dr:dr + ds].astype(jnp.float32)
    C_mat = xdbc[..., dr + ds:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_low @ p["dt_proj"] + p["dt_bias"])  # (B, d)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A)  # (B, d, N)
    dBu = dt[..., None] * B_mat[:, None, :] * u_c[..., None]
    h = dA * state["h"] + dBu
    y = jnp.einsum("bdn,bn->bd", h, C_mat) + p["D"] * u_c
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return (y.astype(x_t.dtype)) @ p["out_proj"], {"h": h, "conv": conv_state}


def ssm_state_shapes(cfg: ModelConfig, batch: int):
    return {
        "h": (batch, cfg.d_inner, cfg.ssm_state),
        "conv": (batch, cfg.ssm_conv - 1, cfg.d_inner),
    }
