"""Memory-bounded attention in pure JAX (the jit / dry-run execution path).

Causal attention uses a *pair-scan flash* formulation: one lax.scan over the
static list of (q-chunk, kv-chunk) blocks of the lower triangle (restricted
to the sliding-window band when configured), maintaining online-softmax
statistics in fp32. Versus the naive masked formulation this
 (a) bounds live memory to one block of scores,
 (b) emits *only useful* FLOPs into the HLO — the compiled cost analysis and
     roofline compute term then reflect real work (no 2x causal waste), and
 (c) carries a custom VJP (FlashAttention-2 style block-recompute backward)
     so training memory stays O(S) rather than O(S^2).

The Pallas kernels in repro.kernels implement the same blocking for the TPU
target; tests cross-validate naive ref / pair-scan / kernel, including grads.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

try:
    _shard_map = jax.shard_map
except AttributeError:  # pre-0.5 jax keeps it in jax.experimental
    from jax.experimental.shard_map import shard_map as _shard_map

NEG_INF = -1e30


def _softcap(s, cap: float):
    if cap and cap > 0:
        return cap * jnp.tanh(s / cap)
    return s


def naive_attention(q, k, v, *, causal=True, window=None, logit_softcap=0.0):
    """Reference O(S^2)-memory attention. q: (B,S,H,hd); k,v: (B,S,KV,hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bqkgd,bmkd->bkgqm", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = _softcap(s / math.sqrt(hd), logit_softcap)
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqm,bmkd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)


def _block_pairs(nq: int, nk: int, window_chunks: int | None, causal: bool):
    import numpy as np
    pairs = []
    for i in range(nq):
        lo = 0 if window_chunks is None else max(0, i - window_chunks)
        hi = i if causal else nk - 1
        for j in range(lo, hi + 1):
            pairs.append((i, j))
    # plain numpy: stays concrete under custom_vjp tracing (the unrolled
    # probe path iterates it in Python)
    return np.asarray(pairs, np.int32)


def _block_mask(i, j, cq, ck, causal, window, kv_len):
    rows = i * cq + jnp.arange(cq)[:, None]
    cols = j * ck + jnp.arange(ck)[None, :]
    mask = cols < kv_len
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    return mask


def _needs_mask(causal, window, kv_len, nk, ck):
    return causal or window is not None or kv_len != nk * ck


def _run_pairs(body, carry, pairs, unroll: bool):
    """lax.scan over block pairs, or a static Python unroll (cost probes)."""
    if unroll:
        import numpy as _np
        for pr in _np.asarray(pairs):
            carry, _ = body(carry, (int(pr[0]), int(pr[1])))
        return carry
    carry, _ = jax.lax.scan(body, carry, pairs)
    return carry


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, chunk, causal, window, logit_softcap, kv_len, unroll):
    out, _ = _flash_fwd_impl(q, k, v, chunk, causal, window, logit_softcap,
                             kv_len, unroll)
    return out


def _flash_fwd_impl(q, k, v, chunk, causal, window, logit_softcap, kv_len,
                    unroll=False):
    B, S, H, hd = q.shape
    Sk = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    n = S // chunk
    ck = chunk
    nk = Sk // ck
    wc = None if window is None else -(-window // chunk)
    pairs = _block_pairs(n, nk, wc, causal)
    masked = _needs_mask(causal, window, kv_len, nk, ck)
    qg = q.reshape(B, n, chunk, KV, G, hd)
    kg = k.reshape(B, nk, ck, KV, hd)
    vg = v.reshape(B, nk, ck, KV, hd)
    scale = 1.0 / math.sqrt(hd)

    out = jnp.zeros((B, n, chunk, KV, G, hd), jnp.float32)
    m = jnp.full((B, n, chunk, KV, G), NEG_INF, jnp.float32)
    l = jnp.zeros((B, n, chunk, KV, G), jnp.float32)

    def body(carry, pair):
        out, m, l = carry
        i, j = pair[0], pair[1]
        qi = jax.lax.dynamic_index_in_dim(qg, i, 1, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kg, j, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vg, j, 1, keepdims=False)
        s = jnp.einsum("bqkgd,bmkd->bqkgm", qi.astype(jnp.float32),
                       kj.astype(jnp.float32)) * scale
        s = _softcap(s, logit_softcap)
        if masked:
            mask = _block_mask(i, j, chunk, ck, causal, window, kv_len)
            s = jnp.where(mask[:, None, None, :], s, NEG_INF)

        mi = jax.lax.dynamic_index_in_dim(m, i, 1, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, i, 1, keepdims=False)
        oi = jax.lax.dynamic_index_in_dim(out, i, 1, keepdims=False)
        m_new = jnp.maximum(mi, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(mi - m_new)
        l_new = li * alpha + jnp.sum(p, axis=-1)
        o_new = oi * alpha[..., None] + jnp.einsum(
            "bqkgm,bmkd->bqkgd", p, vj.astype(jnp.float32))
        out = jax.lax.dynamic_update_index_in_dim(out, o_new, i, 1)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 1)
        return (out, m, l), None

    out, m, l = _run_pairs(body, (out, m, l), pairs, unroll)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    out = out / jnp.maximum(l[..., None], 1e-30)
    out = out.reshape(B, S, H, hd).astype(q.dtype)
    return out, lse  # lse: (B, n, chunk, KV, G)


def _flash_fwd(q, k, v, chunk, causal, window, logit_softcap, kv_len, unroll):
    out, lse = _flash_fwd_impl(q, k, v, chunk, causal, window, logit_softcap,
                               kv_len, unroll)
    return out, (q, k, v, out, lse)


def _flash_bwd(chunk, causal, window, logit_softcap, kv_len, unroll, res, dout):
    q, k, v, out, lse = res
    B, S, H, hd = q.shape
    Sk = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    n = S // chunk
    ck = chunk
    nk = Sk // ck
    wc = None if window is None else -(-window // chunk)
    pairs = _block_pairs(n, nk, wc, causal)
    masked = _needs_mask(causal, window, kv_len, nk, ck)
    scale = 1.0 / math.sqrt(hd)

    qg = q.reshape(B, n, chunk, KV, G, hd)
    kg = k.reshape(B, nk, ck, KV, hd)
    vg = v.reshape(B, nk, ck, KV, hd)
    og = out.reshape(B, n, chunk, KV, G, hd).astype(jnp.float32)
    dog = dout.reshape(B, n, chunk, KV, G, hd).astype(jnp.float32)
    # delta_i = rowsum(dO * O)
    delta = jnp.sum(og * dog, axis=-1)  # (B, n, chunk, KV, G)

    dq = jnp.zeros((B, n, chunk, KV, G, hd), jnp.float32)
    dk = jnp.zeros((B, nk, ck, KV, hd), jnp.float32)
    dv = jnp.zeros((B, nk, ck, KV, hd), jnp.float32)

    def body(carry, pair):
        dq, dk, dv = carry
        i, j = pair[0], pair[1]
        qi = jax.lax.dynamic_index_in_dim(qg, i, 1, keepdims=False).astype(jnp.float32)
        kj = jax.lax.dynamic_index_in_dim(kg, j, 1, keepdims=False).astype(jnp.float32)
        vj = jax.lax.dynamic_index_in_dim(vg, j, 1, keepdims=False).astype(jnp.float32)
        lse_i = jax.lax.dynamic_index_in_dim(lse, i, 1, keepdims=False)
        do_i = jax.lax.dynamic_index_in_dim(dog, i, 1, keepdims=False)
        dl_i = jax.lax.dynamic_index_in_dim(delta, i, 1, keepdims=False)

        s_raw = jnp.einsum("bqkgd,bmkd->bqkgm", qi, kj) * scale
        s = _softcap(s_raw, logit_softcap)
        if masked:
            mask = _block_mask(i, j, chunk, ck, causal, window, kv_len)
            s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        p = jnp.exp(s - lse_i[..., None])  # (B,q,KV,G,m)

        dv_j = jnp.einsum("bqkgm,bqkgd->bmkd", p, do_i)
        dp = jnp.einsum("bqkgd,bmkd->bqkgm", do_i, vj)
        ds = p * (dp - dl_i[..., None])
        if logit_softcap and logit_softcap > 0:
            ds = ds * (1.0 - jnp.square(jnp.tanh(s_raw / logit_softcap)))
        if masked:
            ds = jnp.where(mask[:, None, None, :], ds, 0.0)
        dq_i = jnp.einsum("bqkgm,bmkd->bqkgd", ds, kj) * scale
        dk_j = jnp.einsum("bqkgm,bqkgd->bmkd", ds, qi) * scale

        dq = jax.lax.dynamic_update_index_in_dim(
            dq, jax.lax.dynamic_index_in_dim(dq, i, 1, keepdims=False) + dq_i, i, 1)
        dk = jax.lax.dynamic_update_index_in_dim(
            dk, jax.lax.dynamic_index_in_dim(dk, j, 1, keepdims=False) + dk_j, j, 1)
        dv = jax.lax.dynamic_update_index_in_dim(
            dv, jax.lax.dynamic_index_in_dim(dv, j, 1, keepdims=False) + dv_j, j, 1)
        return (dq, dk, dv), None

    dq, dk, dv = _run_pairs(body, (dq, dk, dv), pairs, unroll)
    return (dq.reshape(q.shape).astype(q.dtype),
            dk.reshape(k.shape).astype(k.dtype),
            dv.reshape(v.shape).astype(v.dtype))


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, chunk: int = 512, causal: bool = True,
                    window: int | None = None, logit_softcap: float = 0.0,
                    unroll: bool = False):
    """Pair-scan flash attention with flash backward.

    q: (B, S, H, hd); k, v: (B, Sk, KV, hd); H a multiple of KV.
    Non-divisible lengths are zero-padded to the chunk grid and masked.
    ``unroll`` statically unrolls the block loop (dry-run cost probes only).
    """
    Sq, Sk = q.shape[1], k.shape[1]
    chunk = min(chunk, max(Sq, 1))
    pad_q = (-Sq) % chunk
    pad_k = (-Sk) % chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    out = _flash(q, k, v, chunk, causal, window, logit_softcap, Sk, unroll)
    if pad_q:
        out = out[:, :Sq]
    return out


def sharded_decode_attention(q, k_cache, v_cache, cache_positions, pos, *,
                             window: int | None = None,
                             logit_softcap: float = 0.0, ctx=None):
    """Flash-decode over a sequence-sharded KV cache (beyond-paper §Perf).

    The cache window axis is sharded over the TP axis; each shard computes
    a partial online softmax over its slots and the shards combine with
    three tiny collectives (pmax of the running max, psum of the rescaled
    numerator (B,H,hd) and denominator (B,H)). This replaces GSPMD's
    auto-partitioning of softmax-over-sharded-axis, which gathers
    score-sized tensors (~score_bytes per layer per token) — the dominant
    collective cost in the decode_32k baseline cells.
    """
    from jax.sharding import PartitionSpec as P

    if ctx is None:
        from repro.sharding.ctx import current
        ctx = current()
    B, W, KV, hd = k_cache.shape
    H = q.shape[1]
    tp = ctx.tp_axis
    if W % ctx.mesh.shape[tp] != 0:
        return decode_attention(q, k_cache, v_cache, cache_positions, pos,
                                logit_softcap=logit_softcap, window=window)
    dp = ctx.dp

    def local(q, kc, vc, sp, pos):
        G = H // KV
        qg = q.reshape(-1, KV, G, hd)
        s = jnp.einsum("bkgd,bmkd->bkgm", qg.astype(jnp.float32),
                       kc.astype(jnp.float32)) / math.sqrt(hd)
        s = _softcap(s, logit_softcap)
        valid = (sp >= 0) & (sp <= pos[:, None])
        if window is not None:
            valid &= sp > (pos[:, None] - window)
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_loc = jnp.max(s, axis=-1)                       # (b,KV,G)
        m_glob = jax.lax.pmax(m_loc, tp)
        p = jnp.exp(s - m_glob[..., None])
        denom = jax.lax.psum(jnp.sum(p, axis=-1), tp)     # (b,KV,G)
        num = jax.lax.psum(
            jnp.einsum("bkgm,bmkd->bkgd", p, vc.astype(jnp.float32)), tp)
        out = num / jnp.maximum(denom[..., None], 1e-30)
        return out.reshape(-1, H, hd).astype(q.dtype)

    mesh = ctx.mesh
    return _shard_map(
        local,
        mesh=mesh,
        in_specs=(P(dp, None, None), P(dp, tp, None, None),
                  P(dp, tp, None, None), P(dp, tp), P(dp)),
        out_specs=P(dp, None, None),
    )(q, k_cache, v_cache, cache_positions, pos)


def decode_attention(q, k_cache, v_cache, cache_positions, pos, *,
                     logit_softcap: float = 0.0, window: int | None = None):
    """Single-token attention against a (possibly rolling) KV cache.

    q: (B, H, hd) — one new token per sequence.
    k_cache/v_cache: (B, W, KV, hd) where W = max_seq (full cache) or the
    sliding-window size (rolling cache).
    cache_positions: (B, W) int32 — absolute position stored in each slot
    (-1 = empty). pos: (B,) int32 — the query token's absolute position.
    """
    B, W, KV, hd = k_cache.shape
    H = q.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bmkd->bkgm", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / math.sqrt(hd)
    s = _softcap(s, logit_softcap)
    valid = (cache_positions >= 0) & (cache_positions <= pos[:, None])
    if window is not None:
        valid &= cache_positions > (pos[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgm,bmkd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)
