"""Shared LM building blocks: rotary embeddings (incl. multimodal M-RoPE),
norm dispatch, token/positional embeddings, depthwise causal conv."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.layers import nonparametric_layernorm


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def norm_init(cfg: ModelConfig, dim: int):
    if cfg.norm == "nonparametric":
        return jnp.zeros((0,), jnp.float32)  # placeholder leaf (no params)
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((dim,), jnp.float32), "bias": jnp.zeros((dim,), jnp.float32)}
    return {"scale": jnp.ones((dim,), jnp.float32)}  # rmsnorm


def norm_apply(cfg: ModelConfig, p, x):
    dtype = x.dtype
    if cfg.norm == "nonparametric":
        return nonparametric_layernorm(x).astype(dtype)
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        return (((xf - mean) * jax.lax.rsqrt(var + 1e-5)) * p["scale"] + p["bias"]).astype(dtype)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"]).astype(dtype)


def rms_head_norm(scale, x):
    """qk-norm (qwen3): RMSNorm over head_dim with a learned (head_dim,) scale."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + 1e-6) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, position_ids, theta: float, sections):
    """Qwen2-VL multimodal RoPE. position_ids: (3, ..., S) for (t, h, w);
    ``sections`` split hd/2 frequency slots across the three axes."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    # Per-frequency-slot position row: slot s uses axis a(s).
    sec = jnp.asarray(sections)
    axis_of_slot = jnp.repeat(jnp.arange(3), sec, total_repeat_length=hd // 2)
    # positions: (3, ..., S) -> (..., S, hd/2) selecting the right axis per slot
    pos = jnp.moveaxis(position_ids, 0, -1).astype(jnp.float32)  # (..., S, 3)
    pos_per_slot = jnp.take(pos, axis_of_slot, axis=-1)  # (..., S, hd/2)
    angles = pos_per_slot * freqs
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def position_encode(cfg: ModelConfig, x, positions):
    """Dispatch q/k position encoding. positions: (…, S) int or (3, …, S)
    for M-RoPE."""
    if cfg.mrope:
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return apply_rope(x, positions, cfg.rope_theta)


def sinusoidal_positions(seq_len: int, dim: int) -> jax.Array:
    """Whisper-style fixed sinusoidal table (encoder)."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    div = jnp.exp(-math.log(10_000.0) * jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    tab = jnp.zeros((seq_len, dim), jnp.float32)
    tab = tab.at[:, 0::2].set(jnp.sin(pos * div))
    tab = tab.at[:, 1::2].set(jnp.cos(pos * div))
    return tab


# ---------------------------------------------------------------------------
# depthwise causal conv (mamba front)
# ---------------------------------------------------------------------------


def causal_depthwise_conv(u, w, b):
    """u: (B, S, C); w: (C, K); b: (C,). Causal depthwise 1-D conv."""
    k = w.shape[-1]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad,
        w.T[:, None, :],  # (K, 1, C) -> spec below maps to depthwise
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=u.shape[-1],
    )
    return out + b


def conv_step(u_t, conv_state, w, b):
    """One decode step of the causal depthwise conv.

    u_t: (B, C) new input; conv_state: (B, K-1, C) previous inputs.
    Returns (y_t (B, C), new_state)."""
    window = jnp.concatenate([conv_state, u_t[:, None, :]], axis=1)  # (B, K, C)
    y = jnp.einsum("bkc,ck->bc", window, w) + b
    return y, window[:, 1:, :]
