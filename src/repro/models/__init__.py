"""LM model zoo: one unified interface over the 10 assigned architectures."""
from repro.models.lm import (
    decode_step,
    init_cache,
    init_params,
    prefill,
    train_loss,
)

__all__ = ["init_params", "train_loss", "prefill", "decode_step", "init_cache"]
