"""Unified LM interface over all assigned architectures.

Public surface (all pure functions, shard-agnostic — sharding is applied by
the launchers via in_shardings/out_shardings + sharding/specs.py):

    init_params(key, cfg)                     -> params
    train_loss(params, batch, cfg, dp_groups) -> (loss, metrics)
    prefill(params, batch, cfg, dp_groups)    -> (cache, last_logits)
    decode_step(params, cache, batch, cfg)    -> (cache, logits)
    init_cache(cfg, batch, max_seq)           -> cache pytree

Layers are scan-stacked; ``cfg.remat`` wraps the scan body. Families:
dense (olmo/qwen3/mistral-large/llama3), moe (mixtral), ssm (falcon-mamba),
hybrid (hymba), vlm (qwen2-vl backbone; stub patch embeddings in),
audio (whisper enc-dec; stub frame embeddings in).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.common import norm_apply, norm_init, sinusoidal_positions
from repro.models.ssm import ssm_state_shapes
from repro.nn.module import normal_init, split_keys
from repro.sharding.ctx import constrain


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig):
    dtype = _dtype(cfg)
    keys = split_keys(key, 8)
    params = {}
    params["embed"] = normal_init(keys[0], (cfg.padded_vocab, cfg.d_model),
                                  stddev=0.02, dtype=dtype)
    if cfg.encoder_decoder:
        enc_keys = jnp.stack(split_keys(keys[1], cfg.num_encoder_layers))
        dec_keys = jnp.stack(split_keys(keys[2], cfg.num_layers))
        params["enc_layers"] = jax.vmap(
            lambda k: L.enc_layer_init(k, cfg, dtype))(enc_keys)
        params["layers"] = jax.vmap(
            lambda k: L.dec_layer_init(k, cfg, dtype))(dec_keys)
        params["enc_norm"] = norm_init(cfg, cfg.d_model)
        params["dec_pos"] = normal_init(keys[3], (32_768, cfg.d_model),
                                        stddev=0.01, dtype=dtype)
    else:
        lkeys = jnp.stack(split_keys(keys[1], cfg.num_layers))
        params["layers"] = jax.vmap(lambda k: L.layer_init(k, cfg, dtype))(lkeys)
    params["final_norm"] = norm_init(cfg, cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(keys[4], (cfg.d_model, cfg.padded_vocab),
                                        stddev=0.02, dtype=dtype)
    return params


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------


def _default_positions(cfg: ModelConfig, batch, b, s):
    if "positions" in batch:
        return batch["positions"]
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))
    if cfg.mrope:
        pos = jnp.broadcast_to(pos[None], (3, b, s))
    return pos


def _embed_in(params, cfg: ModelConfig, batch):
    if "embeds" in batch:
        x = batch["embeds"].astype(_dtype(cfg))
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    return constrain(x, "residual")


def _logits(params, cfg: ModelConfig, x):
    x = norm_apply(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("...d,dv->...v", x.astype(jnp.float32),
                        head.astype(jnp.float32))
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e9, logits)
    return constrain(logits, "logits")


def _run_layers(params, cfg: ModelConfig, x, positions, dp_groups):
    """Scan the decoder stack.

    Returns (x, kvs, ssm_states, aux) — per-layer outputs stacked (L, ...).
    Unused outputs (e.g. kvs during training) are DCE'd by XLA."""

    def block(carry, p_layer):
        carry = constrain(carry, "residual")
        y, kv, ssm_state, aux = L.layer_forward(p_layer, carry, positions, cfg, dp_groups)
        return constrain(y, "residual"), (kv, ssm_state, aux)

    body = _remat(block, cfg)
    if cfg.scan_layers:
        x, (kvs, ssm_states, auxs) = jax.lax.scan(body, x, params["layers"])
        return x, kvs, ssm_states, jnp.mean(auxs)
    outs = []
    n = cfg.num_layers
    for i in range(n):
        p_layer = jax.tree.map(lambda a: a[i], params["layers"])
        x, out = body(x, p_layer)
        outs.append(out)
    stack = lambda *xs: jnp.stack(xs)
    kvs = jax.tree.map(stack, *[o[0] for o in outs]) if outs[0][0] is not None else None
    ssm_states = jax.tree.map(stack, *[o[1] for o in outs]) if outs[0][1] is not None else None
    aux = jnp.mean(jnp.stack([o[2] for o in outs]))
    return x, kvs, ssm_states, aux


def _whisper_encode(params, cfg: ModelConfig, enc_embeds):
    b, s, _ = enc_embeds.shape
    x = enc_embeds.astype(_dtype(cfg))
    x = x + sinusoidal_positions(s, cfg.d_model).astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))

    def block(carry, p_layer):
        return L.enc_layer_forward(p_layer, carry, positions, cfg), None

    body = _remat(block, cfg)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
    else:
        for i in range(cfg.num_encoder_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params["enc_layers"]))
    return norm_apply(cfg, params["enc_norm"], x)


def _whisper_decode_stack(params, cfg: ModelConfig, tokens, enc_out):
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + params["dec_pos"][:s][None]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))

    def block(carry, p_layer):
        y, kv = L.dec_layer_forward(p_layer, carry, enc_out, positions, cfg)
        return y, kv

    body = _remat(block, cfg)
    if cfg.scan_layers:
        x, kvs = jax.lax.scan(body, x, params["layers"])
        return x, kvs
    kv_list = []
    for i in range(cfg.num_layers):
        x, kv = body(x, jax.tree.map(lambda a: a[i], params["layers"]))
        kv_list.append(kv)
    kvs = jax.tree.map(lambda *xs: jnp.stack(xs), *kv_list)
    return x, kvs


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def train_loss(params, batch, cfg: ModelConfig, dp_groups: int = 1):
    """batch: tokens/embeds (+positions) and labels (B, S); -100 = masked."""
    labels = batch["labels"]
    if cfg.encoder_decoder:
        enc_out = _whisper_encode(params, cfg, batch["embeds"])
        x, _ = _whisper_decode_stack(params, cfg, batch["tokens"], enc_out)
        aux = jnp.zeros((), jnp.float32)
    else:
        x = _embed_in(params, cfg, batch)
        b, s = x.shape[0], x.shape[1]
        positions = _default_positions(cfg, batch, b, s)
        x, _, _, aux = _run_layers(params, cfg, x, positions, dp_groups)
    logits = _logits(params, cfg, x)
    # Shard-friendly cross entropy: every vocab-axis op is a reduction or
    # elementwise, so a vocab-TP-sharded logits tensor never gets gathered
    # (only (B, S)-sized partial-reduce all-reduces cross chips).
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    safe_labels = jnp.maximum(labels, 0)
    label_logit = jnp.sum(
        jnp.where(vocab_iota == safe_labels[..., None], shifted, 0.0), axis=-1)
    nll = lse - label_logit
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    total = loss + 0.01 * aux
    metrics = {"loss": loss, "aux_loss": aux,
               "tokens": jnp.sum(mask)}
    return total, metrics


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------


def cache_window(cfg: ModelConfig, max_seq: int) -> int:
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, max_seq)
    return max_seq


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """Zero cache for ``batch`` sequences with capacity ``max_seq``."""
    dtype = _dtype(cfg)
    cache = {"pos": jnp.zeros((batch,), jnp.int32)}
    lcache = {}
    if cfg.family != "ssm":
        w = cache_window(cfg, max_seq)
        kvd = (cfg.num_layers, batch, w, cfg.num_kv_heads, cfg.head_dim)
        lcache["k"] = jnp.zeros(kvd, dtype)
        lcache["v"] = jnp.zeros(kvd, dtype)
        cache["slot_pos"] = jnp.full((batch, w), -1, jnp.int32)
    if cfg.family in ("ssm", "hybrid"):
        shapes = ssm_state_shapes(cfg, batch)
        lcache["h"] = jnp.zeros((cfg.num_layers,) + shapes["h"], jnp.float32)
        lcache["conv"] = jnp.zeros((cfg.num_layers,) + shapes["conv"], jnp.float32)
    cache["layers"] = lcache
    if cfg.encoder_decoder:
        cache["enc_out"] = jnp.zeros((batch, cfg.encoder_len, cfg.d_model), dtype)
    return cache


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------


def prefill(params, batch, cfg: ModelConfig, dp_groups: int = 1,
            max_seq: int | None = None):
    """Process the full prompt; return (cache, last-token logits)."""
    if cfg.encoder_decoder:
        enc_out = _whisper_encode(params, cfg, batch["embeds"])
        tokens = batch["tokens"]
        b, s = tokens.shape
        x, kvs = _whisper_decode_stack(params, cfg, tokens, enc_out)
        cache = init_cache(cfg, b, max_seq or s)
        cache["enc_out"] = enc_out[:, :cfg.encoder_len]
        kvs_dict = {"k": kvs[0], "v": kvs[1]}
        cache = _fill_kv(cache, kvs_dict, cfg, s)
        cache["pos"] = jnp.full((b,), s, jnp.int32)
        return cache, _logits(params, cfg, x[:, -1])

    x = _embed_in(params, cfg, batch)
    b, s = x.shape[0], x.shape[1]
    positions = _default_positions(cfg, batch, b, s)
    x, kvs, ssm_states, _ = _run_layers(params, cfg, x, positions, dp_groups)
    cache = init_cache(cfg, b, max_seq or s)
    if cfg.family != "ssm" and kvs is not None:
        cache = _fill_kv(cache, {"k": kvs[0], "v": kvs[1]}, cfg, s)
    if cfg.family in ("ssm", "hybrid"):
        cache["layers"]["h"] = ssm_states["h"]
        cache["layers"]["conv"] = ssm_states["conv"]
    cache["pos"] = jnp.full((b,), s, jnp.int32)
    return cache, _logits(params, cfg, x[:, -1])


def _fill_kv(cache, kvs, cfg: ModelConfig, s: int):
    """Place prefill K/V (L, B, S, KV, hd) into the (rolling) cache."""
    w = cache["layers"]["k"].shape[2]
    if s <= w:
        k = jnp.pad(kvs["k"], ((0, 0), (0, 0), (0, w - s), (0, 0), (0, 0)))
        v = jnp.pad(kvs["v"], ((0, 0), (0, 0), (0, w - s), (0, 0), (0, 0)))
        slot_pos = jnp.concatenate(
            [jnp.arange(s, dtype=jnp.int32),
             jnp.full((w - s,), -1, jnp.int32)])
    else:
        # keep the last w positions, stored at their rolling slots p % w
        tail = jnp.arange(s - w, s, dtype=jnp.int32)
        slots = tail % w  # a static permutation of [0, w)
        inv = jnp.zeros((w,), jnp.int32).at[slots].set(jnp.arange(w, dtype=jnp.int32))
        k = jnp.take(kvs["k"][:, :, s - w:], inv, axis=2)
        v = jnp.take(kvs["v"][:, :, s - w:], inv, axis=2)
        slot_pos = jnp.zeros((w,), jnp.int32).at[slots].set(tail)
    b = kvs["k"].shape[1]
    cache["layers"]["k"] = k
    cache["layers"]["v"] = v
    cache["slot_pos"] = jnp.broadcast_to(slot_pos[None], (b, w))
    return cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def decode_step(params, cache, batch, cfg: ModelConfig, dp_groups: int = 1):
    """One token for every sequence. batch: {"token": (B,)} (or "embed");
    optional "positions" for M-RoPE: (3, B). Returns (cache, logits (B, V))."""
    if "embed" in batch:
        x = batch["embed"].astype(_dtype(cfg))
    else:
        x = jnp.take(params["embed"], batch["token"], axis=0)
    x = constrain(x, "decode_x")
    b = x.shape[0]
    pos = cache["pos"]  # (B,)
    if cfg.mrope:
        positions = batch.get("positions",
                              jnp.broadcast_to(pos[None], (3, b)))
    else:
        positions = pos
    if cfg.encoder_decoder:
        x = x + jnp.take(params["dec_pos"], jnp.minimum(pos, params["dec_pos"].shape[0] - 1), axis=0)

    slot_pos = cache.get("slot_pos")
    new_slot_pos = slot_pos
    if slot_pos is not None:
        w = slot_pos.shape[1]
        slot = pos % w
        onehot = jax.nn.one_hot(slot, w, dtype=jnp.int32)
        new_slot_pos = slot_pos * (1 - onehot) + pos[:, None] * onehot

    def block(carry, xs):
        p_layer, layer_cache = xs
        carry = constrain(carry, "decode_x")
        if cfg.encoder_decoder:
            y, new_lc = L.dec_layer_decode(
                p_layer, carry, cache["enc_out"], layer_cache, new_slot_pos,
                positions if not cfg.mrope else pos, cfg)
        else:
            y, new_lc = L.layer_decode(
                p_layer, carry, layer_cache, new_slot_pos, positions, cfg,
                dp_groups)
        return constrain(y, "decode_x"), new_lc

    if cfg.scan_layers:
        x, new_layer_caches = jax.lax.scan(
            block, x, (params["layers"], cache["layers"]))
    else:
        lc_list = []
        for i in range(cfg.num_layers):
            xs_i = jax.tree.map(lambda a: a[i], (params["layers"], cache["layers"]))
            x, lc = block(x, xs_i)
            lc_list.append(lc)
        new_layer_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *lc_list)
    logits = _logits(params, cfg, x)
    new_cache = dict(cache)
    new_cache["layers"] = new_layer_caches
    new_cache["pos"] = pos + 1
    if slot_pos is not None:
        new_cache["slot_pos"] = new_slot_pos
    return new_cache, logits
