"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attn+mamba heads [arXiv:2411.13676].

Notes: 25 heads / 5 KV heads do not divide the 16-way TP axis; attention
projections stay 2-D (D, H*hd) so the flattened head axis (1600) shards.
Hymba's meta-tokens are omitted (backbone-only per assignment); the
attention branch uses a 2048-token sliding window (hybrid family ->
long_500k eligible regardless). d_inner = 2*1600 = 3200 (16 | 3200).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    hybrid=True,
    ssm_state=16,
    sliding_window=2048,
    norm="rmsnorm",
    act="silu",
    shard_heads=False,  # 25 heads don't divide TP=16 (see ModelConfig)
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=64,
        num_heads=5,
        num_kv_heads=5,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        sliding_window=16,
        ssm_state=4,
        ssm_conv=4,
        dtype="float32",
        attn_chunk=16,
        remat="none",
    )
