"""llama3-405b [dense]: 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 — GQA, 128k vocab [arXiv:2407.21783].

The largest assigned arch: Adafactor, 8 microbatches, sequence-sharded
activations; see EXPERIMENTS.md §Dry-run for the per-device memory budget.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=500_000.0,
    optimizer="adafactor",
    num_microbatches=8,
    seq_shard_activations=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        dtype="float32",
        attn_chunk=16,
        remat="none",
        num_microbatches=1,
        seq_shard_activations=False,
    )
