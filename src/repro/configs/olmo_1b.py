"""olmo-1b [dense]: 16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304
— non-parametric LayerNorm [arXiv:2402.00838]."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    norm="nonparametric",
    act="silu",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        dtype="float32",
        attn_chunk=16,
        remat="none",
    )
