"""Architecture registry: ``--arch <id>`` resolves here.

Each assigned architecture has its own module with CONFIG (the full,
paper-exact configuration) and ``reduced()`` (a small same-family config for
CPU smoke tests). The paper's own model (the CoRaiS policy network) lives in
``repro.configs.corais``.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    ModelConfig,
    ShapeConfig,
    shape_applicable,
)

_MODULES = {
    "hymba-1.5b": "repro.configs.hymba_1p5b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "olmo-1b": "repro.configs.olmo_1b",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "llama3-405b": "repro.configs.llama3_405b",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
    "whisper-tiny": "repro.configs.whisper_tiny",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_reduced_config(arch: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch]).reduced()


__all__ = [
    "ARCH_IDS", "get_config", "get_reduced_config", "ModelConfig",
    "ShapeConfig", "SHAPES", "ALL_SHAPES", "TRAIN_4K", "PREFILL_32K",
    "DECODE_32K", "LONG_500K", "shape_applicable",
]
