"""whisper-tiny [audio]: 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865 —
encoder-decoder, conv frontend stub [arXiv:2212.04356].

The audio frontend is a stub per assignment: ``input_specs()`` provides
precomputed frame embeddings (B, S_enc, 384). prefill_* cells run the
encoder over S_enc frames + the decoder prompt; decode cells step the
decoder self-attention cache and cross-attend to ``encoder_len`` frames.
Absolute (sinusoidal/learned) positions; LayerNorm; GELU MLP; no RoPE.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,
    num_encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    norm="layernorm",
    act="gelu",
    encoder_decoder=True,
    cross_attention=True,
    encoder_len=1500,
    shard_heads=False,  # 6 heads don't divide TP=16 (see ModelConfig)
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        num_encoder_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        encoder_len=32,
        dtype="float32",
        attn_chunk=16,
        remat="none",
    )
