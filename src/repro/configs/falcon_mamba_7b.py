"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16 — mamba-1 architecture [arXiv:2410.05355].

d_ff=0 per assignment: each layer is a single mamba block (no separate MLP).
O(1) decode state makes every long-context cell trivial by construction —
that is the point of the architecture (DESIGN.md shape-cell notes).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    head_dim=1,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    ssm_expand=2,
    norm="rmsnorm",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=64,
        vocab_size=256,
        ssm_state=4,
        dtype="float32",
        remat="none",
    )
