"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, SWA [arXiv:2401.04088].

Adafactor + microbatching keep single-pod (256-chip) training in HBM.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    num_experts=8,
    experts_per_token=2,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    optimizer="adafactor",
    num_microbatches=4,
    seq_shard_activations=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        num_experts=4,
        capacity_factor=4.0,
        sliding_window=16,
        dtype="float32",
        attn_chunk=16,
        remat="none",
        num_microbatches=1,
        seq_shard_activations=False,
    )
