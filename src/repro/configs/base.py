"""Architecture + run-shape configuration dataclasses.

Every assigned architecture is a :class:`ModelConfig` in its own module under
``repro.configs``; the registry in ``repro.configs.__init__`` resolves
``--arch <id>``. Shape cells (train_4k / prefill_32k / decode_32k /
long_500k) are :class:`ShapeConfig` constants shared by all LM archs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # normalization / activation / attention details
    norm: str = "rmsnorm"            # rmsnorm | layernorm | nonparametric
    act: str = "silu"                # silu (SwiGLU) | gelu (plain MLP)
    rope_theta: float = 10_000.0
    qk_norm: bool = False            # qwen3
    sliding_window: Optional[int] = None  # mixtral/hymba SWA
    tie_embeddings: bool = False
    attn_logit_softcap: float = 0.0

    # MoE (mixtral)
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25

    # SSM (falcon-mamba / hymba)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0             # 0 -> ceil(d_model / 16)

    # hybrid (hymba): attention + SSM heads in parallel per layer
    hybrid: bool = False

    # encoder-decoder (whisper): encoder layer count; frontend is a stub
    encoder_decoder: bool = False
    num_encoder_layers: int = 0
    cross_attention: bool = False
    encoder_len: int = 1500          # cross-attn source length for decode cells

    # VLM backbone (qwen2-vl): multimodal RoPE; frontend is a stub
    mrope: bool = False
    mrope_sections: Tuple[int, ...] = ()
    embed_input: bool = True         # False -> input_specs provides embeddings

    # numerics / execution
    dtype: str = "bfloat16"
    remat: str = "full"              # none | full | dots
    scan_layers: bool = True
    num_microbatches: int = 1
    seq_shard_activations: bool = False
    optimizer: str = "adam"          # adam | adafactor
    use_pallas_kernels: bool = False  # TPU target path (tests use interpret)
    attn_chunk: int = 512            # pure-jnp blocked-attention q-chunk
    # Unroll flags exist for the dry-run cost probes: XLA's HloCostAnalysis
    # counts a while-loop body once, so FLOP/byte/collective accounting uses
    # small unrolled probe configs (see launch/dryrun.py).
    attn_unroll: bool = False
    ssm_chunk: int = 256
    ssm_unroll: bool = False
    # False for archs whose head count does not divide the TP axis (hymba's
    # 25H/5KV, whisper's 6H): replicating attention weights avoids GSPMD
    # "involuntary full rematerialization" on the (B,S,H,hd) reshapes, which
    # otherwise explodes compile time and wire bytes. MLP/SSM stay TP-sharded.
    shard_heads: bool = True
    # --- beyond-paper perf knobs (EXPERIMENTS.md §Perf variants) ---
    # explicit shard_map flash-decode over the seq-sharded KV cache instead
    # of GSPMD auto-partitioned softmax (collective-bound decode cells)
    decode_flash_shardmap: bool = False
    # dtype of the selective-scan discretized tensors (memory-bound ssm)
    ssm_scan_dtype: str = "float32"
    # "tp": batch over data(+pod), TP over model (default).
    # "dp": every mesh axis is data parallelism (small models; §Perf)
    layout: str = "tp"
    # dense-expert evaluation for small token counts (decode): no dispatch
    # machinery / capacity padding; k/E of FLOPs useful (§Perf variant)
    moe_dense_decode: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        if self.ssm_state and self.ssm_dt_rank == 0:
            object.__setattr__(self, "ssm_dt_rank", -(-self.d_model // 16))

    @property
    def padded_vocab(self) -> int:
        """Vocab padded so the embedding/lm_head shard evenly on any mesh
        axis up to 256; logits beyond vocab_size are masked in the loss."""
        return round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (see DESIGN.md shape-cell skips)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(applicable, reason-if-not). Encodes the DESIGN.md skip rules."""
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False, "pure full-attention arch: no sub-quadratic mode at 500k"
    return True, ""
