"""mistral-large-123b [dense]: 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768 [hf:mistralai/Mistral-Large-Instruct-2407]."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    rope_theta=1_000_000.0,
    optimizer="adafactor",
    num_microbatches=4,
    seq_shard_activations=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        dtype="float32",
        attn_chunk=16,
        remat="none",
        num_microbatches=1,
        seq_shard_activations=False,
    )
