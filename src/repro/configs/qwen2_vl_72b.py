"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution [arXiv:2409.12191].

Backbone only per assignment: the vision frontend is a stub —
``input_specs()`` provides precomputed patch embeddings (B, S, D) plus
(3, B, S) M-RoPE position ids for train/prefill; decode embeds generated
text tokens through the vocab table.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    mrope=True,
    mrope_sections=(16, 24, 24),
    embed_input=False,
    rope_theta=1_000_000.0,
    optimizer="adafactor",
    num_microbatches=4,
    seq_shard_activations=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        mrope_sections=(2, 3, 3),
        d_ff=128,
        vocab_size=256,
        dtype="float32",
        attn_chunk=16,
        remat="none",
        num_microbatches=1,
        seq_shard_activations=False,
    )
