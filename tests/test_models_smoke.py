"""Per-architecture smoke tests (task spec deliverable f).

Each assigned arch instantiates its REDUCED same-family config and runs one
train step (grad + finite check), prefill, and decode on CPU, asserting
output shapes + no NaNs. Decode consistency (prefill+step == full forward)
is the strongest invariant: it exercises rolling caches, slot bookkeeping,
SSM state handoff, MoE dispatch and the enc-dec path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.lm as lm_mod
from repro.configs import ARCH_IDS, get_config, get_reduced_config
from repro.data import make_batch, make_decode_batch
from repro.models import decode_step, init_params, prefill, train_loss


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    expected = {
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, (arch, got, expected)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, rng):
    cfg = get_reduced_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = jax.tree.map(jnp.asarray, make_batch(rng, cfg, 2, 32, kind="train"))
    (loss, metrics), grads = jax.value_and_grad(
        train_loss, has_aux=True)(params, batch, cfg, 1)
    assert np.isfinite(float(loss))
    assert float(metrics["tokens"]) == 64
    gsum = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gsum) and gsum > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_consistency(arch, rng):
    cfg = get_reduced_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S, extra = 2, 32, 3
    fb = make_batch(rng, cfg, B, S + extra, kind="prefill")
    if cfg.encoder_decoder:
        fb["embeds"] = fb["embeds"][:, :cfg.encoder_len]
    fb = jax.tree.map(jnp.asarray, fb)

    if cfg.encoder_decoder:
        enc = lm_mod._whisper_encode(params, cfg, fb["embeds"])
        x, _ = lm_mod._whisper_decode_stack(params, cfg, fb["tokens"], enc)
    else:
        x = lm_mod._embed_in(params, cfg, fb)
        pos = lm_mod._default_positions(cfg, fb, B, S + extra)
        x, _, _, _ = lm_mod._run_layers(params, cfg, x, pos, 1)
    ref = lm_mod._logits(params, cfg, x)

    pb = dict(fb)
    if "positions" in pb:
        pb["positions"] = fb["positions"][..., :S]
    if "tokens" in pb:
        pb["tokens"] = fb["tokens"][:, :S]
    if "embeds" in pb and not cfg.encoder_decoder:
        pb["embeds"] = fb["embeds"][:, :S]
    cache, logits = prefill(params, pb, cfg, 1, max_seq=S + extra)
    assert logits.shape == (B, cfg.padded_vocab)
    errs = [float(jnp.abs(logits - ref[:, S - 1]).max())]
    for t in range(extra):
        db = {}
        if cfg.encoder_decoder or cfg.embed_input:
            db["token"] = fb["tokens"][:, S + t]
        else:
            db["embed"] = fb["embeds"][:, S + t]
        if cfg.mrope:
            db["positions"] = fb["positions"][:, :, S + t]
        cache, lg = decode_step(params, cache, db, cfg, 1)
        errs.append(float(jnp.abs(lg - ref[:, S + t]).max()))
    assert max(errs) < 2e-2, (arch, errs)


def test_sliding_window_cache_is_window_sized():
    cfg = get_reduced_config("mixtral-8x7b")  # window 16
    from repro.models import init_cache
    cache = init_cache(cfg, 2, 64)
    assert cache["layers"]["k"].shape[2] == 16  # rolling window, not 64


def test_ssm_has_o1_decode_state():
    cfg = get_reduced_config("falcon-mamba-7b")
    from repro.models import init_cache
    cache = init_cache(cfg, 2, 10_000)
    assert "k" not in cache["layers"]  # no KV cache at all
    assert cache["layers"]["h"].shape == (2, 2, cfg.d_inner, cfg.ssm_state)
