"""Data pipeline determinism/resume + continuous batching backend."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.data import SyntheticTokens, input_specs, make_batch
from repro.configs.base import SHAPES


def test_pipeline_deterministic_and_resumable():
    p1 = SyntheticTokens(vocab_size=100, batch=2, seq=8, seed=7)
    first = [next(p1) for _ in range(3)]
    state = p1.state_dict()
    later = [next(p1) for _ in range(2)]
    p2 = SyntheticTokens(vocab_size=100, batch=2, seq=8, seed=0)
    p2.load_state_dict(state)
    resumed = [next(p2) for _ in range(2)]
    for a, b in zip(later, resumed):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(first[0]["tokens"][:, 1:],
                                  first[0]["labels"][:, :-1])


@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_no_allocation(shape_name):
    cfg = get_reduced_config("olmo-1b")
    io = input_specs(cfg, SHAPES[shape_name])
    for leaf in jax.tree.leaves(io):
        assert isinstance(leaf, jax.ShapeDtypeStruct)  # never a real array


def test_make_batch_matches_specs():
    cfg = get_reduced_config("qwen2-vl-72b")
    b = make_batch(np.random.default_rng(0), cfg, 2, 16, kind="train")
    assert set(b) == {"embeds", "positions", "labels"}
    assert b["positions"].shape == (3, 2, 16)


def test_continuous_batching_backend():
    from repro.models import init_params
    from repro.serving.batching import LMEdgeBackend
    cfg = get_reduced_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    be = LMEdgeBackend(cfg, params, lanes=2, max_seq=64)
    for rid, (plen, glen) in enumerate([(8, 4), (12, 3), (5, 6), (20, 2)]):
        be.submit(rid, plen, glen)
    be.drain()
    assert set(be.finished) == {0, 1, 2, 3}
    assert be.finished[0] == 4 and be.finished[2] == 6
    # phi was fitted from measured prefill latencies
    assert len(be.phi._xs) == 4
