"""Fleet-sharded rollout equivalence on a real (host-forced) 8-device mesh:

the same batch of instances through the single-device vmap engine and
through ``make_fleet_rollout`` over an 8-shard ("fleet",) mesh must
produce the same summary (counts/histograms exact, float reductions to
1e-5), including the Zipf-displaced cross-shard accounting, and a 2-shard
subset mesh (the scaling-curve configuration) must agree too.

Runs in a subprocess because the device count must be forced before jax
initializes (the main test process keeps the real single-device view)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_fleet_multidevice_equivalence():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["TF_CPP_MIN_LOG_LEVEL"] = "3"
    proc = subprocess.run(
        [sys.executable, os.path.join(os.path.dirname(__file__),
                                      "fleet_child.py")],
        capture_output=True, text=True, timeout=600, env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "FLEET_MULTIDEVICE_OK" in proc.stdout, proc.stdout
