"""Edge–cloud tier, service caches, and schema-v3 request fields.

The tentpole contract: with a CloudSpec/CacheSpec pair from the scenario
registry, the event-driven oracle and the batched engine simulate the
*identical* tiered cluster — cache hits/misses, cloud offloads, and
deadline-miss counts agree exactly, per-request finish times to 1e-4.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import (EDGE_FEATURES, REQ_FEATURES,
                               TIER_EDGE_FEATURES, TIER_REQ_FEATURES,
                               PolicyConfig, corais_apply, corais_init,
                               edge_feature_dim, req_feature_dim)
from repro.serving import engine
from repro.serving.cache import CacheSpec, HostCache, cache_commit, initial_cache
from repro.serving.simulator import MultiEdgeSim, SimConfig
from repro.workloads.batch import materialize_rounds
from repro.workloads.scenarios import scenario, scenario_cloud_spec

DT = 0.25

CLOUD_CASES = [
    ("cloud-cache-churn", 4, 16, 3),
    ("cloud-burst-offload", 5, 20, 7),
]


class _ScriptedController:
    """Oracle twin of the engine-side scripted hash over N = Q + 1 nodes."""

    last_decision_time = 0.0

    def __init__(self, num_nodes):
        self.n = num_nodes

    def schedule(self, edges, pending, w, ct):
        return [(r, (r.rid * 7 + 3) % self.n) for r in pending]


def _run_pair(name, q, rounds, seed):
    cloud, cache = scenario_cloud_spec(name)
    assert cloud is not None and cache is not None
    n = q + 1
    cfg = engine.EngineConfig(num_edges=q, num_rounds=rounds,
                              round_interval=DT, max_per_round=64,
                              cloud=cloud, cache=cache)
    arr = materialize_rounds(scenario(name), q, rounds, DT, seed=seed,
                             max_per_round=64)

    def assign(key, inst):
        return ((inst["req_rid"] * 7 + 3) % n).astype(jnp.int32)

    run = engine.make_rollout(cfg, assign)
    final, _ = run(engine.init_state(cfg, seed=seed), arr,
                   jax.random.PRNGKey(0))
    final = jax.device_get(final)

    sim = MultiEdgeSim(
        SimConfig(num_edges=q, round_interval=DT, seed=seed, exec_noise=0.0,
                  phi_oracle=True, cloud=cloud, cache=cache),
        _ScriptedController(n))
    m = sim.drive(scenario(name), until=rounds * DT, run_until=1e5, seed=seed)
    return cfg, arr, final, sim, m


@pytest.mark.parametrize("name,q,rounds,seed", CLOUD_CASES)
def test_cloud_equivalence_with_event_sim(name, q, rounds, seed):
    cfg, arr, final, sim, m = _run_pair(name, q, rounds, seed)
    s = engine.summarize(final)

    # everything drains in both engines
    assert m["completed"] == m["submitted"] == s["completed"] > 0
    assert s["stranded_requests"] == 0

    # tier/cache/deadline counters agree exactly
    for k in ("cache_hits", "cache_misses", "cloud_completed",
              "deadline_total", "deadline_missed", "transferred",
              "completed"):
        assert s[k] == m[k], (k, s[k], m[k])
    assert s["cache_misses"] > 0 and s["cache_hits"] > 0
    assert s["cloud_completed"] > 0          # the hash does offload
    assert s["deadline_total"] == s["completed"]  # every arrival has one

    # per-request finish times match to the acceptance tolerance
    mask = np.asarray(arr["mask"]).ravel()
    rids = np.asarray(arr["rid"]).ravel()[mask]
    committed = final["slot_edge"].ravel() >= 0
    fin_engine = final["slot_finish"].ravel()[committed]
    oracle = {r.rid: r.finish_time for e in sim.edges for r in e.completed}
    fin_oracle = np.array([oracle[r] for r in rids])
    np.testing.assert_allclose(fin_engine, fin_oracle, rtol=1e-5, atol=1e-4)

    # deadline/cache fracs derive from the same counts
    assert s["deadline_miss_frac"] == pytest.approx(m["deadline_miss_frac"])
    assert s["cache_hit_rate"] == pytest.approx(m["cache_hit_rate"])
    assert s["cloud_offload_frac"] == pytest.approx(m["cloud_offload_frac"])


@pytest.mark.parametrize("name,q,rounds,seed", CLOUD_CASES)
def test_unified_summary_schema(name, q, rounds, seed):
    """Satellite: every summary producer returns the one SUMMARY_KEYS
    schema — engine summarize, reduced partials, and the oracle (plus its
    decision_* extras) — so benchmarks never special-case the source."""
    cfg, arr, final, sim, m = _run_pair(name, q, rounds, seed)
    s = engine.summarize(final)
    p = engine.partials_to_summary(engine.summarize_partials(final))

    assert sorted(s) == sorted(engine.SUMMARY_KEYS)
    assert sorted(p) == sorted(engine.SUMMARY_KEYS)
    assert set(engine.SUMMARY_KEYS) <= set(m)  # oracle adds decision_*

    # the two engine-side producers agree on every exact (non-histogram) key
    for k in engine.SUMMARY_KEYS:
        if k in ("p50_response", "p95_response"):  # histogram estimates
            continue
        if isinstance(s[k], float):
            assert p[k] == pytest.approx(s[k], rel=1e-6), k
        else:
            assert p[k] == s[k], k


def test_summary_schema_zero_completions():
    cfg = engine.EngineConfig(num_edges=3, num_rounds=2, max_per_round=4)
    s = engine.summarize(engine.init_state(cfg, seed=0))
    assert sorted(s) == sorted(engine.SUMMARY_KEYS)
    assert s["completed"] == 0 and s["per_edge_completed"] == {}
    p = engine.partials_to_summary(
        engine.summarize_partials(engine.init_state(cfg, seed=0)))
    assert sorted(p) == sorted(engine.SUMMARY_KEYS)
    sim = MultiEdgeSim(SimConfig(num_edges=3), _ScriptedController(3))
    assert set(engine.SUMMARY_KEYS) <= set(sim.metrics())


def test_host_cache_matches_cache_commit():
    """FIFO cache-aside parity: random (node, service) access sequences
    produce identical hit patterns and final cache contents."""
    rng = np.random.default_rng(0)
    q, slots, services = 4, 3, 9
    spec = CacheSpec(slots=slots, miss_penalty=0.5, num_services=services)
    host = HostCache(q + 1, q, spec)
    cache = jnp.asarray(initial_cache(q + 1, q, spec))
    ptr = jnp.zeros(q + 1, jnp.int32)
    for _ in range(20):  # 20 rounds of 8 accesses
        nodes = rng.integers(0, q + 1, size=8)
        svcs = rng.integers(0, services, size=8)
        on = rng.random(8) < 0.9
        host_hits = [host.access(nd, sv) if o else False
                     for nd, sv, o in zip(nodes, svcs, on)]
        cache, ptr, hit = cache_commit(cache, ptr, jnp.asarray(nodes),
                                       jnp.asarray(svcs), jnp.asarray(on), q)
        assert np.asarray(hit).tolist() == host_hits
    np.testing.assert_array_equal(np.asarray(cache), host.cache)
    np.testing.assert_array_equal(np.asarray(ptr), host.ptr)
    assert host.hits > 0 and host.misses > 0


def test_cloud_always_hits_and_never_installs():
    q = 2
    spec = CacheSpec(slots=2, num_services=6, warm=False)
    host = HostCache(q + 1, q, spec)
    assert host.access(q, 5)          # cloud: hit with a cold cache
    assert (host.cache[q] == -1).all()  # and nothing installed
    assert not host.access(0, 5)      # edge: cold miss installs
    assert host.access(0, 5)


def test_second_same_service_miss_becomes_hit_in_round():
    """Two same-service dispatches to one cold edge in one round: the first
    misses and installs, the second hits — in both implementations."""
    q = 2
    spec = CacheSpec(slots=2, num_services=6, warm=False)
    host = HostCache(q + 1, q, spec)
    assert [host.access(1, 4), host.access(1, 4)] == [False, True]
    cache = jnp.asarray(initial_cache(q + 1, q, spec))
    ptr = jnp.zeros(q + 1, jnp.int32)
    _, _, hit = cache_commit(cache, ptr, jnp.asarray([1, 1]),
                             jnp.asarray([4, 4]), jnp.asarray([True, True]), q)
    assert np.asarray(hit).tolist() == [False, True]


def test_extend_cluster_with_cloud_row():
    from repro.serving.rounds import extend_cluster_with_cloud, sample_cluster
    from repro.serving.topology import CloudSpec
    base = sample_cluster(5, 4, 0.2, 1.0, seed=0)
    cloud = CloudSpec(wan_rtt=0.4, wan_dist=1.5, lanes=12, phi_a=0.2,
                      phi_b=0.02)
    ext = extend_cluster_with_cloud(base, cloud)
    assert ext.w.shape == (6, 6)
    np.testing.assert_array_equal(ext.w[:5, :5], base.w)
    assert (ext.w[:5, 5] == 1.5).all() and (ext.w[5, :5] == 1.5).all()
    assert ext.true_a[5] == 0.2 and ext.true_b[5] == 0.02
    assert ext.replicas[5] == 12


def test_flat_tier_state_unchanged_by_v3_fields():
    """Schema-v3 columns are inert without cloud/cache: a flat rollout's
    physics are identical to what the same seed produced before."""
    cfg = engine.EngineConfig(num_edges=4, num_rounds=8, max_per_round=16)
    arr = materialize_rounds(scenario("uniform_iid"), 4, 8, DT, seed=5,
                             max_per_round=16)

    def assign(key, inst):
        return ((inst["req_rid"] * 7 + 3) % 4).astype(jnp.int32)

    final, _ = engine.make_rollout(cfg, assign)(
        engine.init_state(cfg, seed=5), arr, jax.random.PRNGKey(0))
    s = engine.summarize(jax.device_get(final))
    assert s["cloud_completed"] == 0 and s["cache_hits"] == 0
    assert s["deadline_total"] == 0 and s["deadline_miss_frac"] == 0.0
    sim = MultiEdgeSim(SimConfig(num_edges=4, round_interval=DT, seed=5,
                                 exec_noise=0.0, phi_oracle=True),
                       _ScriptedController(4))
    m = sim.drive(scenario("uniform_iid"), until=8 * DT, run_until=1e5, seed=5)
    assert m["completed"] == s["completed"] > 0
    assert abs(m["mean_response"] - s["mean_response"]) < 1e-4


# -- policy tier features -----------------------------------------------------


def test_tier_feature_dims_and_forward():
    flat = PolicyConfig(d_model=32, num_heads=2, edge_layers=1,
                        request_layers=1, ff_hidden=32)
    tier = PolicyConfig(d_model=32, num_heads=2, edge_layers=1,
                        request_layers=1, ff_hidden=32, tier_features=True)
    assert edge_feature_dim(flat) == EDGE_FEATURES
    assert req_feature_dim(flat) == REQ_FEATURES
    assert edge_feature_dim(tier) == EDGE_FEATURES + TIER_EDGE_FEATURES
    assert req_feature_dim(tier) == REQ_FEATURES + TIER_REQ_FEATURES

    params, state = corais_init(jax.random.PRNGKey(0), tier)
    assert params["edge_proj"]["w"].shape[0] == EDGE_FEATURES + TIER_EDGE_FEATURES
    assert params["req_proj"]["w"].shape[0] == REQ_FEATURES + TIER_REQ_FEATURES

    # a v3 engine instance feeds the new features through the forward
    name, q, rounds, seed = CLOUD_CASES[0]
    cloud, cache = scenario_cloud_spec(name)
    cfg = engine.EngineConfig(num_edges=q, num_rounds=rounds,
                              round_interval=DT, max_per_round=64,
                              cloud=cloud, cache=cache)
    arr = materialize_rounds(scenario(name), q, rounds, DT, seed=seed,
                             max_per_round=64)
    st = engine.init_state(cfg, seed=seed)
    inst = engine.round_instance(
        jax.tree.map(jnp.asarray, st),
        {k: jnp.asarray(v[0]) for k, v in arr.items()}, cfg)
    for k in ("tier", "req_slack", "req_priority", "cache_frac",
              "req_cached"):
        assert k in inst, k
    lp, _ = corais_apply(params, state, inst, tier)
    assert lp.shape == (64, q + 1)
    assert bool(jnp.all(jnp.isfinite(lp[jnp.asarray(arr["mask"][0])])))

    # legacy instances (no tier keys) run with zero fallbacks
    legacy = {k: v for k, v in inst.items()
              if k not in ("tier", "req_slack", "req_priority",
                           "cache_frac", "req_cached")}
    lp2, _ = corais_apply(params, state, legacy, tier)
    assert lp2.shape == lp.shape


def test_deadline_slack_feature_is_capped():
    name, q, rounds, seed = CLOUD_CASES[0]
    cloud, cache = scenario_cloud_spec(name)
    cfg = engine.EngineConfig(num_edges=q, num_rounds=rounds,
                              round_interval=DT, max_per_round=64,
                              cloud=cloud, cache=cache)
    arr = materialize_rounds(scenario(name), q, rounds, DT, seed=seed,
                             max_per_round=64)
    st = engine.init_state(cfg, seed=seed)
    inst = engine.round_instance(
        jax.tree.map(jnp.asarray, st),
        {k: jnp.asarray(v[0]) for k, v in arr.items()}, cfg)
    slack = np.asarray(inst["req_slack"])
    assert (slack >= 0).all() and (slack <= engine.SLACK_CAP).all()
    mask = np.asarray(arr["mask"][0])
    assert (slack[mask] > 0).any()
