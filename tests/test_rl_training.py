"""S-sample REINFORCE (paper §IV-B): mechanics + learning signal."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import InstanceConfig, PolicyConfig, generate_batch
from repro.core.heuristics import solve_local, solve_random
from repro.core.objective import makespan_np
from repro.core.policy import corais_apply, corais_init
from repro.core.train import (RLConfig, TemporalRLConfig, greedy_eval,
                              make_train_step, temporal_train, train)
from repro.optim import AdamConfig, adam_init
from repro.serving.engine import EngineConfig


def _cfg(**kw):
    base = dict(
        policy=PolicyConfig(d_model=32, ff_hidden=64, edge_layers=2,
                            request_layers=1),
        instance=InstanceConfig(num_edges=3, num_requests=12, backlog_high=5),
        batch_size=16,
        num_samples=16,
        lr=3e-4,
        num_batches=5,
        seed=0,
    )
    base.update(kw)
    return RLConfig(**base)


def test_step_runs_and_is_finite():
    cfg = _cfg()
    params, state = corais_init(jax.random.PRNGKey(0), cfg.policy)
    opt = adam_init(params, AdamConfig(lr=cfg.lr))
    step, _ = make_train_step(cfg)
    rng = np.random.default_rng(0)
    batch = jax.tree.map(jnp.asarray,
                         generate_batch(rng, cfg.instance, cfg.batch_size))
    params, state, opt, metrics = step(params, state, opt, batch,
                                       jax.random.PRNGKey(1))
    for k, v in metrics.items():
        assert np.isfinite(float(v)), (k, v)
    assert float(metrics["cost_best"]) <= float(metrics["cost_mean"]) + 1e-6


def test_entropy_decreases_with_entropy_penalty_off():
    """With C2 high the policy stays stochastic; sanity on the knob."""
    cfg_high = _cfg(c2=50.0, num_batches=8)
    _, state_h, _, hist_h = train(cfg_high)
    cfg_low = _cfg(c2=0.0, num_batches=8)
    _, state_l, _, hist_l = train(cfg_low)
    assert hist_h[-1]["entropy"] >= hist_l[-1]["entropy"] - 1e-3


def test_temporal_step_runs_and_is_finite():
    """Temporal REINFORCE over batched engine rollouts: one update on a
    miniature scenario episode is finite and actually completes requests."""
    cfg = TemporalRLConfig(
        policy=PolicyConfig(d_model=32, ff_hidden=64, edge_layers=1,
                            request_layers=1),
        engine=EngineConfig(num_edges=3, num_rounds=4, max_per_round=8),
        scenario="uniform_iid",
        batch_size=4,
        lr=3e-4,
        num_batches=2,
        seed=0,
    )
    params, state, opt, hist = temporal_train(cfg)
    assert len(hist) == 2
    for row in hist:
        for k in ("loss", "grad_norm", "cost_mean", "entropy"):
            assert np.isfinite(row[k]), (k, row)
        assert row["completed"] > 0


@pytest.mark.slow
def test_policy_learns_to_beat_local():
    """The qualitative Table-II claim at miniature scale: a briefly trained
    CoRaiS beats Local and Random(1) on held-out instances."""
    cfg = _cfg(lr=1e-3, num_batches=60, batch_size=32, num_samples=16,
               instance=InstanceConfig(num_edges=3, num_requests=10,
                                       backlog_high=3))
    params, state, _, hist = train(cfg)
    rng = np.random.default_rng(123)
    eval_batch = generate_batch(rng, cfg.instance, 64)
    jb = jax.tree.map(jnp.asarray, eval_batch)
    policy_cost = float(greedy_eval(params, state, jb, cfg))
    local = np.mean([
        makespan_np(jax.tree.map(lambda x, i=i: np.asarray(x[i]), eval_batch),
                    solve_local(jax.tree.map(lambda x, i=i: np.asarray(x[i]),
                                             eval_batch)))
        for i in range(64)])
    assert policy_cost < local, (policy_cost, local)


def _temporal_cfg(**kw):
    base = dict(
        policy=PolicyConfig(d_model=32, ff_hidden=64, edge_layers=1,
                            request_layers=1),
        engine=EngineConfig(num_edges=3, num_rounds=4, max_per_round=8),
        scenario="uniform_iid",
        batch_size=4,
        lr=3e-4,
        num_batches=4,
        seed=0,
    )
    base.update(kw)
    return TemporalRLConfig(**base)


def _trees_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_temporal_epoch_path_runs_and_is_finite():
    """The scanned-epoch path (device-generated episodes, K updates per
    dispatch) covers the same contract as the host loop: per-batch history
    rows, finite metrics, work actually completing."""
    cfg = _temporal_cfg(device_episodes=True, epoch_len=2)
    params, state, opt, hist = temporal_train(cfg)
    assert [row["batch"] for row in hist] == [0, 1, 2, 3]
    for row in hist:
        for k in ("loss", "grad_norm", "cost_mean", "entropy"):
            assert np.isfinite(row[k]), (k, row)
    assert any(row["completed"] > 0 for row in hist)


def test_temporal_epoch_path_on_faulted_scenario():
    cfg = _temporal_cfg(scenario="chaos-straggler-storm",
                        device_episodes=True, epoch_len=2, num_batches=2)
    _, _, _, hist = temporal_train(cfg)
    assert len(hist) == 2 and all(np.isfinite(r["loss"]) for r in hist)


@pytest.mark.parametrize("epoch", [False, True])
def test_temporal_checkpoint_resume_bit_identical(tmp_path, epoch):
    """Stopping a temporal run at any checkpoint and resuming must replay
    exactly what the uninterrupted run would have produced: per-batch
    derived randomness makes save -> resume bit-identical on both the host
    loop and the scanned epoch path (whose chunking clamps to checkpoint
    boundaries)."""
    from repro.checkpoint.checkpointer import Checkpointer

    kw = dict(device_episodes=True, epoch_len=3) if epoch else {}
    cfg = _temporal_cfg(num_batches=4, **kw)

    p_full, _, o_full, h_full = temporal_train(cfg)

    ck = Checkpointer(str(tmp_path / "ck"), every=2, async_save=False)
    temporal_train(cfg, num_batches=2, checkpointer=ck)
    ck2 = Checkpointer(str(tmp_path / "ck"), every=2, async_save=False)
    p_res, _, o_res, h_res = temporal_train(cfg, num_batches=2,
                                            checkpointer=ck2)

    assert [r["batch"] for r in h_res] == [2, 3]
    assert _trees_equal(p_full, p_res)
    assert _trees_equal(o_full, o_res)
    full_tail = [r for r in h_full if r["batch"] >= 2]
    for a, b in zip(full_tail, h_res):
        assert a["loss"] == b["loss"] and a["cost_mean"] == b["cost_mean"]
