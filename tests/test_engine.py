"""Array-native batched engine: equivalence with the event-driven oracle,
vmap/batching consistency, per-round feature semantics, and the padded
arrival materializer feeding it — in the fault-free world and under
injected chaos (edge failures mid-episode, straggler slowdowns + jitter)."""
import jax
import numpy as np
import pytest

from repro.core.state import snapshot_instance
from repro.resilience import faults as faults_lib
from repro.serving import (MultiEdgeSim, SimConfig, engine)
from repro.serving.topology import nearest_alive_edge
from repro.workloads import PoissonArrivals, scenario, scenario_fault_spec
from repro.workloads.batch import materialize_round_batch, materialize_rounds

Q, ROUNDS, DT = 5, 12, 0.25


def _scripted_assign(key, inst):
    """Deterministic per-request assignment shared by both engines: a hash
    of the global arrival index spreads requests across all edges (heavy
    cross-edge transfer traffic, no scheduler tie-break sensitivity)."""
    del key
    return (inst["req_rid"] * 7 + 3) % Q


class _ScriptedController:
    """Oracle-side twin of `_scripted_assign`, recording the per-round
    workload features the CC would feed a scheduler."""

    last_decision_time = 0.0

    def __init__(self):
        self.features = {}  # round time -> (Q, 3) workload features

    def schedule(self, edges, pending, w, ct):
        inst = snapshot_instance([e.state for e in edges], pending, w, ct)
        t = min(r.submit_time for r in pending)  # any time inside the window
        self.features[int(np.ceil(t / DT)) - 1] = inst["workload"].copy()
        return [(r, (r.rid * 7 + 3) % Q) for r in pending]


def _engine_run(name, seed, assign_fn):
    arr = materialize_rounds(scenario(name), Q, ROUNDS, DT, seed=seed,
                             max_per_round=64)
    cfg = engine.EngineConfig(num_edges=Q, num_rounds=ROUNDS,
                              round_interval=DT, max_per_round=64)
    state = engine.init_state(cfg, seed=seed)
    run = engine.make_rollout(cfg, assign_fn)
    final, infos = run(state, arr, jax.random.PRNGKey(0))
    return arr, jax.device_get(final), jax.device_get(infos)


@pytest.mark.parametrize("name", ["uniform_iid", "flash_crowd_10x",
                                  "mmpp_bursty", "heavy_tail_pareto"])
def test_trace_equivalence_with_event_sim(name):
    """The same recorded workload, cluster seed, and per-request assignment
    through both engines: per-request finish times, per-round completion
    counts, per-round workload features, and the makespan must agree."""
    seed = 0
    arr, final, infos = _engine_run(name, seed, _scripted_assign)

    cc = _ScriptedController()
    sim = MultiEdgeSim(SimConfig(num_edges=Q, round_interval=DT, seed=seed,
                                 exec_noise=0.0, phi_oracle=True), cc)
    m = sim.drive(scenario(name), until=ROUNDS * DT, run_until=1e5, seed=seed)

    mask = arr["mask"].ravel()
    rids = arr["rid"].ravel()[mask]
    fin_engine = final["slot_finish"].ravel()[final["slot_edge"].ravel() >= 0]
    oracle = {r.rid: r.finish_time for e in sim.edges for r in e.completed}
    assert m["completed"] == m["submitted"] == len(rids) > 0
    fin_oracle = np.array([oracle[r] for r in rids])
    np.testing.assert_allclose(fin_engine, fin_oracle, rtol=1e-5, atol=1e-4)

    # identical per-round completion bucketing (same rule on both finish sets)
    bounds = (np.arange(ROUNDS) + 1) * DT + 1e-6
    np.testing.assert_array_equal(
        (fin_engine[None, :] <= bounds[:, None]).sum(-1),
        (fin_oracle[None, :] <= bounds[:, None]).sum(-1))
    np.testing.assert_allclose(fin_engine.max(), fin_oracle.max(),
                               rtol=1e-5, atol=1e-4)

    # workload-state evaluation (c_le, c_in, t_in) agrees round by round
    assert cc.features  # the oracle scheduled at least one non-empty round
    for r, wl_oracle in cc.features.items():
        np.testing.assert_allclose(infos["features"][r], wl_oracle,
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"round {r} features diverged")


class _ChaosController:
    """Oracle-side twin of the engine's fault-mode scheduling: fresh
    requests go to the scripted hash target failed over to the nearest
    alive edge (the engine's dispatch clamp); re-admitted orphans retry
    locally at their failed-over source (the engine's retry rule)."""

    last_decision_time = 0.0

    def __init__(self, sim):
        self.sim = sim
        self.seen = set()
        self.features = {}  # round index -> (Q, 3) workload features

    def schedule(self, edges, pending, w, ct):
        inst = snapshot_instance([e.state for e in edges], pending, w, ct)
        self.features[int(round(self.sim.now / DT)) - 1] = (
            inst["workload"].copy())
        alive = [e.alive for e in edges]
        out = []
        for r in pending:
            if r.rid in self.seen:
                out.append((r, r.source_edge))  # orphan retry: re-run local
            else:
                self.seen.add(r.rid)
                out.append((r, nearest_alive_edge(
                    self.sim.w, (r.rid * 7 + 3) % Q, alive)))
        return out


@pytest.mark.parametrize("name,seed", [
    ("chaos-rolling-failure", 0),   # every edge down in turn: mass orphaning
    ("chaos-rolling-failure", 1),
    ("chaos-straggler-storm", 0),   # Markov slowdowns + per-request jitter
    ("chaos-flash-failure", 0),     # crowd + outage collide on one edge
])
def test_chaos_equivalence_with_event_sim(name, seed):
    """The same fault trajectory (materialized rows vs scheduled events),
    workload, cluster, and scheduling rule through both engines: per-request
    finish times, completion bucketing, fault-free-round workload features,
    and the makespan must agree to 1e-4."""
    spec = scenario_fault_spec(name)
    assert spec is not None and spec.has_faults
    arr = materialize_rounds(scenario(name), Q, ROUNDS, DT, seed=seed,
                             max_per_round=64)
    ev = faults_lib.materialize_faults(spec, Q, ROUNDS, seed=seed)
    jit = (faults_lib.jitter_table(spec, int(arr["rid"].max()) + 1, seed=seed)
           if spec.jitter_sigma else None)

    cfg = engine.EngineConfig(num_edges=Q, num_rounds=ROUNDS,
                              round_interval=DT, max_per_round=64)
    run = engine.make_rollout(cfg, _scripted_assign)
    final, infos = run(engine.init_state(cfg, seed=seed),
                       faults_lib.attach_faults(arr, ev, jit),
                       jax.random.PRNGKey(0))
    final, infos = jax.device_get(final), jax.device_get(infos)

    sim = MultiEdgeSim(SimConfig(num_edges=Q, round_interval=DT, seed=seed,
                                 exec_noise=0.0, phi_oracle=True), None)
    cc = _ChaosController(sim)
    sim.cc = cc
    faults_lib.schedule_into_sim(sim, ev, DT, jit)
    m = sim.drive(scenario(name), until=ROUNDS * DT, run_until=1e5, seed=seed)

    mask = arr["mask"].ravel()
    rids = arr["rid"].ravel()[mask]
    committed = final["slot_edge"].ravel() >= 0
    fin_engine = final["slot_finish"].ravel()[committed]
    oracle = {r.rid: r.finish_time for e in sim.edges for r in e.completed}
    # the rolling outage always recovers, so nothing is stranded: every
    # arrival completes in both engines (some after one or more retries)
    assert m["completed"] == m["submitted"] == len(rids) > 0
    assert committed.sum() == len(rids)
    fin_oracle = np.array([oracle[r] for r in rids])
    np.testing.assert_allclose(fin_engine, fin_oracle, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(fin_engine.max(), fin_oracle.max(),
                               rtol=1e-5, atol=1e-4)
    bounds = (np.arange(ROUNDS) + 1) * DT + 1e-6
    np.testing.assert_array_equal(
        (fin_engine[None, :] <= bounds[:, None]).sum(-1),
        (fin_oracle[None, :] <= bounds[:, None]).sum(-1))
    if "failure" in name:
        assert int(final["retried"]) > 0  # the outage actually orphaned work
    # workload features agree at rounds untouched by an alive transition
    # (at a fault round the oracle briefly holds orphans as pending briefs
    # while the engine keeps them as in-flight slots — a representation
    # difference, not a schedule difference; finish times above pin those)
    quiet = np.ones(ROUNDS, bool)
    prev = np.ones(Q, bool)
    for r in range(ROUNDS):
        quiet[r] = bool((ev["alive"][r] == prev).all())
        prev = ev["alive"][r]
    checked = 0
    for r, wl_oracle in cc.features.items():
        if quiet[r] and (r == 0 or quiet[r - 1]):
            np.testing.assert_allclose(infos["features"][r], wl_oracle,
                                       rtol=1e-4, atol=1e-4,
                                       err_msg=f"round {r} features diverged")
            checked += 1
    assert checked > 0


def test_vmap_batch_matches_unbatched():
    """Batch-of-1 equals unbatched, and every element of a batched rollout
    equals its own unbatched rollout (different seeds per element)."""
    name, seeds = "uniform_iid", [0, 1, 2, 3]
    arrb = materialize_round_batch(scenario(name), Q, 8, DT, len(seeds),
                                   base_seed=0, max_per_round=32)
    cfg = engine.EngineConfig(num_edges=Q, num_rounds=8, round_interval=DT,
                              max_per_round=32)
    run_b = engine.make_rollout(cfg, engine.greedy_assign, batch=True)
    keys = jax.random.split(jax.random.PRNGKey(0), len(seeds))
    final_b, _ = run_b(engine.init_batch(cfg, seeds), arrb, keys)
    final_b = jax.device_get(final_b)

    run_1 = engine.make_rollout(cfg, engine.greedy_assign)
    for i, seed in enumerate(seeds):
        arr = {k: v[i] for k, v in arrb.items()}
        final, _ = run_1(engine.init_state(cfg, seed), arr, keys[i])
        final = jax.device_get(final)
        for k in ("slot_finish", "slot_start", "slot_edge", "lane_free"):
            np.testing.assert_allclose(final_b[k][i], final[k], rtol=1e-6,
                                       atol=1e-6, err_msg=(k, i))


def test_greedy_assign_beats_local_on_hotspot():
    """All traffic on one edge: greedy insertion must spread it out."""
    wl = PoissonArrivals(rate=40.0, edge_skew=64.0)
    arr = materialize_rounds(wl, Q, 8, DT, seed=3)
    cfg = engine.EngineConfig(num_edges=Q, num_rounds=8, round_interval=DT,
                              max_per_round=arr["mask"].shape[-1])
    out = {}
    for name, fn in engine.ASSIGN_FNS.items():
        if getattr(fn, "_assign_factory", False):
            continue  # the policy factory needs params; covered elsewhere
        run = engine.make_rollout(cfg, fn)
        final, _ = run(engine.init_state(cfg, 3), arr, jax.random.PRNGKey(0))
        out[name] = engine.summarize(final)
    assert out["greedy"]["completed"] == out["local"]["completed"] > 0
    assert out["greedy"]["mean_response"] < out["local"]["mean_response"]
    assert out["greedy"]["transferred_frac"] > 0.2


def test_learn_phi_recovers_true_coefficients():
    """Online running-sum phi fitting inside the engine: with deterministic
    affine runtimes the estimate converges to the hidden truth."""
    arr = materialize_rounds(scenario("uniform_iid"), Q, ROUNDS, DT, seed=5)
    cfg = engine.EngineConfig(num_edges=Q, num_rounds=ROUNDS,
                              round_interval=DT, learn_phi=True,
                              max_per_round=arr["mask"].shape[-1])
    state = engine.init_state(cfg, seed=5)
    assert np.allclose(np.asarray(state["phi_est"]),
                       np.tile([1.0, 0.0], (Q, 1)))  # cold start
    run = engine.make_rollout(cfg, engine.local_assign)
    final, _ = run(state, arr, jax.random.PRNGKey(0))
    final = jax.device_get(final)
    fitted = final["phi_n"] >= cfg.phi_min_samples
    assert fitted.any()
    np.testing.assert_allclose(final["phi_est"][fitted],
                               final["phi_true"][fitted], atol=5e-2)


def test_policy_assign_runs_in_engine():
    """Untrained CoRaiS policy as the engine scheduler (plumbing check)."""
    from repro.core.policy import PolicyConfig, corais_init
    pcfg = PolicyConfig(d_model=32, ff_hidden=64, edge_layers=1,
                        request_layers=1)
    params, pstate = corais_init(jax.random.PRNGKey(0), pcfg)
    arr = materialize_rounds(scenario("uniform_iid"), Q, 6, DT, seed=0)
    cfg = engine.EngineConfig(num_edges=Q, num_rounds=6, round_interval=DT,
                              max_per_round=arr["mask"].shape[-1])
    run = engine.make_rollout(
        cfg, engine.make_policy_assign(params, pstate, pcfg))
    final, _ = run(engine.init_state(cfg, 0), arr, jax.random.PRNGKey(1))
    m = engine.summarize(final)
    assert m["completed"] == m["submitted"] == int(arr["mask"].sum()) > 0


def test_engine_cluster_matches_simulator_cluster():
    """(seed -> cluster) is one function for both engines."""
    cfg = engine.EngineConfig(num_edges=Q)
    state = engine.init_state(cfg, seed=7)
    sim = MultiEdgeSim(SimConfig(num_edges=Q, seed=7),
                       _ScriptedController())
    np.testing.assert_allclose(state["w"], sim.w.astype(np.float32))
    for i, e in enumerate(sim.edges):
        np.testing.assert_allclose(state["phi_true"][i],
                                   [e.true_a, e.true_b], rtol=1e-6)
        assert int(state["replicas"][i]) == e.replicas


def test_mismatched_arrival_width_is_rejected():
    """A width/rounds mismatch between arrivals and the slot table must
    raise instead of silently misaligning slot rows."""
    arr = materialize_rounds(scenario("uniform_iid"), Q, 6, DT, seed=0,
                             max_per_round=16)
    cfg = engine.EngineConfig(num_edges=Q, num_rounds=6, max_per_round=8)
    run = engine.make_rollout(cfg, engine.local_assign)
    with pytest.raises(ValueError, match="max_per_round"):
        run(engine.init_state(cfg, 0), arr, jax.random.PRNGKey(0))
    cfg_short = engine.EngineConfig(num_edges=Q, num_rounds=4,
                                    max_per_round=16)
    run_short = engine.make_rollout(cfg_short, engine.local_assign)
    with pytest.raises(ValueError, match="rounds"):
        run_short(engine.init_state(cfg_short, 0), arr,
                  jax.random.PRNGKey(0))


# -- padded arrival materialization ------------------------------------------


def test_materialize_rounds_windows_and_determinism():
    wl = scenario("uniform_iid")
    arr = materialize_rounds(wl, Q, ROUNDS, DT, seed=0)
    arr2 = materialize_rounds(wl, Q, ROUNDS, DT, seed=0)
    for k in arr:
        np.testing.assert_array_equal(arr[k], arr2[k])
    mask = arr["mask"]
    assert mask.any()
    # every arrival sits in its round's window (r*dt, (r+1)*dt]
    for r in range(ROUNDS):
        ts = arr["t"][r][mask[r]]
        assert np.all(ts > r * DT - 1e-9) and np.all(ts <= (r + 1) * DT + 1e-9)
    # rids are the global time order
    rids = arr["rid"][mask]
    np.testing.assert_array_equal(rids, np.arange(mask.sum()))
    assert np.all(np.diff(arr["t"][mask]) >= 0)


def test_materialize_rounds_overflow_policies():
    wl = PoissonArrivals(rate=200.0)
    with pytest.raises(ValueError, match="max_per_round"):
        materialize_rounds(wl, Q, 4, DT, seed=0, max_per_round=2)
    clipped = materialize_rounds(wl, Q, 4, DT, seed=0, max_per_round=2,
                                 overflow="clip")
    assert clipped["mask"].shape == (4, 2)
    full = materialize_rounds(wl, Q, 4, DT, seed=0)
    assert clipped["mask"].sum() < full["mask"].sum()


def test_materialize_round_batch_shapes():
    wl = scenario("uniform_iid")
    arr = materialize_round_batch(wl, Q, 6, DT, 3, base_seed=0)
    assert arr["mask"].shape[0] == 3 and arr["mask"].shape[1] == 6
    # element i reproduces the single materialization under seed base+i
    one = materialize_rounds(wl, Q, 6, DT, seed=1,
                             max_per_round=arr["mask"].shape[-1])
    for k in arr:
        np.testing.assert_array_equal(arr[k][1], one[k])
