"""Resilience subsystem: fault materialization, admission control (heuristic
and trained), circuit breaking, retry backoff, drop accounting, and the
fault-injected temporal training path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.inference import make_policy_assign
from repro.core.policy import PolicyConfig, corais_admit, corais_encode, corais_init
from repro.resilience import ResilienceConfig
from repro.resilience import faults as faults_lib
from repro.resilience.policies import (admission_mask, breaker_step,
                                       dispatch_mask, probe_cap)
from repro.serving import engine
from repro.serving.rounds import MIN_JITTER
from repro.workloads import PoissonArrivals, scenario, scenario_fault_spec
from repro.workloads.batch import materialize_rounds

Q, ROUNDS, DT = 5, 12, 0.25


# -- fault materialization ---------------------------------------------------


def test_materialize_faults_deterministic_and_shaped():
    spec = faults_lib.FaultSpec(fail_prob=0.3, recover_prob=0.5,
                                straggle_prob=0.3, straggle_factor=3.0)
    ev1 = faults_lib.materialize_faults(spec, Q, ROUNDS, seed=7)
    ev2 = faults_lib.materialize_faults(spec, Q, ROUNDS, seed=7)
    assert ev1["alive"].shape == ev1["speed"].shape == (ROUNDS, Q)
    assert ev1["alive"].dtype == bool and ev1["speed"].dtype == np.float32
    np.testing.assert_array_equal(ev1["alive"], ev2["alive"])
    np.testing.assert_array_equal(ev1["speed"], ev2["speed"])
    ev3 = faults_lib.materialize_faults(spec, Q, ROUNDS, seed=8)
    assert not (np.array_equal(ev1["alive"], ev3["alive"])
                and np.array_equal(ev1["speed"], ev3["speed"]))
    assert set(np.unique(ev1["speed"])) <= {np.float32(1.0), np.float32(3.0)}


def test_materialize_faults_min_alive_floor():
    spec = faults_lib.FaultSpec(fail_prob=1.0, recover_prob=0.0, min_alive=2)
    ev = faults_lib.materialize_faults(spec, Q, ROUNDS, seed=0)
    assert (ev["alive"].sum(axis=1) >= 2).all()
    # scripted kills are floored too
    spec2 = faults_lib.FaultSpec(
        scripted_failures=tuple((q, 0, ROUNDS) for q in range(Q)))
    ev2 = faults_lib.materialize_faults(spec2, Q, ROUNDS, seed=0)
    assert (ev2["alive"].sum(axis=1) >= 1).all()


def test_rolling_outage_pattern():
    ev = faults_lib.materialize_faults(
        faults_lib.FaultSpec(rolling=(2, 2)), Q, ROUNDS, seed=0)
    for q in range(Q):
        lo, hi = 2 + q * 2, min(2 + (q + 1) * 2, ROUNDS)
        assert not ev["alive"][lo:hi, q].any()
    assert (ev["alive"].sum(axis=1) >= Q - 1).all()


def test_jitter_table_floor_and_identity():
    spec = faults_lib.FaultSpec(jitter_sigma=2.0)
    jit = faults_lib.jitter_table(spec, 512, seed=3)
    assert jit.shape == (512,) and (jit >= MIN_JITTER).all()
    assert jit.std() > 0
    np.testing.assert_array_equal(
        faults_lib.jitter_table(faults_lib.FaultSpec(), 16), np.ones(16))


def test_attach_faults_rows_and_padded_jitter():
    arr = materialize_rounds(scenario("uniform_iid"), Q, ROUNDS, DT, seed=0)
    spec = faults_lib.FaultSpec(rolling=(2, 2), jitter_sigma=0.3)
    ev = faults_lib.materialize_faults(spec, Q, ROUNDS, seed=0)
    jit = faults_lib.jitter_table(spec, int(arr["rid"].max()) + 1, seed=0)
    out = faults_lib.attach_faults(arr, ev, jit)
    assert out["alive"].shape == out["speed"].shape == (ROUNDS, Q)
    assert out["jitter"].shape == arr["mask"].shape
    # padding slots carry neutral jitter, real slots the rid-table entry
    np.testing.assert_array_equal(out["jitter"][~arr["mask"]], 1.0)
    np.testing.assert_allclose(out["jitter"][arr["mask"]],
                               jit[arr["rid"][arr["mask"]]])
    with pytest.raises(ValueError, match="rounds"):
        short = faults_lib.materialize_faults(spec, Q, ROUNDS - 1, seed=0)
        faults_lib.attach_faults(arr, short, jit)


def test_fault_events_round_trip_orders_recovers_first():
    ev = faults_lib.materialize_faults(
        faults_lib.FaultSpec(rolling=(2, 2)), Q, ROUNDS, seed=0)
    evs = faults_lib.fault_events_from_rows(ev, DT)
    assert evs and all(e.t > 0 for e in evs)
    assert [e.t for e in evs] == sorted(e.t for e in evs)
    # a rolling handover round has both a recovery and a failure at the
    # same instant: the recovery must come first (the oracle's failover
    # mask must match the engine's atomic row application)
    by_t = {}
    for e in evs:
        by_t.setdefault(e.t, []).append(e.kind)
    handovers = [k for k in by_t.values() if len(k) > 1]
    assert handovers and all(k.index("recover") < k.index("fail")
                             for k in handovers if "recover" in k)


# -- admission control -------------------------------------------------------


def _overload_instance():
    arr = materialize_rounds(PoissonArrivals(rate=120.0), Q, 1, DT, seed=0)
    cfg = engine.EngineConfig(num_edges=Q, num_rounds=1, round_interval=DT,
                              max_per_round=arr["mask"].shape[-1])
    state = jax.tree.map(jnp.asarray, engine.init_state(cfg, seed=0))
    arr0 = {k: jnp.asarray(v[0]) for k, v in arr.items()}
    state = engine.advance(state, DT, cfg)
    return engine.round_instance(state, arr0, cfg), arr0


def test_admission_heuristics():
    inst, arr0 = _overload_instance()
    assign = inst["req_src"]
    res_all = ResilienceConfig(admission="none")
    np.testing.assert_array_equal(admission_mask(res_all, inst, assign),
                                  np.ones_like(arr0["mask"]))
    tight = ResilienceConfig(admission="slo_threshold", admit_threshold=1e-4)
    loose = ResilienceConfig(admission="slo_threshold", admit_threshold=1e4)
    n_tight = int(jnp.sum(admission_mask(tight, inst, assign) & arr0["mask"]))
    n_loose = int(jnp.sum(admission_mask(loose, inst, assign) & arr0["mask"]))
    assert n_tight == 0 and n_loose == int(arr0["mask"].sum())
    with pytest.raises(ValueError, match="admission"):
        ResilienceConfig(admission="nope")


def test_engine_sheds_under_admission_and_accounts_everything():
    """Overload + slo_threshold admission: every arrival is either completed
    or shed, and summarize's population accounting stays exact."""
    wl = PoissonArrivals(rate=80.0, edge_skew=8.0)
    arr = materialize_rounds(wl, Q, 8, DT, seed=1)
    res = ResilienceConfig(admission="slo_threshold", admit_threshold=0.8)
    cfg = engine.EngineConfig(num_edges=Q, num_rounds=8, round_interval=DT,
                              max_per_round=arr["mask"].shape[-1],
                              resilience=res)
    run = engine.make_rollout(cfg, engine.local_assign)
    final, infos = run(engine.init_state(cfg, 1), arr, jax.random.PRNGKey(0))
    m = engine.summarize(final, slo=res.slo)
    n = int(arr["mask"].sum())
    assert m["submitted"] == n
    assert 0 < m["shed_requests"] < n
    assert m["completed"] + m["shed_requests"] == n
    assert m["shed_rate"] == pytest.approx(m["shed_requests"] / n)
    assert 0.0 < m["slo_violation_frac"] <= 1.0
    assert int(jax.device_get(infos["round_shed"]).sum()) == m["shed_requests"]
    # and shedding the expensive tail must actually help the served mean
    cfg_open = engine.EngineConfig(num_edges=Q, num_rounds=8,
                                   round_interval=DT,
                                   max_per_round=arr["mask"].shape[-1])
    run_open = engine.make_rollout(cfg_open, engine.local_assign)
    final_open, _ = run_open(engine.init_state(cfg_open, 1), arr,
                             jax.random.PRNGKey(0))
    m_open = engine.summarize(final_open)
    assert m["mean_response"] < m_open["mean_response"]


def test_policy_admission_head_plumbing():
    """admit_head=True grows an admit MLP; corais_admit starts near
    admit-all (positive bias) and the engine consumes (assign, admit)."""
    pcfg = PolicyConfig(d_model=32, ff_hidden=64, edge_layers=1,
                        request_layers=1, admit_head=True, admit_hidden=16)
    params, pstate = corais_init(jax.random.PRNGKey(0), pcfg)
    assert "admit" in params
    arr = materialize_rounds(scenario("uniform_iid"), Q, 6, DT, seed=0)
    res = ResilienceConfig(admission="policy")
    cfg = engine.EngineConfig(num_edges=Q, num_rounds=6, round_interval=DT,
                              max_per_round=arr["mask"].shape[-1],
                              resilience=res)
    run = engine.make_rollout(
        cfg, make_policy_assign(params, pstate, pcfg, admission=True))
    final, _ = run(engine.init_state(cfg, 0), arr, jax.random.PRNGKey(1))
    m = engine.summarize(final)
    n = int(arr["mask"].sum())
    assert m["submitted"] == n
    assert m["completed"] + m["shed_requests"] == n
    assert m["shed_requests"] < n / 4  # fresh head ~ admit-all

    # a head-less policy must fail loudly, not silently admit-all
    plain, pstate2 = corais_init(jax.random.PRNGKey(0), PolicyConfig(
        d_model=32, ff_hidden=64, edge_layers=1, request_layers=1))
    state = jax.tree.map(jnp.asarray, engine.init_state(cfg, 0))
    inst = engine.round_instance(
        engine.advance(state, DT, cfg),
        {k: jnp.asarray(v[0]) for k, v in arr.items()}, cfg)
    c_emb, h_emb, _ = corais_encode(plain, pstate2, inst, pcfg,
                                    training=False)
    with pytest.raises(ValueError, match="admit"):
        corais_admit(plain, c_emb, h_emb, inst["edge_mask"], pcfg)


# -- circuit breaker & retry backoff -----------------------------------------


def test_breaker_step_cooldown_growth_and_reset():
    res = ResilienceConfig(breaker=True, breaker_cooldown_rounds=2.0,
                           breaker_reset_rounds=2)
    open_until = jnp.full(2, -1.0)
    trips = jnp.zeros(2)
    healthy = jnp.zeros(2)
    died = jnp.array([True, False])
    alive = jnp.array([False, True])
    o1, t1, h1 = breaker_step(open_until, trips, healthy, died, alive,
                              1.0, DT, res)
    assert float(o1[0]) == pytest.approx(1.0 + 2.0 * DT)  # first trip
    assert float(t1[0]) == 1.0 and float(h1[0]) == 0.0
    # second trip doubles the cooldown
    o2, t2, _ = breaker_step(o1, t1, h1, died, alive, 2.0, DT, res)
    assert float(o2[0]) == pytest.approx(2.0 + 4.0 * DT)
    assert float(t2[0]) == 2.0
    # healthy rounds past the cooldown reset the trip counter
    ok = jnp.array([True, True])
    o3, t3, h3 = o2, t2, jnp.zeros(2)
    for t in (4.0, 4.25):
        o3, t3, h3 = breaker_step(o3, t3, h3, jnp.array([False, False]),
                                  ok, t, DT, res)
    assert float(t3[0]) == 0.0


def test_dispatch_mask_open_breaker_and_fallback():
    alive = jnp.array([True, True, False])
    open_until = jnp.array([5.0, -1.0, -1.0])
    np.testing.assert_array_equal(dispatch_mask(alive, open_until, 1.0),
                                  [False, True, False])
    np.testing.assert_array_equal(dispatch_mask(alive, open_until, 6.0),
                                  [True, True, False])
    # every alive edge behind an open breaker -> fall back to liveness
    all_open = jnp.array([5.0, 5.0, 5.0])
    np.testing.assert_array_equal(dispatch_mask(alive, all_open, 1.0),
                                  [True, True, False])


def test_probe_cap_limits_half_open_traffic():
    res = ResilienceConfig(breaker=True, breaker_probe=1)
    w = jnp.asarray(np.array([[0.0, 1.0, 2.0], [1.0, 0.0, 1.0],
                              [2.0, 1.0, 0.0]], np.float32))
    assign = jnp.array([0, 0, 0, 1], jnp.int32)
    req_mask = jnp.array([True, True, True, True])
    src = jnp.array([1, 1, 2, 1], jnp.int32)
    half_open = jnp.array([True, False, False])
    closed = jnp.array([False, True, True])
    out = np.asarray(probe_cap(w, assign, req_mask, src, half_open, closed,
                               res))
    assert out[0] == 0              # the single allowed probe
    assert out[1] == 1 and out[2] == 2  # excess -> nearest closed to src
    assert out[3] == 1              # closed-edge traffic untouched


def test_breaker_keeps_recovered_edge_cold_then_reopens():
    """Edge 0 dies for one round; with a 3-round breaker the engine must not
    dispatch fresh work there while the breaker is open, then resume."""
    spec = faults_lib.FaultSpec(scripted_failures=((0, 2, 3),))
    arr = materialize_rounds(PoissonArrivals(rate=40.0, edge_skew=6.0),
                             Q, ROUNDS, DT, seed=2)
    ev = faults_lib.materialize_faults(spec, Q, ROUNDS, seed=2)
    res = ResilienceConfig(breaker=True, breaker_cooldown_rounds=3.0,
                           breaker_reset_rounds=2)
    cfg = engine.EngineConfig(num_edges=Q, num_rounds=ROUNDS,
                              round_interval=DT,
                              max_per_round=arr["mask"].shape[-1],
                              resilience=res)
    run = engine.make_rollout(cfg, engine.local_assign)
    final, infos = run(engine.init_state(cfg, 2),
                       faults_lib.attach_faults(arr, ev), jax.random.PRNGKey(0))
    final, infos = jax.device_get(final), jax.device_get(infos)
    assign = infos["assign"]  # (R, A)
    hot = arr["mask"] & (assign == 0)
    # round 2 applies the death (local traffic fails over), and the breaker
    # holds through the recovery at round 3 until the cooldown lapses
    open_rounds = range(2, 2 + 3)
    for r in open_rounds:
        assert not hot[r].any(), f"dispatch to open edge 0 at round {r}"
    assert any(hot[r].any() for r in range(max(open_rounds) + 1, ROUNDS))
    assert int(final["retried"]) > 0


def test_retry_backoff_delays_orphan_ready():
    res = ResilienceConfig(retry_backoff_rounds=2.0, retry_backoff_cap=3)
    cfg = engine.EngineConfig(num_edges=Q, num_rounds=2, round_interval=DT,
                              max_per_round=4, resilience=res)
    cfg0 = dataclasses_replace_resilience(cfg, None)
    state = jax.tree.map(jnp.asarray, engine.init_state(cfg, seed=0))
    state = dict(state)
    state["t"] = jnp.float32(DT)
    # one committed, unfinished slot on edge 0
    state["slot_edge"] = state["slot_edge"].at[0].set(0)
    state["slot_src"] = state["slot_src"].at[0].set(0)
    state["slot_ready"] = state["slot_ready"].at[0].set(0.1)
    arr = {"alive": jnp.asarray([False, True, True, True, True]),
           "speed": jnp.ones(Q)}
    out = engine.apply_faults(state, arr, cfg)
    expect = DT + engine.RETRY_EPS + 2.0 * DT  # first retry: 2 rounds
    assert float(out["slot_ready"][0]) == pytest.approx(expect)
    assert int(out["retried"]) == 1 and float(out["slot_retries"][0]) == 1.0
    # without backoff the orphan is ready immediately (epsilon-nudged)
    out0 = engine.apply_faults(state, arr, cfg0)
    assert float(out0["slot_ready"][0]) == pytest.approx(
        DT + engine.RETRY_EPS)


def dataclasses_replace_resilience(cfg, res):
    import dataclasses
    return dataclasses.replace(cfg, resilience=res)


# -- drop accounting ---------------------------------------------------------


def test_overflow_drops_surface_in_summary():
    wl = PoissonArrivals(rate=200.0)
    arr = materialize_rounds(wl, Q, 4, DT, seed=0, max_per_round=4,
                             overflow="clip")
    assert arr["dropped"].sum() > 0
    cfg = engine.EngineConfig(num_edges=Q, num_rounds=4, round_interval=DT,
                              max_per_round=4)
    run = engine.make_rollout(cfg, engine.local_assign)
    final, _ = run(engine.init_state(cfg, 0), arr, jax.random.PRNGKey(0))
    m = engine.summarize(final, slo=100.0)
    assert m["dropped_requests"] == int(arr["dropped"].sum())
    assert m["submitted"] == m["completed"] + m["dropped_requests"]
    assert m["shed_rate"] > 0
    # drops are SLO violations even when every served request is fast
    assert m["slo_violation_frac"] == pytest.approx(
        m["dropped_requests"] / m["submitted"])


# -- fault-injected temporal training ----------------------------------------


def test_temporal_train_with_admission_on_chaos_scenario():
    """Smoke: joint dispatch+admission REINFORCE on fault-injected episodes
    runs, logs the resilience metrics, and touches the admit head."""
    from repro.core.train import TemporalRLConfig, temporal_train

    assert scenario_fault_spec("chaos-rolling-failure").has_faults
    pcfg = PolicyConfig(d_model=16, ff_hidden=32, edge_layers=1,
                        request_layers=1, admit_head=True, admit_hidden=8)
    ecfg = engine.EngineConfig(num_edges=Q, num_rounds=4, round_interval=DT,
                               max_per_round=8)
    cfg = TemporalRLConfig(policy=pcfg, engine=ecfg,
                           scenario="chaos-rolling-failure", batch_size=2,
                           lr=1e-4, seed=0, admission=True, slo=3.0,
                           slo_penalty=2.0)
    params0, _ = corais_init(jax.random.PRNGKey(0), pcfg)
    params, state, _, history = temporal_train(cfg, num_batches=2)
    assert len(history) == 2
    for h in history:
        assert np.isfinite(h["loss"])
        assert "slo_violation_frac" in h and "shed" in h
    # the admit head received gradient (params moved from its init)
    assert "admit" in params
    moved = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         params["admit"], params0["admit"])
    assert max(jax.tree.leaves(moved)) >= 0.0  # finite, well-formed
