"""Workload & scenario subsystem: determinism, empirical arrival rates,
trace record->replay round trips, scenario-conditioned instance sampling,
and scenario-driven end-to-end sim smoke tests."""
import numpy as np
import pytest

from repro.core import InstanceConfig, generate_instance
from repro.serving import (CentralController, MultiEdgeSim, SimConfig,
                           nearest_alive_edge)
from repro.workloads import (SCHEMA_V1, SCHEMA_V2, SCHEMA_V3, DiurnalArrivals,
                             FaultEvent, FlashCrowdArrivals, MMPPArrivals,
                             PoissonArrivals, ServiceMix, SizeSpec,
                             instance_config_for_scenario, list_scenarios,
                             merge, read_trace, record_trace, scenario,
                             scenario_fault_spec, scenario_spec, write_trace)

TIMING_KEYS = ("scheduler_decision_s", "decision_mean_s", "decision_p95_s",
               "decision_max_s")

import pathlib
DATA = pathlib.Path(__file__).parent / "data"


def _completion(m):
    return {k: v for k, v in m.items() if k not in TIMING_KEYS}


# -- determinism -------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(list_scenarios()))
def test_scenario_arrivals_deterministic(name):
    wl = scenario(name)
    a1 = list(wl.arrivals(np.random.default_rng(7), 4, 2.0))
    a2 = list(wl.arrivals(np.random.default_rng(7), 4, 2.0))
    assert a1 == a2
    assert len(a1) > 0
    ts = [a.t for a in a1]
    assert ts == sorted(ts)
    assert all(0 <= a.edge < 4 and 0 < a.size and a.t <= 2.0 for a in a1)


# -- empirical rate sanity ---------------------------------------------------

def _count(wl, until=50.0, edges=4, seed=0):
    return len(list(wl.arrivals(np.random.default_rng(seed), edges, until)))


def test_poisson_rate():
    n = _count(PoissonArrivals(rate=20.0), until=50.0)
    assert n == pytest.approx(1000, rel=0.15)


def test_diurnal_mean_rate_and_modulation():
    wl = DiurnalArrivals(base_rate=20.0, amplitude=0.9, period=4.0)
    arrivals = list(wl.arrivals(np.random.default_rng(1), 4, 48.0))
    # time-average rate is base_rate (sinusoid integrates to zero)
    assert len(arrivals) == pytest.approx(20.0 * 48.0, rel=0.15)
    # peaks (rate ~38) must be busier than troughs (rate ~2)
    phase = [(a.t % 4.0) for a in arrivals]
    rising = sum(1 for p in phase if 0.5 <= p < 1.5)     # around sin max
    falling = sum(1 for p in phase if 2.5 <= p < 3.5)    # around sin min
    assert rising > 3 * falling


def test_flash_crowd_spike_volume_and_placement():
    wl = FlashCrowdArrivals(base_rate=10.0, multiplier=10.0, spike_start=1.0,
                            spike_duration=0.5, spike_edge=2)
    arrivals = list(wl.arrivals(np.random.default_rng(2), 5, 3.0))
    spike = [a for a in arrivals if 1.0 <= a.t <= 1.5]
    rest = [a for a in arrivals if a.t < 1.0 or a.t > 1.5]
    # spike window carries ~100 req/s vs ~10 elsewhere
    assert len(spike) == pytest.approx(100 * 0.5, rel=0.35)
    assert len(rest) == pytest.approx(10 * 2.5, rel=0.5)
    # the spike concentrates on the configured edge
    on_hot = sum(1 for a in spike if a.edge == 2)
    assert on_hot / len(spike) > 0.8


def test_mmpp_rate_between_regimes():
    wl = MMPPArrivals(rates=(5.0, 80.0), mean_sojourn=(2.0, 0.25))
    n = _count(wl, until=100.0, seed=3)
    lo, hi = 5.0 * 100, 80.0 * 100
    assert lo < n < hi
    # long-run mean rate = sum(rate_i * sojourn_i) / sum(sojourn_i)
    mean_rate = (5.0 * 2.0 + 80.0 * 0.25) / 2.25
    assert n == pytest.approx(mean_rate * 100, rel=0.3)


def test_merge_superposes():
    a = PoissonArrivals(rate=5.0)
    b = PoissonArrivals(rate=15.0)
    n = _count(merge(a, b), until=50.0, seed=4)
    assert n == pytest.approx(20.0 * 50, rel=0.15)


def test_size_specs():
    rng = np.random.default_rng(0)
    u = SizeSpec("uniform", (0.2, 0.8)).sample(rng, 1000)
    assert u.min() >= 0.2 and u.max() <= 0.8
    p = SizeSpec("pareto", (1.5, 0.05)).sample(rng, 5000)
    assert p.max() <= 1.0 and p.min() > 0
    # heavy tail: some mass far above the scale parameter
    assert (p > 0.5).sum() > 0
    ln = SizeSpec("lognormal", (-1.5, 0.8)).sample(rng, 1000)
    assert ln.max() <= 1.0 and ln.min() > 0
    with pytest.raises(ValueError):
        SizeSpec("nope").sample(rng, 1)


def test_hotspot_skew_concentrates_sources():
    wl = scenario("hotspot_skew")
    arrivals = list(wl.arrivals(np.random.default_rng(5), 5, 10.0))
    share0 = sum(1 for a in arrivals if a.edge == 0) / len(arrivals)
    assert share0 > 0.5  # Zipf(2) over 5 edges puts ~68% on the hot edge


# -- trace record / replay ---------------------------------------------------

def test_trace_round_trip_exact(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    wl = scenario("heavy_tail_pareto")
    rng = np.random.default_rng(11)
    events = list(wl.arrivals(rng, 6, 4.0))
    write_trace(path, events, num_edges=6, meta={"note": "unit"})
    tr = read_trace(path)
    assert tr.num_edges == 6 and tr.meta["note"] == "unit"
    assert list(tr.events) == events  # bit-exact floats via json repr
    # replay respects the until bound
    clipped = list(tr.arrivals(None, 6, 2.0))
    assert clipped == [a for a in events if a.t <= 2.0]


def test_record_trace_deterministic(tmp_path):
    p1, p2 = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    wl = scenario("mmpp_bursty")
    n1 = record_trace(p1, wl, num_edges=3, until=5.0, seed=9)
    n2 = record_trace(p2, wl, num_edges=3, until=5.0, seed=9)
    assert n1 == n2
    assert list(read_trace(p1).events) == list(read_trace(p2).events)


def test_read_trace_rejects_bad_schema(tmp_path):
    path = str(tmp_path / "bad.jsonl")
    with open(path, "w") as f:
        f.write('{"schema": "corais.trace.v999"}\n')
    # the error names every supported version, so a stale reader's message
    # tells the operator exactly what their file could be migrated to
    with pytest.raises(ValueError) as exc:
        read_trace(path)
    for schema in (SCHEMA_V1, SCHEMA_V2, SCHEMA_V3):
        assert schema in str(exc.value)


# -- schema v3 (deadlines / priorities) migration -----------------------------

def test_trace_v3_round_trip_bit_exact(tmp_path):
    """A deadline/priority-carrying stream stamps v3 and round-trips every
    field bit-exactly (repr floats)."""
    path = str(tmp_path / "v3.jsonl")
    wl = ServiceMix(PoissonArrivals(rate=30.0), num_services=5, skew=0.7,
                    deadline=(1.0, 2.5), priorities=(2.0, 1.0))
    rng = np.random.default_rng(4)
    events = list(wl.arrivals(rng, 4, 3.0))
    assert any(a.deadline > 0 for a in events)
    assert any(a.priority for a in events)
    write_trace(path, events, num_edges=4)
    tr = read_trace(path)
    assert tr.schema == SCHEMA_V3
    assert list(tr.events) == events


def test_trace_v3_downgrade_byte_exact(tmp_path):
    """The v3-capable writer is a byte-exact downgrader: a stream with no
    deadlines/priorities produces the identical v1 (or, with faults, v2)
    bytes pre-v3 code wrote."""
    plain = str(tmp_path / "plain.jsonl")
    record_trace(plain, scenario("uniform_iid"), num_edges=4, until=2.0,
                 seed=42)
    assert read_trace(plain).schema == SCHEMA_V1
    assert open(plain, "rb").read() == open(DATA / "trace_v1.jsonl", "rb").read()


def test_pre_v3_files_read_under_v3_reader(tmp_path):
    """Committed v1/v2 fixture traces (recorded before any v3 fields
    existed in their streams) read back unchanged: defaults fill the new
    Arrival fields and a replay drives the sim end to end."""
    for path, schema in ((DATA / "trace_v1.jsonl", SCHEMA_V1),
                         (DATA / "trace_v2.jsonl", SCHEMA_V2)):
        tr = read_trace(path)
        assert tr.schema == schema
        assert tr.num_edges == 4 and len(tr.events) > 0
        assert all(a.deadline == 0.0 and a.priority == 0 for a in tr.events)
    tr2 = read_trace(DATA / "trace_v2.jsonl")
    assert len(tr2.fault_events) > 0
    sim = MultiEdgeSim(SimConfig(num_edges=4, seed=0),
                       CentralController(scheduler="greedy"))
    m = sim.drive(read_trace(DATA / "trace_v1.jsonl"), until=2.0,
                  run_until=300.0)
    assert m["completed"] == m["submitted"] > 0


def test_pre_v3_schemas_reject_v3_fields(tmp_path):
    path = str(tmp_path / "smuggle.jsonl")
    with open(path, "w") as f:
        f.write('{"schema": "corais.trace.v1", "num_edges": 3}\n')
        f.write('{"t": 0.1, "edge": 0, "size": 0.5, "deadline": 1.0}\n')
    with pytest.raises(ValueError, match="corais.trace.v3"):
        read_trace(path)


def test_v3_deadlines_thread_into_sim_metrics():
    """drive() converts relative trace deadlines to absolute hard-SLO
    times; the unified metrics expose the miss accounting."""
    wl = ServiceMix(PoissonArrivals(rate=30.0), num_services=4,
                    deadline=(0.5, 1.0))
    sim = MultiEdgeSim(SimConfig(num_edges=4, seed=0),
                       CentralController(scheduler="greedy"))
    m = sim.drive(wl, until=2.0, run_until=300.0, seed=0)
    assert m["deadline_total"] == m["submitted"] > 0
    assert 0.0 <= m["deadline_miss_frac"] <= 1.0
    assert m["deadline_missed"] == round(
        m["deadline_miss_frac"] * m["deadline_total"])


def test_trace_v2_fault_events_round_trip(tmp_path):
    """A trace with a fault timeline is stamped v2 and round-trips the
    events exactly; without one, the file is a byte-identical v1 trace."""
    from repro.resilience.faults import (FaultSpec, fault_events_from_rows,
                                         materialize_faults)

    path = str(tmp_path / "chaos.jsonl")
    wl = scenario("chaos-rolling-failure")
    ev = materialize_faults(scenario_fault_spec("chaos-rolling-failure"),
                            5, 12, seed=0)
    fault_events = fault_events_from_rows(ev, 0.25)
    assert fault_events
    record_trace(path, wl, num_edges=5, until=3.0, seed=0,
                 fault_events=fault_events)
    tr = read_trace(path)
    assert tr.schema == SCHEMA_V2
    assert tr.fault_events == fault_events  # repr floats: exact round trip
    assert len(tr.events) > 0

    # no fault events -> v1, byte-identical to a pre-v2 recording
    p1 = str(tmp_path / "plain.jsonl")
    record_trace(p1, wl, num_edges=5, until=3.0, seed=0)
    tr1 = read_trace(p1)
    assert tr1.schema == SCHEMA_V1 and tr1.fault_events == ()
    assert '"schema": "corais.trace.v1"' in open(p1).readline()
    assert list(tr1.events) == list(tr.events)  # same arrival stream


def test_trace_v2_rejects_malformed_fault_events(tmp_path):
    with pytest.raises(ValueError, match="fault kind"):
        FaultEvent(t=0.5, kind="explode", edge=0)
    path = str(tmp_path / "bad_events.jsonl")
    with open(path, "w") as f:
        f.write('{"schema": "corais.trace.v2", "num_edges": 3, '
                '"events": [{"t": 0.5, "kind": "fail", "edge": 9}]}\n')
    with pytest.raises(ValueError, match="edge 9"):
        read_trace(path)
    # v1 headers must not smuggle an events section
    p2 = str(tmp_path / "v1_events.jsonl")
    with open(p2, "w") as f:
        f.write('{"schema": "corais.trace.v1", "num_edges": 3, '
                '"events": [{"t": 0.5, "kind": "fail", "edge": 0}]}\n')
    with pytest.raises(ValueError, match="corais.trace.v2"):
        read_trace(p2)


def test_read_trace_rejects_out_of_range_edge(tmp_path):
    path = str(tmp_path / "corrupt.jsonl")
    with open(path, "w") as f:
        f.write('{"schema": "corais.trace.v1", "num_edges": 3}\n')
        f.write('{"t": 0.1, "edge": 7, "size": 0.5}\n')
    with pytest.raises(ValueError, match="edge 7 outside"):
        read_trace(path)


def test_drive_rejects_wider_trace(tmp_path):
    path = str(tmp_path / "wide.jsonl")
    record_trace(path, scenario("uniform_iid"), num_edges=8, until=1.0, seed=0)
    sim = MultiEdgeSim(SimConfig(num_edges=4, seed=0),
                       CentralController(scheduler="greedy"))
    with pytest.raises(ValueError, match="recorded on 8 edges"):
        sim.drive(read_trace(path), until=1.0)
    # a narrower trace replays fine on a wider cluster
    sim2 = MultiEdgeSim(SimConfig(num_edges=4, seed=0),
                        CentralController(scheduler="greedy"))
    path2 = str(tmp_path / "narrow.jsonl")
    record_trace(path2, scenario("uniform_iid"), num_edges=2, until=1.0, seed=0)
    m = sim2.drive(read_trace(path2), until=1.0, run_until=200.0)
    assert m["completed"] == m["submitted"] > 0


def test_replay_reproduces_live_completion_metrics(tmp_path):
    path = str(tmp_path / "replay.jsonl")
    wl = scenario("flash_crowd_10x")
    live = MultiEdgeSim(SimConfig(num_edges=4, seed=0),
                        CentralController(scheduler="greedy"))
    m_live = live.drive(wl, until=2.0, run_until=300.0)
    record_trace(path, wl, num_edges=4, until=2.0, seed=0)
    replayed = MultiEdgeSim(SimConfig(num_edges=4, seed=0),
                            CentralController(scheduler="greedy"))
    m_replay = replayed.drive(read_trace(path), until=2.0, run_until=300.0)
    assert m_live["completed"] == m_live["submitted"] > 0
    assert _completion(m_live) == _completion(m_replay)


# -- scenario-driven simulation ----------------------------------------------

@pytest.mark.parametrize("name", sorted(list_scenarios()))
def test_scenario_drives_sim_end_to_end(name):
    sim = MultiEdgeSim(SimConfig(num_edges=4, seed=1),
                       CentralController(scheduler="greedy"))
    m = sim.drive(scenario(name), until=1.5, run_until=300.0)
    assert m["submitted"] > 0
    assert m["completed"] == m["submitted"]  # nothing lost under any scenario
    assert m["decision_rounds"] >= 1
    assert m["decision_mean_s"] <= m["decision_max_s"]
    assert m["decision_p95_s"] <= m["decision_max_s"] + 1e-12


def test_total_outage_buffers_arrivals_until_recovery():
    """All edges down: arrivals wait (client retry), nothing crashes, and
    everything completes once the cluster recovers."""
    sim = MultiEdgeSim(SimConfig(num_edges=3, seed=0),
                       CentralController(scheduler="greedy"))
    for i in range(3):
        sim.fail_edge(i, t=0.5)
    sim.recover_edge(0, t=2.0)
    m = sim.drive(PoissonArrivals(rate=20.0), until=1.5, run_until=300.0)
    assert m["completed"] == m["submitted"] > 0


def test_consecutive_drives_do_not_stack_round_chains():
    sim = MultiEdgeSim(SimConfig(num_edges=3, seed=0),
                       CentralController(scheduler="greedy"))
    sim.drive(PoissonArrivals(rate=15.0), until=1.0, run_until=1.0)
    sim.drive(PoissonArrivals(rate=15.0), until=2.0, run_until=2.0)
    rounds_in_heap = sum(1 for _, _, kind, _ in sim._events
                         if kind == "round")
    assert rounds_in_heap == 1  # one chain, not one per run() call


def test_mmpp_three_state_randomized_transitions():
    wl = MMPPArrivals(rates=(5.0, 80.0, 20.0), mean_sojourn=(1.0, 0.25, 0.5))
    a1 = list(wl.arrivals(np.random.default_rng(6), 3, 20.0))
    a2 = list(wl.arrivals(np.random.default_rng(6), 3, 20.0))
    assert a1 == a2 and len(a1) > 0  # deterministic despite random jumps


def test_drive_fails_over_dead_edge_arrivals():
    sim = MultiEdgeSim(SimConfig(num_edges=4, seed=2),
                       CentralController(scheduler="greedy"))
    sim.fail_edge(1, t=0.0)
    m = sim.drive(PoissonArrivals(rate=30.0, edge_skew=64.0, hot_edge=1),
                  until=1.0, run_until=300.0)
    assert m["completed"] == m["submitted"] > 0


# -- scenario-conditioned instance sampling ----------------------------------

def test_instance_config_scenario_overrides():
    base = InstanceConfig(num_edges=5, num_requests=40)
    cfg = instance_config_for_scenario("heavy_tail_pareto", base)
    assert cfg.size_dist == "pareto"
    # purely temporal scenarios leave the static config untouched
    assert instance_config_for_scenario("diurnal", base) == base
    assert scenario_spec("hotspot_skew").instance_overrides["source_skew"] == 2.0


def test_generate_instance_pareto_sizes_and_skewed_sources():
    rng = np.random.default_rng(0)
    cfg = InstanceConfig(num_edges=5, num_requests=400,
                         size_dist="pareto", size_params=(1.5, 0.05),
                         source_skew=2.0)
    inst = generate_instance(rng, cfg)
    sizes = inst["req_size"][inst["req_mask"]]
    assert sizes.max() <= 1.0 and sizes.min() > 0
    assert np.median(sizes) < 0.2  # heavy tail: median far below cap
    srcs = inst["req_src"][inst["req_mask"]]
    share0 = np.mean(srcs == 0)
    assert share0 > 0.4  # Zipf(2) hot edge
    # determinism under fixed seed
    inst2 = generate_instance(np.random.default_rng(0), cfg)
    for k in inst:
        np.testing.assert_array_equal(inst[k], inst2[k])


def test_generate_instance_default_unchanged_fields():
    """Default config must still produce the paper's U(0,1) i.i.d. regime."""
    inst = generate_instance(np.random.default_rng(3), InstanceConfig())
    sizes = inst["req_size"][inst["req_mask"]]
    assert 0.0 < sizes.min() and sizes.max() <= 1.0
    assert abs(sizes.mean() - 0.5) < 0.1
    counts = np.bincount(inst["req_src"][inst["req_mask"]], minlength=5)
    assert counts.max() < 3 * max(counts.min(), 1)


# -- failover helper + controller remap fix ----------------------------------

def test_nearest_alive_edge_helper():
    w = np.array([[0.0, 1.0, 2.0],
                  [1.0, 0.0, 0.5],
                  [2.0, 0.5, 0.0]])
    assert nearest_alive_edge(w, 1, [True, True, True]) == 1
    assert nearest_alive_edge(w, 1, [True, False, True]) == 2
    assert nearest_alive_edge(w, 1, [True, False, False]) == 0
    with pytest.raises(RuntimeError):
        nearest_alive_edge(w, 0, [False, False, False])


def test_controller_remaps_dead_source_to_nearest_alive():
    """A request whose source edge died must be re-homed at the *nearest*
    alive edge (not alive index 0): under the 'local' policy the assignment
    equals the remapped source, which makes the remap observable."""
    sim = MultiEdgeSim(SimConfig(num_edges=3, seed=0),
                       CentralController(scheduler="local"))
    # line topology: edge1 sits next to edge2, far from edge0
    sim.w = np.array([[0.0, 10.0, 11.0],
                      [10.0, 0.0, 1.0],
                      [11.0, 1.0, 0.0]], np.float32)
    sim.edges[1].alive = False
    from repro.core.state import QueuedRequest
    req = QueuedRequest(rid=0, data_size=0.5, source_edge=1)
    (scheduled,) = sim.cc.schedule(sim.edges, [req], sim.w, ct=1.0)
    assert scheduled[0] is req
    assert scheduled[1] == 2  # nearest alive, not the old alive-index-0 bias


# -- round bucketing horizon validation --------------------------------------

class _ScriptedArrivals:
    """Fixed arrival times, all on edge 0 (for bucketing-window tests)."""

    def __init__(self, ts):
        self.ts = ts

    def arrivals(self, rng, num_edges, until):
        from repro.workloads import Arrival
        for t in self.ts:
            yield Arrival(t=t, edge=0, size=1.0)


def test_materialize_rejects_out_of_horizon_arrivals():
    """Round windows are (r*dt, (r+1)*dt]: t == 0 and t > until have no
    round to fire in and must raise, not be silently clamped into round 0
    or R-1 (the clamp rewrote the arrival's scheduling window)."""
    from repro.workloads.batch import materialize_rounds
    with pytest.raises(ValueError, match="outside the scheduling horizon"):
        materialize_rounds(_ScriptedArrivals([0.0]), 2, 4, 0.25)
    with pytest.raises(ValueError, match="outside the scheduling horizon"):
        materialize_rounds(_ScriptedArrivals([1.25]), 2, 4, 0.25)  # > until


def test_materialize_boundary_arrivals_land_in_their_window():
    """t == until is the last valid instant (closed upper edge of round
    R-1's window); exact round boundaries r*dt belong to round r-1."""
    from repro.workloads.batch import materialize_rounds
    arr = materialize_rounds(_ScriptedArrivals([0.25, 0.5, 1.0]), 2, 4, 0.25)
    assert arr["mask"][0].sum() == 1 and arr["t"][0][0] == 0.25
    assert arr["mask"][1].sum() == 1 and arr["t"][1][0] == 0.5
    assert arr["mask"][3].sum() == 1 and arr["t"][3][0] == 1.0  # t == until
