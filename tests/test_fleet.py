"""Fleet sharding, host level: Zipf partition balance/displacement, the
mergeable summary partials vs the classic ``summarize`` path, fleet rollout
on a single-device mesh, the sort-free ``stable_order``, and mesh
construction errors.

The real multi-device equivalence (8 forced host devices, psum-reduced
partials vs the vmap engine) runs as a subprocess in
tests/test_fleet_multidevice.py; everything here stays on the plain
single-device test process."""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_fleet_mesh, make_host_mesh
from repro.serving import (EngineConfig, apply_partition, fleet_summary,
                           init_batch, make_fleet_rollout, make_rollout,
                           partials_to_summary, summarize, summarize_partials,
                           zipf_partition)
from repro.serving import engine
from repro.workloads import materialize_round_batch, scenario

Q, ROUNDS, DT, B = 4, 6, 0.25, 8


def _batch(seed=0):
    arr = materialize_round_batch(scenario("uniform_iid"), Q, ROUNDS, DT, B,
                                  base_seed=seed)
    cfg = EngineConfig(num_edges=Q, num_rounds=ROUNDS, round_interval=DT,
                       max_per_round=arr["mask"].shape[-1])
    keys = np.asarray(jax.random.split(jax.random.PRNGKey(seed), B))
    return cfg, init_batch(cfg, range(B)), arr, keys


# -- stable_order (the shard_map-safe argsort) --------------------------------


def test_stable_order_matches_stable_argsort():
    """Rank-by-comparison must be bit-identical to stable argsort,
    including ties and the INF padding the lane scan relies on."""
    rng = np.random.default_rng(0)
    for keys in (
        rng.standard_normal(104).astype(np.float32),
        np.where(rng.random(64) < 0.5, engine.INF,
                 rng.random(64)).astype(np.float32),
        np.repeat(rng.standard_normal(8), 8).astype(np.float32),  # ties
        np.full(16, engine.INF, np.float32),
        np.zeros(1, np.float32),
    ):
        got = np.asarray(engine.stable_order(jnp.asarray(keys)))
        want = np.argsort(keys, kind="stable")
        np.testing.assert_array_equal(got, want)


# -- Zipf partition -----------------------------------------------------------


def test_zipf_partition_balances_skewed_homes():
    part = zipf_partition(64, 8, skew=1.2, seed=0)
    # placement is capacity-balanced: exactly B/S instances per shard
    assert np.bincount(part.shard, minlength=8).tolist() == [8] * 8
    # the placement order groups shards into contiguous blocks
    assert (np.diff(part.shard[part.order]) >= 0).all()
    rep = part.imbalance_report()
    assert rep["capacity"] == 8
    assert sum(rep["home_load"]) == sum(rep["placed_load"]) == 64
    # Zipf homes are skewed; the balancer flattens them
    assert rep["home_imbalance"] > 1.1
    assert rep["placed_imbalance"] == pytest.approx(1.0)
    # skew displaced someone, and the two displaced views agree
    assert 0 < rep["displaced_instances"] == part.displaced.sum()
    assert part.placed_displaced.sum() == part.displaced.sum()


def test_zipf_partition_rejects_indivisible_batch():
    with pytest.raises(ValueError, match="equal blocks"):
        zipf_partition(10, 4)


def test_apply_partition_reorders_leading_axis():
    part = zipf_partition(8, 2, skew=1.0, seed=2)
    tree = {"a": np.arange(8), "b": np.arange(16).reshape(8, 2)}
    out = apply_partition(part, tree)
    np.testing.assert_array_equal(out["a"], np.arange(8)[part.order])
    np.testing.assert_array_equal(out["b"],
                                  np.arange(16).reshape(8, 2)[part.order])


# -- summary partials ---------------------------------------------------------


def test_partials_match_classic_summarize():
    """The mergeable partials must reproduce the classic full-slot-table
    ``summarize`` on the same final state: counts exactly, float metrics
    to float32 tolerance, percentiles to one histogram bin."""
    cfg, states, arr, keys = _batch()
    run = make_rollout(cfg, engine.greedy_assign, batch=True)
    final, _ = run(states, arr, keys)
    want = summarize(final)
    got = partials_to_summary(summarize_partials(final))
    for k in ("completed", "submitted", "shed_requests", "dropped_requests",
              "stranded_requests", "retried_requests"):
        assert got[k] == want[k], k
    assert got["per_edge_completed"] == {
        e: c for e, c in want["per_edge_completed"].items() if c}
    for k in ("mean_response", "max_response", "makespan",
              "transferred_frac"):
        np.testing.assert_allclose(got[k], want[k], rtol=1e-5, err_msg=k)
    bin_width = engine.HIST_MAX / engine.HIST_BINS
    for k in ("p50_response", "p95_response"):
        assert abs(got[k] - want[k]) <= bin_width, k
    # no partition given: every transfer is intra-fleet
    assert got["cross_shard_transferred"] == 0
    assert got["intra_fleet_transferred"] == got["transferred_frac"] * \
        got["completed"] == pytest.approx(want["transferred_frac"]
                                          * want["completed"])


def test_fleet_rollout_single_device_mesh_matches_vmap():
    """On a 1-shard mesh the fleet path (shard_map + psum reduction) must
    reduce to exactly the vmap engine's summary, displaced accounting
    included."""
    cfg, states, arr, keys = _batch(seed=1)
    part = zipf_partition(B, 1, seed=1)  # 1 shard: nobody displaced
    run = make_rollout(cfg, engine.greedy_assign, batch=True)
    final, _ = run(states, arr, keys)
    ref = partials_to_summary(summarize_partials(final))

    mesh = make_fleet_mesh()
    frun = make_fleet_rollout(cfg, engine.greedy_assign, mesh)
    got = fleet_summary(frun(states, arr, keys, part.placed_displaced))
    assert got["completed"] == ref["completed"] > 0
    assert got["displaced_instances"] == 0
    for k in ("mean_response", "p50_response", "p95_response",
              "max_response", "makespan"):
        np.testing.assert_allclose(got[k], ref[k], rtol=1e-6, err_msg=k)
    assert got["per_edge_completed"] == ref["per_edge_completed"]


def test_fleet_rollout_rejects_indivisible_batch():
    """A batch that does not divide over the fleet axis fails loudly before
    any device work (shard_map would otherwise crash opaquely)."""
    cfg, states, arr, keys = _batch()
    mesh3 = types.SimpleNamespace(shape={"fleet": 3})  # B=8 % 3 != 0
    frun = make_fleet_rollout(cfg, engine.greedy_assign, mesh3)
    with pytest.raises(ValueError, match="does not divide"):
        frun(states, arr, keys)


# -- mesh construction --------------------------------------------------------


def test_make_host_mesh_rejects_bad_model_parallel():
    """The indivisible-device-count failure is a ValueError naming both
    numbers (it was a bare assert, which vanishes under python -O)."""
    n = len(jax.devices())
    with pytest.raises(ValueError, match=rf"{n} available device\(s\)"):
        make_host_mesh(model_parallel=n * 2)
    with pytest.raises(ValueError, match="model_parallel=0"):
        make_host_mesh(model_parallel=0)


def test_make_fleet_mesh_bounds():
    n = len(jax.devices())
    assert dict(make_fleet_mesh().shape) == {"fleet": n}
    assert dict(make_fleet_mesh(n).shape) == {"fleet": n}
    with pytest.raises(ValueError, match="fleet mesh"):
        make_fleet_mesh(n + 1)
    with pytest.raises(ValueError, match="fleet mesh"):
        make_fleet_mesh(0)
