"""Distributed correctness on a real (host-forced) 8-device mesh:

1. ``compressed_psum`` (int8 cross-pod gradient compression) sums correctly
   within its quantization error bound under shard_map.
2. A sharded ``build_train_step`` on a (4, 2) data x model mesh produces the
   same loss and updated parameters as the single-device reference step —
   the FSDP+TP sharding rules are semantics-preserving.

Runs in a subprocess because the device count must be forced before jax
initializes (the main test process keeps the real single-device view).
"""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_multidevice_semantics():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["TF_CPP_MIN_LOG_LEVEL"] = "3"
    proc = subprocess.run(
        [sys.executable, os.path.join(os.path.dirname(__file__),
                                      "multidevice_child.py")],
        capture_output=True, text=True, timeout=600, env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "MULTIDEVICE_OK" in proc.stdout, proc.stdout
