"""Online serving fast path: bucket padding, double-buffered decision loop,
SLO evaluation, and the drift-check schema compatibility."""
import importlib.util
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import InstanceConfig, generate_instance
from repro.core.inference import policy_decide
from repro.core.policy import PolicyConfig, corais_init
from repro.serving.fastpath import (DecisionFastPath, SLOSpec, evaluate_slo,
                                    pad_instance)

CFG = PolicyConfig(d_model=32, ff_hidden=64, edge_layers=2, request_layers=1)


def _inst(q, z, seed=0):
    return {k: np.asarray(v) for k, v in generate_instance(
        np.random.default_rng(seed),
        InstanceConfig(num_edges=q, num_requests=z)).items()}


@pytest.fixture(scope="module")
def policy():
    return corais_init(jax.random.PRNGKey(0), CFG)


# -- padding + buckets -------------------------------------------------------


def test_pad_instance_is_mask_preserving():
    inst = _inst(4, 6)
    padded = pad_instance(inst, 7, 11)
    assert padded["edge_mask"].shape == (7,)
    assert padded["req_mask"].shape == (11,)
    assert padded["w"].shape == (7, 7)
    np.testing.assert_array_equal(padded["edge_mask"][:4],
                                  inst["edge_mask"])
    assert not padded["edge_mask"][4:].any()
    assert not padded["req_mask"][6:].any()
    np.testing.assert_array_equal(padded["req_size"][:6], inst["req_size"])
    with pytest.raises(ValueError, match="exceeds pad"):
        pad_instance(inst, 3, 11)


def test_bucket_selection(policy):
    params, state = policy
    fp = DecisionFastPath(params, state, CFG,
                          buckets=((8, 32), (16, 64), (4, 128)))
    assert fp.bucket_for(3, 10) == (4, 128)  # sorted: smallest that fits
    assert fp.bucket_for(5, 10) == (8, 32)
    assert fp.bucket_for(9, 60) == (16, 64)
    with pytest.raises(ValueError, match="exceeds every fast-path bucket"):
        fp.bucket_for(17, 10)


# -- decision loop -----------------------------------------------------------


def test_fastpath_matches_policy_decide(policy):
    """Bucket padding + staging + fused decode must reproduce the plain
    policy_decide decision on the unpadded instance (mask invariance),
    across buckets."""
    params, state = policy
    fp = DecisionFastPath(params, state, CFG, buckets=((8, 32), (16, 64)))
    for q, z, seed in ((5, 20, 0), (8, 30, 1), (12, 50, 2)):
        inst = _inst(q, z, seed)
        got = fp.decide(inst)
        want = np.asarray(policy_decide(
            None, params, state, jax.tree.map(jnp.asarray, inst), CFG))
        assert got.shape == (z,) and got.dtype == np.int32
        np.testing.assert_array_equal(got, want, err_msg=f"q={q} z={z}")


def test_fastpath_stream_matches_sync(policy):
    """The pipelined (double-buffered) stream yields exactly the sync
    decisions, in order — staging round n+1 never corrupts round n."""
    params, state = policy
    insts = [_inst(5, 20, s) for s in range(6)]
    fp_sync = DecisionFastPath(params, state, CFG, buckets=((8, 32),))
    fp_stream = DecisionFastPath(params, state, CFG, buckets=((8, 32),))
    sync = [fp_sync.decide(i) for i in insts]
    streamed = list(fp_stream.stream(insts))
    assert len(streamed) == len(sync)
    for a, b in zip(sync, streamed):
        np.testing.assert_array_equal(a, b)


def test_fastpath_warmup_compiles_buckets(policy):
    params, state = policy
    fp = DecisionFastPath(params, state, CFG, buckets=((8, 32), (16, 64)))
    compile_ms = fp.warmup()
    assert set(compile_ms) == {(8, 32), (16, 64)}
    assert all(ms > 0 for ms in compile_ms.values())
    # warmed executables answer without recompiling (latency way under
    # compile time)
    fp.decide(_inst(5, 20))
    assert fp.latencies_ms[-1] < compile_ms[(8, 32)]


def test_fastpath_modes_and_donation_default(policy):
    params, state = policy
    # greedy default resolves normalize off; sample keeps true log-probs
    fp_g = DecisionFastPath(params, state, CFG, buckets=((8, 32),))
    assert fp_g.spec.normalize is False
    fp_s = DecisionFastPath(params, state, CFG, buckets=((8, 32),),
                            mode="sample", num_samples=8)
    assert fp_s.spec.normalize is True
    a = fp_s.decide(_inst(5, 20, 3))
    assert a.shape == (20,) and a.max() < 5
    # CPU resolves donate off automatically (jax can't donate on cpu)
    if jax.default_backend() == "cpu":
        assert fp_g.donate is False


# -- SLO ---------------------------------------------------------------------


def test_slo_spec_check():
    slo = SLOSpec(p50_ms=1.0, p95_ms=2.0, p99_ms=3.0, name="x")
    rep = slo.check([0.5] * 90 + [5.0] * 10)
    assert rep["p50_ok"] and not rep["p95_ok"] and not rep["p99_ok"]
    assert rep["pass"] is False
    assert rep["samples"] == 100
    ok = slo.check([0.5, 0.6])
    assert ok["pass"] is True
    with pytest.raises(ValueError, match="no latency samples"):
        slo.check([])


def test_evaluate_slo_report_structure(policy):
    params, state = policy
    fp = DecisionFastPath(params, state, CFG, buckets=((8, 32),))
    insts = [_inst(5, 20, s) for s in range(3)]
    rep = evaluate_slo(fp, insts, SLOSpec(1e4, 1e4, 1e4, name="test-path"))
    assert rep["pass"] is True and rep["name"] == "test-path"
    assert rep["samples"] == 3  # warmup rounds not counted
    assert rep["buckets"] == [[8, 32]]
    assert "8x32" in rep["compile_ms"]
    for p in (50, 95, 99):
        assert rep[f"p{p}_ms"] > 0 and rep[f"p{p}_slo_ms"] == 1e4


def test_evaluate_slo_warms_cold_buckets_after_partial_warmup(policy):
    """A partial warmup must not suppress warming the buckets the workload
    actually hits: previously any non-empty compile_ms skipped warmup
    entirely, so the first decision in a cold bucket paid jit compilation
    inside a measured SLO sample."""
    params, state = policy
    fp = DecisionFastPath(params, state, CFG, buckets=((8, 32), (16, 64)))
    fp.warmup([(8, 32)])  # partial: the workload's bucket stays cold
    insts = [_inst(12, 50, s) for s in range(3)]  # all land in (16, 64)
    rep = evaluate_slo(fp, insts, SLOSpec(1e4, 1e4, 1e4))
    # the hit bucket was compiled before measurement started...
    assert (16, 64) in fp.compile_ms
    # ...only the workload decisions were measured...
    assert rep["samples"] == len(insts)
    # ...and no measured sample contains the (16, 64) compile
    assert rep["p95_ms"] < fp.compile_ms[(16, 64)]


# -- drift-check schema compatibility ----------------------------------------


def _load_drift_module():
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "check_latency_drift.py")
    spec = importlib.util.spec_from_file_location("check_latency_drift", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _v1_cell(backend, q, z, p95):
    return {"backend": backend, "num_edges": q, "num_requests": z,
            "single": {"p95_ms": p95}}


def _v2_cell(backend, q, z, stage, decode, p95):
    c = _v1_cell(backend, q, z, p95)
    c.update(stage=stage, decode=decode)
    return c


def test_drift_check_reads_v1_and_v2(tmp_path):
    """The drift gate keys v1 cells as (…, 'decision', 'host'), so v1 and
    v2 reports/baselines interoperate and fused cells gate separately."""
    drift = _load_drift_module()
    v1 = {"schema": "corais.policy_latency.v1",
          "cells": [_v1_cell("pallas", 5, 20, 1.0)]}
    v2 = {"schema": "corais.policy_latency.v2",
          "cells": [_v2_cell("pallas", 5, 20, "decision", "host", 1.1),
                    _v2_cell("pallas", 5, 20, "decision", "fused", 0.4),
                    _v2_cell("pallas", 5, 20, "head", "fused", 0.1)]}
    p1, p2 = tmp_path / "v1.json", tmp_path / "v2.json"
    p1.write_text(json.dumps(v1))
    p2.write_text(json.dumps(v2))
    k1 = drift.load_report_cells(str(p1))
    k2 = drift.load_report_cells(str(p2))
    assert ("pallas", 5, 20, "decision", "host") in k1
    assert set(k1) < set(k2)

    # v2 report vs v1-schema baseline: overlapping host cell gates, fused
    # cells are new and skipped
    base = {"schema": "corais.policy_latency_baseline.v1",
            "cells": [{"backend": "pallas", "num_edges": 5,
                       "num_requests": 20, "p95_ms": 1.0}]}
    bp = tmp_path / "base.json"
    bp.write_text(json.dumps(base))
    assert drift.check(str(p2), str(bp), factor=4.0, floor_ms=0.0) == 0
    # and the gate still trips on real drift
    slow = {"schema": "corais.policy_latency.v2",
            "cells": [_v2_cell("pallas", 5, 20, "decision", "host", 99.0)]}
    ps = tmp_path / "slow.json"
    ps.write_text(json.dumps(slow))
    assert drift.check(str(ps), str(bp), factor=4.0, floor_ms=0.0) == 1


def test_drift_write_baseline_roundtrip(tmp_path):
    """write_baseline distills a v2 report into a v2 baseline whose cells
    gate that same report cleanly (including fused/head cells)."""
    drift = _load_drift_module()
    report = {"schema": "corais.policy_latency.v2",
              "cells": [_v2_cell("pallas", 5, 20, "decision", "fused", 0.4),
                        _v2_cell("pallas", 100, 1000, "head", "host", 2.2),
                        _v2_cell("xla", 5, 20, "decision", "host", 0.9)]}
    rp, bp = tmp_path / "r.json", tmp_path / "b.json"
    rp.write_text(json.dumps(report))
    drift.write_baseline(str(rp), str(bp))
    payload = json.loads(bp.read_text())
    assert payload["schema"] == "corais.policy_latency_baseline.v2"
    assert len(payload["cells"]) == 3
    assert {c["stage"] for c in payload["cells"]} == {"decision", "head"}
    assert drift.check(str(rp), str(bp), factor=4.0, floor_ms=0.0) == 0


def test_drift_check_fails_on_missing_baseline_cells(tmp_path, capsys):
    """Baseline cells absent from the fresh report fail the gate by default
    (a dropped grid point or renamed backend must not pass silently) and
    are listed; --allow-missing opts out for intentional grid shrinks."""
    drift = _load_drift_module()
    report = {"schema": "corais.policy_latency.v2",
              "cells": [_v2_cell("pallas", 5, 20, "decision", "host", 1.0)]}
    base = {"schema": "corais.policy_latency_baseline.v2",
            "cells": [{"backend": "pallas", "num_edges": 5,
                       "num_requests": 20, "stage": "decision",
                       "decode": "host", "p95_ms": 1.0},
                      {"backend": "xla", "num_edges": 100,
                       "num_requests": 1000, "stage": "decision",
                       "decode": "host", "p95_ms": 2.0}]}
    rp, bp = tmp_path / "r.json", tmp_path / "b.json"
    rp.write_text(json.dumps(report))
    bp.write_text(json.dumps(base))
    assert drift.check(str(rp), str(bp), factor=4.0, floor_ms=0.0) == 1
    out = capsys.readouterr().out
    assert "MISSING" in out and "xla" in out
    assert drift.check(str(rp), str(bp), factor=4.0, floor_ms=0.0,
                       allow_missing=True) == 0
    # a regression in a common cell still fails even with allow_missing
    slow = {"schema": "corais.policy_latency.v2",
            "cells": [_v2_cell("pallas", 5, 20, "decision", "host", 99.0)]}
    sp = tmp_path / "s.json"
    sp.write_text(json.dumps(slow))
    assert drift.check(str(sp), str(bp), factor=4.0, floor_ms=0.0,
                       allow_missing=True) == 1
