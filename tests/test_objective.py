"""Objective eqs (4)-(11)/(18)-(19): hand-computed case, np/jnp agreement,
and hypothesis invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import InstanceConfig, generate_instance, makespan, makespan_np
from repro.core.objective import per_edge_times_np


def _hand_instance():
    """2 edges, 2 requests, no backlogs; everything computable by hand."""
    return {
        "edge_coords": np.array([[0.0, 0.0], [1.0, 0.0]], np.float32),
        "phi": np.array([[1.0, 0.0], [2.0, 0.0]], np.float32),  # phi(x)=a*x
        "replicas": np.array([1.0, 2.0], np.float32),
        "workload": np.zeros((2, 3), np.float32),
        "w": np.array([[0.0, 1.0], [1.0, 0.0]], np.float32),
        "ct": np.float32(1.0),
        "req_src": np.array([0, 0], np.int32),
        "req_size": np.array([0.5, 1.0], np.float32),
        "edge_mask": np.array([True, True]),
        "req_mask": np.array([True, True]),
    }


def test_hand_computed_local():
    inst = _hand_instance()
    # both local at edge 0: mu_0 = (0.5 + 1.0)*1.0 / 1 = 1.5; T = 1.5
    assert makespan_np(inst, np.array([0, 0])) == pytest.approx(1.5)


def test_hand_computed_transfer():
    inst = _hand_instance()
    # r1 -> edge 1: edge0: mu=0.5; edge1: eta = 2*1.0/2 = 1.0,
    # kappa = ct*1.0*1.0 = 1.0, T1 = max(1.0, 0) + 1.0 = 2.0
    assert makespan_np(inst, np.array([0, 1])) == pytest.approx(2.0)
    t = per_edge_times_np(inst, np.array([0, 1]))
    assert t["mu"][0] == pytest.approx(0.5)
    assert t["eta"][1] == pytest.approx(1.0)
    assert t["kappa"][1] == pytest.approx(1.0)


def test_transfer_overlaps_compute():
    """eq (9): transfer and local compute overlap via max()."""
    inst = _hand_instance()
    inst["workload"][1, 0] = 5.0  # big local backlog at edge 1
    # r1 -> edge1: T1 = max(kappa=1.0, mu=5.0) + eta=1.0 = 6.0
    assert makespan_np(inst, np.array([0, 1])) == pytest.approx(6.0)


@hypothesis.settings(max_examples=30, deadline=None)
@hypothesis.given(seed=st.integers(0, 10_000), q=st.integers(2, 6),
                  z=st.integers(1, 12))
def test_np_jnp_agree(seed, q, z):
    rng = np.random.default_rng(seed)
    inst = generate_instance(rng, InstanceConfig(num_edges=q, num_requests=z))
    assign = rng.integers(0, q, size=inst["req_size"].shape[0]).astype(np.int32)
    c_np = makespan_np(inst, assign)
    c_j = float(makespan(jax.tree.map(jnp.asarray, inst), jnp.asarray(assign)))
    assert c_np == pytest.approx(c_j, rel=1e-4, abs=1e-4)


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(seed=st.integers(0, 10_000))
def test_monotone_in_request_set(seed):
    """Masking off any request never increases the makespan (the B&B bound's
    soundness condition)."""
    rng = np.random.default_rng(seed)
    inst = generate_instance(rng, InstanceConfig(num_edges=4, num_requests=8))
    assign = rng.integers(0, 4, size=8).astype(np.int32)
    full = makespan_np(inst, assign)
    drop = int(rng.integers(0, 8))
    sub = dict(inst)
    m = inst["req_mask"].copy()
    m[drop] = False
    sub["req_mask"] = m
    assert makespan_np(sub, assign) <= full + 1e-9


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(seed=st.integers(0, 10_000))
def test_padding_invariance(seed):
    """Embedding an instance into a larger padded frame must not change the
    objective (padded edges/requests are inert)."""
    rng = np.random.default_rng(seed)
    inst = generate_instance(rng, InstanceConfig(num_edges=3, num_requests=5))
    qp, zp = 6, 9

    def pad(a, shape, fill=0):
        out = np.full(shape, fill, a.dtype)
        out[tuple(slice(0, s) for s in a.shape)] = a
        return out

    padded = {
        "edge_coords": pad(inst["edge_coords"], (qp, 2)),
        "phi": pad(inst["phi"], (qp, 2)),
        "replicas": pad(inst["replicas"], (qp,), fill=1),
        "workload": pad(inst["workload"], (qp, 3)),
        "w": pad(inst["w"], (qp, qp)),
        "ct": inst["ct"],
        "req_src": pad(inst["req_src"], (zp,)),
        "req_size": pad(inst["req_size"], (zp,)),
        "edge_mask": pad(inst["edge_mask"], (qp,), fill=False),
        "req_mask": pad(inst["req_mask"], (zp,), fill=False),
    }
    assign = rng.integers(0, 3, size=5).astype(np.int32)
    a_pad = np.zeros(zp, np.int32)
    a_pad[:5] = assign
    assert makespan_np(inst, assign) == pytest.approx(
        makespan_np(padded, a_pad), rel=1e-5)
    j = float(makespan(jax.tree.map(jnp.asarray, padded), jnp.asarray(a_pad)))
    assert j == pytest.approx(makespan_np(inst, assign), rel=1e-4, abs=1e-4)
