"""Exact and heuristic solvers: optimality on tiny instances, feasibility,
ordering guarantees, LP export well-formedness."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import numpy as np

from repro.core import InstanceConfig, generate_instance, makespan_np
from repro.core.heuristics import solve_greedy, solve_ils, solve_local, solve_random
from repro.core.ilp import solve_branch_and_bound, solve_enumerate, write_lp


def small_instance(seed, q=3, z=5, backlog=10):
    rng = np.random.default_rng(seed)
    return generate_instance(
        rng, InstanceConfig(num_edges=q, num_requests=z, backlog_high=backlog))


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(seed=st.integers(0, 10_000))
def test_bnb_matches_enumeration(seed):
    inst = small_instance(seed)
    e = makespan_np(inst, solve_enumerate(inst))
    b = makespan_np(inst, solve_branch_and_bound(inst))
    assert b == pytest.approx(e, rel=1e-9)


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(seed=st.integers(0, 10_000))
def test_heuristics_feasible_and_ordered(seed):
    inst = small_instance(seed, q=4, z=8)
    qs = np.nonzero(inst["edge_mask"])[0]
    opt = makespan_np(inst, solve_enumerate(inst))
    for solver in (solve_local, solve_greedy,
                   lambda i: solve_random(i, 50, seed=seed)):
        a = solver(inst)
        assert set(a[np.nonzero(inst["req_mask"])[0]]) <= set(qs)
        assert makespan_np(inst, a) >= opt - 1e-9  # nothing beats the optimum


def test_ils_never_worse_than_greedy():
    inst = small_instance(7, q=5, z=20, backlog=20)
    g = makespan_np(inst, solve_greedy(inst))
    i = makespan_np(inst, solve_ils(inst, budget_s=0.5, seed=0))
    assert i <= g + 1e-9


def test_greedy_beats_local_on_hotspot():
    """All requests at one edge: greedy must spread them (paper Fig. 8)."""
    rng = np.random.default_rng(0)
    inst = generate_instance(
        rng, InstanceConfig(num_edges=5, num_requests=30, backlog_high=1))
    inst["req_src"][:] = 0
    assert makespan_np(inst, solve_greedy(inst)) < \
        makespan_np(inst, solve_local(inst))


def test_lp_export(tmp_path):
    inst = small_instance(3)
    path = str(tmp_path / "model.lp")
    write_lp(inst, path)
    text = open(path).read()
    assert text.startswith("Minimize")
    assert "Binaries" in text and text.rstrip().endswith("End")
    z = int(np.sum(inst["req_mask"]))
    assert text.count("r_one_") == z  # one assignment constraint per request
