"""Per-kernel shape/dtype sweeps vs the ref.py pure-jnp oracles
(interpret mode executes the Pallas bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(1, 128, 4, 2, 32), (2, 256, 8, 8, 64),
                                   (1, 192, 6, 2, 16)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
def test_flash_attention_sweep(dtype, shape, causal, window):
    B, S, H, KV, hd = shape
    q = jax.random.normal(KEY, (B, S, H, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd), dtype)
    bq = bk = 64
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=bq, bk=bk)
    expected = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(2, 256, 8, 2, 64), (1, 128, 4, 4, 32)])
@pytest.mark.parametrize("window", [None, 96])
def test_decode_attention_sweep(dtype, shape, window):
    B, W, H, KV, hd = shape
    q = jax.random.normal(KEY, (B, H, hd), dtype)
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, W, KV, hd), dtype)
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, W, KV, hd), dtype)
    slot = jnp.broadcast_to(jnp.arange(W)[None], (B, W)).astype(jnp.int32)
    # one sequence mid-stream, one full
    pos = jnp.asarray([W // 3] + [W - 1] * (B - 1), jnp.int32)
    out = ops.decode_attention(q, kc, vc, slot, pos, window=window, bk=64)
    expected = ref.decode_attention_ref(q, kc, vc, slot, pos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32), **_tol(dtype))


def test_decode_attention_rolling_slots():
    """Rolling cache: slot absolute positions out of order."""
    B, W, H, KV, hd = 1, 64, 4, 2, 32
    q = jax.random.normal(KEY, (B, H, hd))
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, W, KV, hd))
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, W, KV, hd))
    # positions 64..127 stored rolling: slot i holds pos 64+((i+7)%64)
    slot = ((jnp.arange(W) + 7) % W + W)[None].astype(jnp.int32)
    pos = jnp.asarray([127], jnp.int32)
    out = ops.decode_attention(q, kc, vc, slot, pos, window=32, bk=32)
    expected = ref.decode_attention_ref(q, kc, vc, slot, pos, window=32)
    np.testing.assert_allclose(out, expected, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape", [(2, 128, 64, 8), (1, 64, 128, 16)])
@pytest.mark.parametrize("chunk,bd", [(32, 32), (64, 64)])
def test_mamba_scan_sweep(shape, chunk, bd):
    b, s, d, n = shape
    u = jax.random.normal(jax.random.PRNGKey(3), (b, s, d))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(4), (b, s, d))) * 0.1
    Bm = jax.random.normal(jax.random.PRNGKey(5), (b, s, n))
    Cm = jax.random.normal(jax.random.PRNGKey(6), (b, s, n))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(7), (d, n)) * 0.2)
    y, h = ops.mamba_scan(u, dt, Bm, Cm, A, chunk=chunk, bd=bd)
    yr, hr = ref.mamba_scan_ref(u, dt, Bm, Cm, A)
    np.testing.assert_allclose(y, yr, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(h, hr, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("q,z,d", [(10, 100, 128), (5, 50, 64), (16, 37, 32)])
def test_policy_score_sweep(q, z, d):
    c = jax.random.normal(jax.random.PRNGKey(8), (q, d))
    h = jax.random.normal(jax.random.PRNGKey(9), (z, d))
    wx = jax.random.normal(jax.random.PRNGKey(10), (d, d)) * 0.05
    wy = jax.random.normal(jax.random.PRNGKey(11), (d, d)) * 0.05
    mask = jnp.asarray([True] * (q - 2) + [False] * 2)
    out = ops.policy_score(c, h, wx, wy, mask, bz=32)
    expected = ref.policy_score_ref(c, h, wx, wy, mask)
    np.testing.assert_allclose(out, expected, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.exp(np.asarray(out)).sum(-1), 1.0, rtol=1e-4)


@pytest.mark.parametrize("b,q,z,d", [(3, 10, 100, 64), (2, 7, 33, 32)])
def test_policy_score_batched_sweep(b, q, z, d):
    """Leading batch axis (grid (B, Z-blocks)) vs per-element oracle."""
    c = jax.random.normal(jax.random.PRNGKey(8), (b, q, d))
    h = jax.random.normal(jax.random.PRNGKey(9), (b, z, d))
    wx = jax.random.normal(jax.random.PRNGKey(10), (d, d)) * 0.05
    wy = jax.random.normal(jax.random.PRNGKey(11), (d, d)) * 0.05
    mask = jnp.asarray([[True] * (q - 1) + [False]] * b)
    out = ops.policy_score(c, h, wx, wy, mask, bz=32)
    expected = jnp.stack([
        ref.policy_score_ref(c[i], h[i], wx, wy, mask[i]) for i in range(b)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=2e-4, atol=2e-4)
    # and the batched xla head agrees with the same oracle
    np.testing.assert_allclose(
        np.asarray(ref.policy_score_xla(c, h, wx, wy, mask)),
        np.asarray(expected), rtol=2e-4, atol=2e-4)


def test_policy_score_custom_vjp_vs_xla_grads():
    """The fused kernel's custom VJP against autodiff through the plain
    einsum head, wrt embeddings and both projections."""
    b, q, z, d = 2, 5, 19, 16
    c = jax.random.normal(jax.random.PRNGKey(0), (b, q, d))
    h = jax.random.normal(jax.random.PRNGKey(1), (b, z, d))
    wx = jax.random.normal(jax.random.PRNGKey(2), (d, d)) * 0.1
    wy = jax.random.normal(jax.random.PRNGKey(3), (d, d)) * 0.1
    mask = jnp.asarray([True, True, True, True, False])
    w = jax.random.normal(jax.random.PRNGKey(4), (b, z, q))

    def loss(fn, c, h, wx, wy):
        return jnp.sum(jnp.exp(fn(c, h, wx, wy, mask)) * w)

    g_pal = jax.grad(lambda *a: loss(
        lambda *x: ops.policy_score(*x, bz=8), *a), (0, 1, 2, 3))(c, h, wx, wy)
    g_xla = jax.grad(lambda *a: loss(
        ref.policy_score_xla, *a), (0, 1, 2, 3))(c, h, wx, wy)
    for gp, gx, name in zip(g_pal, g_xla, ("c", "h", "wx", "wy")):
        np.testing.assert_allclose(np.asarray(gp), np.asarray(gx),
                                   rtol=1e-4, atol=1e-5, err_msg=name)


def test_policy_score_matches_network_head():
    """The fused kernel must agree with the policy network's head math."""
    import math
    d = 64
    q, z = 6, 20
    c = jax.random.normal(jax.random.PRNGKey(0), (q, d))
    h = jax.random.normal(jax.random.PRNGKey(1), (z, d))
    wx = jax.random.normal(jax.random.PRNGKey(2), (d, d)) * 0.1
    wy = jax.random.normal(jax.random.PRNGKey(3), (d, d)) * 0.1
    mask = jnp.ones((q,), bool)
    u = ((h @ wy) @ (c @ wx).T) / math.sqrt(d)
    expected = jax.nn.log_softmax(10.0 * jnp.tanh(u), axis=-1)
    out = ops.policy_score(c, h, wx, wy, mask)
    np.testing.assert_allclose(out, expected, rtol=2e-4, atol=2e-4)
