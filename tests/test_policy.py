"""CoRaiS policy network: shapes, masking, normalization, equivariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import InstanceConfig, generate_batch
from repro.core.ablations import variant_config
from repro.core.decode import (assignment_log_prob, greedy_decode,
                               sampling_decode)
from repro.core.objective import makespan
from repro.core.policy import PolicyConfig, corais_apply, corais_init
from repro.nn.module import param_count

CFG = PolicyConfig(d_model=32, ff_hidden=64, edge_layers=2, request_layers=1)


def _batch(seed=0, b=3, q=5, z=12, q_pad=None, z_pad=None):
    rng = np.random.default_rng(seed)
    batch = generate_batch(
        rng,
        InstanceConfig(num_edges=q, num_requests=z, max_edges=q_pad,
                       max_requests=z_pad),
        b)
    return jax.tree.map(jnp.asarray, batch)


def test_shapes_and_normalization():
    params, state = corais_init(jax.random.PRNGKey(0), CFG)
    batch = _batch()
    lp, _ = corais_apply(params, state, batch, CFG, training=True)
    assert lp.shape == (3, 12, 5)
    np.testing.assert_allclose(np.exp(lp).sum(-1), 1.0, rtol=1e-5)
    assert not np.any(np.isnan(np.asarray(lp)))


def test_padded_edges_get_zero_probability():
    params, state = corais_init(jax.random.PRNGKey(0), CFG)
    batch = _batch(q=4, q_pad=7, z=6, z_pad=10)
    lp, _ = corais_apply(params, state, batch, CFG, training=False)
    probs = np.exp(np.asarray(lp))
    assert probs[..., 4:].max() < 1e-6  # padded edges never selected
    np.testing.assert_allclose(probs.sum(-1), 1.0, rtol=1e-5)


def test_edge_permutation_equivariance():
    """Permuting the edge set permutes the per-request distributions (the
    attention alignment has no positional bias over edges)."""
    cfg = PolicyConfig(d_model=32, ff_hidden=64, edge_layers=2,
                       request_layers=1, norm="layer")
    params, state = corais_init(jax.random.PRNGKey(1), cfg)
    batch = _batch(b=1, q=5, z=8)
    perm = np.array([3, 1, 4, 0, 2])
    permuted = dict(batch)
    permuted["edge_coords"] = batch["edge_coords"][:, perm]
    permuted["phi"] = batch["phi"][:, perm]
    permuted["replicas"] = batch["replicas"][:, perm]
    permuted["workload"] = batch["workload"][:, perm]
    permuted["w"] = batch["w"][:, perm][:, :, perm]
    permuted["edge_mask"] = batch["edge_mask"][:, perm]
    inv = np.argsort(perm)
    permuted["req_src"] = jnp.asarray(inv)[batch["req_src"]]
    lp0, _ = corais_apply(params, state, batch, cfg, training=False)
    lp1, _ = corais_apply(params, state, permuted, cfg, training=False)
    np.testing.assert_allclose(np.asarray(lp1), np.asarray(lp0)[:, :, perm],
                               rtol=5e-3, atol=5e-3)


def test_decode_strategies():
    params, state = corais_init(jax.random.PRNGKey(0), CFG)
    batch = _batch(b=1)
    inst = jax.tree.map(lambda x: x[0], batch)
    lp, _ = corais_apply(params, state, inst, CFG, training=False)
    g = greedy_decode(lp)
    assert g.shape == (12,) and g.max() < 5
    a, cost = sampling_decode(jax.random.PRNGKey(2), inst, lp, 32)
    # sampling's best-of-n includes the greedy candidate
    assert float(cost) <= float(makespan(inst, g)) + 1e-5
    lp_assign = assignment_log_prob(lp, a, inst["req_mask"])
    assert np.isfinite(float(lp_assign))


def test_ablation_variants_param_matched():
    base = PolicyConfig(d_model=64, ff_hidden=128, edge_layers=2,
                        request_layers=2)
    counts = {}
    for v in ("corais", "fc1", "fc2", "fc3"):
        params, _ = corais_init(jax.random.PRNGKey(0), variant_config(base, v))
        counts[v] = param_count(params)
    # MLP replacement is parameter-matched to MHA (4d^2 each)
    assert len(set(counts.values())) == 1, counts


def test_paper_scale_param_count():
    params, _ = corais_init(jax.random.PRNGKey(0), PolicyConfig())
    n = param_count(params)
    assert 3e6 < n < 6e6, n  # paper: "about 4 million"
