"""Pair-scan flash attention (the jit path) vs the naive oracle, incl. the
custom VJP and padding/cross-attention edge cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (decode_attention, flash_attention,
                                    naive_attention)

KEY = jax.random.PRNGKey(0)


def _qkv(B=2, S=256, H=8, KV=4, hd=32, dtype=jnp.float32, Sk=None):
    q = jax.random.normal(KEY, (B, S, H, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Sk or S, KV, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Sk or S, KV, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None), (False, 64)])
def test_forward_matches_naive(causal, window):
    q, k, v = _qkv()
    out = flash_attention(q, k, v, chunk=64, causal=causal, window=window)
    expected = naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, expected, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [None, 64])
def test_gradients_match_naive(window):
    q, k, v = _qkv(S=128)

    def loss(fn):
        return lambda q, k, v: jnp.sum(
            fn(q, k, v) * jnp.cos(jnp.arange(q.size).reshape(q.shape)))

    gf = jax.grad(loss(lambda q, k, v: flash_attention(
        q, k, v, chunk=32, causal=True, window=window)), (0, 1, 2))(q, k, v)
    gn = jax.grad(loss(lambda q, k, v: naive_attention(
        q, k, v, causal=True, window=window)), (0, 1, 2))(q, k, v)
    for a, b in zip(gf, gn):
        np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-3)


def test_nondivisible_lengths_padded():
    q, k, v = _qkv(S=100)
    out = flash_attention(q, k, v, chunk=32, causal=True)
    expected = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(out, expected, rtol=2e-4, atol=2e-4)


def test_cross_attention_lengths():
    q, k, v = _qkv(S=64, Sk=192)
    out = flash_attention(q, k, v, chunk=32, causal=False)
    # naive with rectangular mask
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bqkgd,bmkd->bkgqm", qg, k) / np.sqrt(hd)
    p = jax.nn.softmax(s, axis=-1)
    expected = jnp.einsum("bkgqm,bmkd->bqkgd", p, v).reshape(B, S, H, hd)
    np.testing.assert_allclose(out, expected, rtol=2e-4, atol=2e-4)


def test_unroll_equals_scan():
    q, k, v = _qkv(S=128)
    a = flash_attention(q, k, v, chunk=32, causal=True, unroll=False)
    b = flash_attention(q, k, v, chunk=32, causal=True, unroll=True)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_decode_matches_last_row():
    q, k, v = _qkv(S=128)
    cache_pos = jnp.broadcast_to(jnp.arange(128)[None], (2, 128))
    pos = jnp.full((2,), 127, jnp.int32)
    out = decode_attention(q[:, -1], k, v, cache_pos, pos)
    expected = naive_attention(q, k, v, causal=True)[:, -1]
    np.testing.assert_allclose(out, expected, rtol=2e-4, atol=2e-4)
