"""Unified policy inference stack: encode/score split, backend registry
parity (xla / ref / pallas-interpret), fused-decode parity and
no-materialization guarantees, custom-VJP gradients, mask invariance under
padding, and the engine's named policy backends."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import InstanceConfig, generate_batch
from repro.core.inference import make_decision_fn, policy_decide
from repro.core.policy import (PolicyConfig, corais_apply, corais_encode,
                               corais_init, corais_score,
                               corais_score_decode, list_score_backends)
from repro.serving import engine
from repro.workloads import materialize_rounds, scenario

CFG = PolicyConfig(d_model=32, ff_hidden=64, edge_layers=2, request_layers=1)
BACKENDS = ("xla", "ref", "pallas")


def _batch(seed=0, b=3, q=5, z=12, q_pad=None, z_pad=None):
    rng = np.random.default_rng(seed)
    batch = generate_batch(
        rng,
        InstanceConfig(num_edges=q, num_requests=z, max_edges=q_pad,
                       max_requests=z_pad),
        b)
    return jax.tree.map(jnp.asarray, batch)


# -- encode/score split ------------------------------------------------------


def test_encode_score_composition_is_apply():
    params, state = corais_init(jax.random.PRNGKey(0), CFG)
    batch = _batch()
    lp_apply, st_apply = corais_apply(params, state, batch, CFG, training=True)
    c, h, st_split = corais_encode(params, state, batch, CFG, training=True)
    lp_split = corais_score(params, c, h, batch["edge_mask"], CFG)
    np.testing.assert_array_equal(np.asarray(lp_apply), np.asarray(lp_split))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), st_apply, st_split)


def test_registry_lists_all_backends_and_rejects_unknown():
    assert set(BACKENDS) <= set(list_score_backends())
    params, state = corais_init(jax.random.PRNGKey(0), CFG)
    batch = _batch(b=1)
    c, h, _ = corais_encode(params, state, batch, CFG)
    with pytest.raises(ValueError, match="unknown score backend"):
        corais_score(params, c, h, batch["edge_mask"], CFG, backend="nope")


# -- kernel parity (satellite: pallas-interpret vs ref vs xla <= 1e-5) -------


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_score_backend_parity_with_xla_head(backend):
    """Same encoder outputs through every head implementation: log-probs
    agree to <= 1e-5, batched and unbatched, mask included."""
    params, state = corais_init(jax.random.PRNGKey(0), CFG)
    batch = _batch(q=4, q_pad=6, z=9, z_pad=13)  # padded + odd Z
    c, h, _ = corais_encode(params, state, batch, CFG)
    lp_xla = corais_score(params, c, h, batch["edge_mask"], CFG, backend="xla")
    lp = corais_score(params, c, h, batch["edge_mask"], CFG, backend=backend)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lp_xla),
                               rtol=1e-5, atol=1e-5)
    # unbatched single instance through the same entry (same embeddings,
    # different backend — untrained batchnorm stats depend on batch width,
    # so the xla reference is recomputed on the unbatched encoder outputs)
    inst = jax.tree.map(lambda x: x[0], batch)
    c1, h1, _ = corais_encode(params, state, inst, CFG)
    lp1 = corais_score(params, c1, h1, inst["edge_mask"], CFG, backend=backend)
    lp1_xla = corais_score(params, c1, h1, inst["edge_mask"], CFG,
                           backend="xla")
    np.testing.assert_allclose(np.asarray(lp1), np.asarray(lp1_xla),
                               rtol=1e-5, atol=1e-5)


def test_apply_backend_kwarg_end_to_end_parity():
    params, state = corais_init(jax.random.PRNGKey(1), CFG)
    batch = _batch(seed=5)
    lps = {b: corais_apply(params, state, batch, CFG, backend=b)[0]
           for b in BACKENDS}
    for b in ("ref", "pallas"):
        np.testing.assert_allclose(np.asarray(lps[b]), np.asarray(lps["xla"]),
                                   rtol=1e-5, atol=1e-5)


# -- custom VJP (satellite: finite-difference gradient check) ----------------


def test_pallas_vjp_matches_finite_differences():
    """Central finite differences on the fused kernel's scalar loss vs the
    custom_vjp gradients, for every differentiable input."""
    from repro.kernels import ops
    q, z, d = 4, 7, 8
    c = jax.random.normal(jax.random.PRNGKey(0), (q, d))
    h = jax.random.normal(jax.random.PRNGKey(1), (z, d))
    wx = jax.random.normal(jax.random.PRNGKey(2), (d, d)) * 0.2
    wy = jax.random.normal(jax.random.PRNGKey(3), (d, d)) * 0.2
    mask = jnp.asarray([True, True, True, False])
    w = jax.random.normal(jax.random.PRNGKey(4), (z, q))

    def loss(c, h, wx, wy):
        lp = ops.policy_score(c, h, wx, wy, mask, bz=4)
        return jnp.sum(jnp.exp(lp) * w)  # bounded in every direction

    args = (c, h, wx, wy)
    grads = jax.grad(loss, argnums=(0, 1, 2, 3))(*args)
    eps = 1e-3
    rng = np.random.default_rng(0)
    for ai, g in enumerate(grads):
        g = np.asarray(g)
        for _ in range(5):  # spot-check coordinates
            idx = tuple(rng.integers(0, s) for s in g.shape)
            e = np.zeros(g.shape, np.float32)
            e[idx] = eps
            hi = list(args)
            lo = list(args)
            hi[ai] = args[ai] + e
            lo[ai] = args[ai] - e
            fd = (float(loss(*hi)) - float(loss(*lo))) / (2 * eps)
            np.testing.assert_allclose(g[idx], fd, rtol=5e-2, atol=5e-3,
                                       err_msg=f"arg {ai} coord {idx}")


def test_pallas_grads_match_xla_backend_grads():
    """grad through corais_score must agree across backends (REINFORCE
    trains through whichever head is configured)."""
    params, state = corais_init(jax.random.PRNGKey(0), CFG)
    batch = _batch(b=2, z=9)
    c, h, _ = corais_encode(params, state, batch, CFG)
    w = jax.random.normal(jax.random.PRNGKey(9), (2, 9, 5))

    def loss(c, h, backend):
        lp = corais_score(params, c, h, batch["edge_mask"], CFG,
                          backend=backend)
        return jnp.sum(jnp.exp(lp) * w)

    for backend in ("ref", "pallas"):
        gc, gh = jax.grad(lambda a, b: loss(a, b, backend), (0, 1))(c, h)
        gc0, gh0 = jax.grad(lambda a, b: loss(a, b, "xla"), (0, 1))(c, h)
        np.testing.assert_allclose(np.asarray(gc), np.asarray(gc0),
                                   rtol=1e-4, atol=1e-5, err_msg=backend)
        np.testing.assert_allclose(np.asarray(gh), np.asarray(gh0),
                                   rtol=1e-4, atol=1e-5, err_msg=backend)


def test_pallas_backend_under_vmap_and_grad():
    """The fused kernel inside vmap (the engine's instance axis) and grad
    through that vmap (temporal REINFORCE) both match the xla head."""
    params, state = corais_init(jax.random.PRNGKey(0), CFG)
    batch = _batch(b=3)
    w = jax.random.normal(jax.random.PRNGKey(7), (3, 12, 5))

    def one(inst, backend):
        c, h, _ = corais_encode(params, state, inst, CFG)
        return corais_score(params, c, h, inst["edge_mask"], CFG,
                            backend=backend)

    lp_p = jax.vmap(lambda i: one(i, "pallas"))(batch)
    lp_x = jax.vmap(lambda i: one(i, "xla"))(batch)
    np.testing.assert_allclose(np.asarray(lp_p), np.asarray(lp_x),
                               rtol=1e-5, atol=1e-5)

    def loss(params, backend):
        return jnp.sum(jnp.exp(jax.vmap(
            lambda i: one_p(params, i, backend))(batch)) * w)

    def one_p(params, inst, backend):
        c, h, _ = corais_encode(params, state, inst, CFG)
        return corais_score(params, c, h, inst["edge_mask"], CFG,
                            backend=backend)

    from jax.flatten_util import ravel_pytree
    gp = jax.grad(loss)(params, "pallas")
    gx = jax.grad(loss)(params, "xla")
    flat_p, _ = ravel_pytree(gp)
    flat_x, _ = ravel_pytree(gx)
    np.testing.assert_allclose(np.asarray(flat_p), np.asarray(flat_x),
                               rtol=1e-4, atol=1e-5)


# -- mask invariance (satellite: padding must not leak) ----------------------


def _pad_instance(inst, q_pad, z_pad):
    """Re-pad a single instance to larger (Q, Z) with zero features."""
    q = inst["edge_mask"].shape[-1]
    z = inst["req_mask"].shape[-1]
    dq, dz = q_pad - q, z_pad - z
    out = dict(inst)
    out["edge_coords"] = jnp.pad(inst["edge_coords"], ((0, dq), (0, 0)))
    out["phi"] = jnp.pad(inst["phi"], ((0, dq), (0, 0)))
    out["replicas"] = jnp.pad(inst["replicas"], (0, dq))
    out["workload"] = jnp.pad(inst["workload"], ((0, dq), (0, 0)))
    out["w"] = jnp.pad(inst["w"], ((0, dq), (0, dq)))
    out["edge_mask"] = jnp.pad(inst["edge_mask"], (0, dq))
    out["req_src"] = jnp.pad(inst["req_src"], (0, dz))
    out["req_size"] = jnp.pad(inst["req_size"], (0, dz))
    out["req_mask"] = jnp.pad(inst["req_mask"], (0, dz))
    return out


@pytest.mark.parametrize("backend", BACKENDS)
def test_mask_invariance_of_encode_and_score(backend):
    """Padding extra edges/requests onto an instance must leave the valid
    region of the embeddings and log-probs unchanged (catches -1e9 and
    masked-norm leaks through softmax/batchnorm denominators)."""
    params, state = corais_init(jax.random.PRNGKey(0), CFG)
    batch = _batch(b=1, q=4, z=6)
    inst = jax.tree.map(lambda x: x[0], batch)
    padded = _pad_instance(inst, q_pad=7, z_pad=11)

    c0, h0, _ = corais_encode(params, state, inst, CFG)
    c1, h1, _ = corais_encode(params, state, padded, CFG)
    np.testing.assert_allclose(np.asarray(c1)[:4], np.asarray(c0),
                               rtol=0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(h1)[:6], np.asarray(h0),
                               rtol=0, atol=1e-6)

    lp0 = corais_score(params, c0, h0, inst["edge_mask"], CFG,
                       backend=backend)
    lp1 = corais_score(params, c1, h1, padded["edge_mask"], CFG,
                       backend=backend)
    np.testing.assert_allclose(np.asarray(lp1)[:6, :4], np.asarray(lp0),
                               rtol=0, atol=1e-6)
    # padded edges keep zero probability for real requests
    probs = np.exp(np.asarray(lp1))
    assert probs[:6, 4:].max() < 1e-6
    # and the decision itself is identical
    g0 = np.asarray(policy_decide(None, params, state, inst, CFG,
                                  backend=backend))
    g1 = np.asarray(policy_decide(None, params, state, padded, CFG,
                                  backend=backend))
    np.testing.assert_array_equal(g1[:6], g0)


def test_mask_invariance_of_engine_assignments():
    """Widening the engine's arrival padding (max_per_round) must not move
    any real request's assignment, for the policy and greedy backends."""
    pcfg = PolicyConfig(d_model=32, ff_hidden=64, edge_layers=1,
                        request_layers=1)
    params, pstate = corais_init(jax.random.PRNGKey(0), pcfg)
    q, rounds, dt = 5, 6, 0.25
    fns = {
        "policy": engine.resolve_assign_fn(
            "policy", params=params, policy_state=pstate, policy_cfg=pcfg),
        "greedy": engine.resolve_assign_fn("greedy"),
    }
    for name, fn in fns.items():
        outs = {}
        for pad in (16, 32):
            arr = materialize_rounds(scenario("uniform_iid"), q, rounds, dt,
                                     seed=0, max_per_round=pad)
            cfg = engine.EngineConfig(num_edges=q, num_rounds=rounds,
                                      round_interval=dt, max_per_round=pad)
            run = engine.make_rollout(cfg, fn)
            final, infos = run(engine.init_state(cfg, 0), arr,
                               jax.random.PRNGKey(1))
            mask = np.asarray(arr["mask"])
            outs[pad] = np.asarray(jax.device_get(infos["assign"]))[mask]
        np.testing.assert_array_equal(outs[16], outs[32], err_msg=name)


# -- engine + controller integration -----------------------------------------


def test_policy_backend_rollout_matches_across_score_backends():
    """Full batched rollouts driven by the policy must produce identical
    assignments whichever scoring backend computes the head."""
    pcfg = PolicyConfig(d_model=32, ff_hidden=64, edge_layers=1,
                        request_layers=1)
    params, pstate = corais_init(jax.random.PRNGKey(0), pcfg)
    q, rounds, dt = 4, 4, 0.25
    arr = materialize_rounds(scenario("uniform_iid"), q, rounds, dt, seed=2)
    cfg = engine.EngineConfig(num_edges=q, num_rounds=rounds,
                              round_interval=dt,
                              max_per_round=arr["mask"].shape[-1])
    finals = {}
    for backend in BACKENDS:
        fn = engine.resolve_assign_fn(
            "policy", params=params, policy_state=pstate, policy_cfg=pcfg,
            backend=backend)
        run = engine.make_rollout(cfg, fn)
        final, infos = run(engine.init_state(cfg, 2), arr,
                           jax.random.PRNGKey(0))
        finals[backend] = jax.device_get(infos["assign"])
    for backend in ("ref", "pallas"):
        np.testing.assert_array_equal(finals[backend], finals["xla"],
                                      err_msg=backend)


def test_make_decision_fn_modes():
    params, state = corais_init(jax.random.PRNGKey(0), CFG)
    batch = _batch(b=1)
    inst = jax.tree.map(lambda x: x[0], batch)
    for mode in ("greedy", "sample"):
        decide = make_decision_fn(params, state, CFG, mode=mode,
                                  num_samples=8)
        a = np.asarray(decide(inst, jax.random.PRNGKey(0)))
        assert a.shape == (12,) and a.dtype == np.int32 and a.max() < 5
    with pytest.raises(ValueError, match="decode mode"):
        policy_decide(None, params, state, inst, CFG, mode="beam")


# -- fused decode: parity, no-materialization, sampled dispatch --------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("k,normalize", [(1, True), (1, False), (3, True)])
def test_decode_backend_parity(backend, k, normalize):
    """corais_score_decode agrees with the materialized xla decode across
    every backend: identical winner indices, values <= 1e-5, batched and
    unbatched (candidate slots only up to the real edge count — beyond it
    the kernel's output is documented undefined)."""
    params, state = corais_init(jax.random.PRNGKey(0), CFG)
    batch = _batch(q=4, q_pad=6, z=9, z_pad=13)  # padded + odd Z
    c, h, _ = corais_encode(params, state, batch, CFG)
    ti0, tv0 = corais_score_decode(params, c, h, batch["edge_mask"], CFG,
                                   k=k, normalize=normalize, backend="xla")
    ti, tv = corais_score_decode(params, c, h, batch["edge_mask"], CFG,
                                 k=k, normalize=normalize, backend=backend)
    assert ti.shape == tv.shape == batch["req_mask"].shape + (k,)
    assert ti.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(ti), np.asarray(ti0))
    np.testing.assert_allclose(np.asarray(tv), np.asarray(tv0),
                               rtol=1e-5, atol=1e-5)
    # unbatched through the same entry
    inst = jax.tree.map(lambda x: x[0], batch)
    c1, h1, _ = corais_encode(params, state, inst, CFG)
    ti1, tv1 = corais_score_decode(params, c1, h1, inst["edge_mask"], CFG,
                                   k=k, normalize=normalize, backend=backend)
    ti1x, tv1x = corais_score_decode(params, c1, h1, inst["edge_mask"], CFG,
                                     k=k, normalize=normalize, backend="xla")
    np.testing.assert_array_equal(np.asarray(ti1), np.asarray(ti1x))
    np.testing.assert_allclose(np.asarray(tv1), np.asarray(tv1x),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_decode_matches_materialized_score(backend):
    """The fused decode's top-1 must be the argmax of the materialized
    log-prob matrix, and its log-prob the gathered matrix entry."""
    params, state = corais_init(jax.random.PRNGKey(0), CFG)
    batch = _batch(b=2, q=5, z=11)
    c, h, _ = corais_encode(params, state, batch, CFG)
    lp = corais_score(params, c, h, batch["edge_mask"], CFG, backend="xla")
    ti, tv = corais_score_decode(params, c, h, batch["edge_mask"], CFG,
                                 k=1, normalize=True, backend=backend)
    np.testing.assert_array_equal(np.asarray(ti)[..., 0],
                                  np.argmax(np.asarray(lp), axis=-1))
    gathered = np.take_along_axis(np.asarray(lp), np.asarray(ti), axis=-1)
    np.testing.assert_allclose(np.asarray(tv), gathered,
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_decode_mask_and_padding_invariance(backend):
    """Bucket-padding an instance (extra masked edges and requests) must
    not move any real request's fused-decode candidates."""
    params, state = corais_init(jax.random.PRNGKey(0), CFG)
    batch = _batch(b=1, q=4, z=6)
    inst = jax.tree.map(lambda x: x[0], batch)
    padded = _pad_instance(inst, q_pad=7, z_pad=11)
    c0, h0, _ = corais_encode(params, state, inst, CFG)
    c1, h1, _ = corais_encode(params, state, padded, CFG)
    for normalize in (True, False):
        ti0, tv0 = corais_score_decode(params, c0, h0, inst["edge_mask"],
                                       CFG, k=2, normalize=normalize,
                                       backend=backend)
        ti1, tv1 = corais_score_decode(params, c1, h1, padded["edge_mask"],
                                       CFG, k=2, normalize=normalize,
                                       backend=backend)
        np.testing.assert_array_equal(np.asarray(ti1)[:6], np.asarray(ti0))
        np.testing.assert_allclose(np.asarray(tv1)[:6], np.asarray(tv0),
                                   rtol=0, atol=1e-5)
        # padded edges never win a candidate slot for real requests
        assert np.asarray(ti1)[:6].max() < 4


def test_decode_rejects_unknown_backend():
    params, state = corais_init(jax.random.PRNGKey(0), CFG)
    batch = _batch(b=1)
    c, h, _ = corais_encode(params, state, batch, CFG)
    with pytest.raises(ValueError, match="unknown decode backend"):
        corais_score_decode(params, c, h, batch["edge_mask"], CFG,
                            backend="nope")


def _jaxpr_shapes(jaxpr, acc):
    """All aval shapes in a jaxpr, recursing into sub-jaxprs (pjit bodies,
    scan/cond branches, pallas_call kernel jaxprs)."""
    def subs(val):
        if hasattr(val, "jaxpr"):  # ClosedJaxpr
            yield val.jaxpr
        elif hasattr(val, "eqns"):  # Jaxpr
            yield val
        elif isinstance(val, (list, tuple)):
            for v in val:
                yield from subs(v)

    for v in list(jaxpr.invars) + list(jaxpr.outvars) + list(jaxpr.constvars):
        aval = getattr(v, "aval", None)
        if aval is not None and hasattr(aval, "shape"):
            acc.add(tuple(aval.shape))
    for eqn in jaxpr.eqns:
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                acc.add(tuple(aval.shape))
        for val in eqn.params.values():
            for sub in subs(val):
                _jaxpr_shapes(sub, acc)
    return acc


def test_fused_decode_never_materializes_zq():
    """The tentpole guarantee, asserted on the program itself: the fused
    decode head's jaxpr contains no (Z, Q)-shaped intermediate anywhere
    (sub-jaxprs included) once the Z-block is smaller than Z, while the
    materialized host path provably does. Q and Z are chosen distinct from
    every other dimension so the shape match is unambiguous."""
    from repro.kernels import ops
    q, z, d, bz = 5, 64, 16, 32  # bz < z: full (Z, Q) can't hide in a block
    c = jax.random.normal(jax.random.PRNGKey(0), (q, d)) * 0.3
    h = jax.random.normal(jax.random.PRNGKey(1), (z, d)) * 0.3
    wx = jax.random.normal(jax.random.PRNGKey(2), (d, d)) * 0.3
    wy = jax.random.normal(jax.random.PRNGKey(3), (d, d)) * 0.3
    mask = jnp.ones(q, bool)

    fused = jax.make_jaxpr(
        lambda c, h: ops.policy_score_decode(c, h, wx, wy, mask, k=1,
                                             normalize=False, bz=bz))(c, h)
    shapes = _jaxpr_shapes(fused.jaxpr, set())
    assert (z, q) not in shapes and (q, z) not in shapes, sorted(shapes)

    # sanity: the same walk catches the materialized path red-handed
    host = jax.make_jaxpr(
        lambda c, h: jnp.argmax(ops.policy_score(c, h, wx, wy, mask),
                                axis=-1))(c, h)
    assert (z, q) in _jaxpr_shapes(host.jaxpr, set())


def test_policy_decide_fused_greedy_matches_host():
    """Same greedy decision through the fused and materialized routes, with
    and without the log-softmax normalizer, every backend."""
    params, state = corais_init(jax.random.PRNGKey(0), CFG)
    batch = _batch(b=1, q=5, z=12)
    inst = jax.tree.map(lambda x: x[0], batch)
    a0 = np.asarray(policy_decide(None, params, state, inst, CFG))
    for backend in BACKENDS:
        for normalize in (True, False):
            a = np.asarray(policy_decide(None, params, state, inst, CFG,
                                         fused_decode=True,
                                         normalize=normalize,
                                         backend=backend))
            np.testing.assert_array_equal(a, a0, err_msg=f"{backend}")


def test_policy_decide_sampled_fused_matches_dense_at_full_k():
    """With num_candidates=None (K = Q) the kernel top-k carries the whole
    categorical distribution, so the fused sampled dispatch reproduces the
    dense one draw for draw under the same key."""
    params, state = corais_init(jax.random.PRNGKey(0), CFG)
    batch = _batch(b=1, q=5, z=12)
    inst = jax.tree.map(lambda x: x[0], batch)
    for seed in (0, 1, 2):
        k = jax.random.PRNGKey(seed)
        dense = policy_decide(k, params, state, inst, CFG, mode="sample",
                              num_samples=12)
        fused = policy_decide(k, params, state, inst, CFG, mode="sample",
                              num_samples=12, fused_decode=True)
        np.testing.assert_array_equal(np.asarray(dense), np.asarray(fused))


def test_topk_sampling_distribution():
    """Seeded statistical pin of the sampled dispatch distribution.

    Exact part: at K = Q the renormalized kernel candidate set scatters
    back to exactly the dense softmax. Statistical part: empirical marginals
    of categorical draws over the (Z, K) candidate values stay within a
    small total-variation distance of the renormalized truncated
    distribution (and of the dense distribution at K = Q)."""
    params, state = corais_init(jax.random.PRNGKey(0), CFG)
    batch = _batch(b=1, q=5, z=8)
    inst = jax.tree.map(lambda x: x[0], batch)
    c, h, _ = corais_encode(params, state, inst, CFG)
    lp = np.asarray(corais_score(params, c, h, inst["edge_mask"], CFG))
    z, q = lp.shape

    ti, tv = corais_score_decode(params, c, h, inst["edge_mask"], CFG,
                                 k=q, normalize=True, backend="pallas")
    scattered = np.full((z, q), -np.inf, np.float32)
    np.put_along_axis(scattered, np.asarray(ti), np.asarray(tv), axis=-1)
    np.testing.assert_allclose(np.exp(scattered), np.exp(lp),
                               rtol=1e-5, atol=1e-5)

    for k in (3, q):
        tik, tvk = corais_score_decode(params, c, h, inst["edge_mask"], CFG,
                                       k=k, normalize=True, backend="pallas")
        n = 4000
        slots = jax.random.categorical(
            jax.random.PRNGKey(7), jnp.asarray(tvk)[None], axis=-1,
            shape=(n, z))
        draws = np.take_along_axis(np.asarray(tik)[None],
                                   np.asarray(slots)[..., None],
                                   axis=-1)[..., 0]            # (n, z)
        emp = np.stack([(draws == e).mean(axis=0) for e in range(q)], -1)
        # renormalized truncated target
        p = np.exp(np.asarray(tvk))
        target = np.zeros((z, q))
        np.put_along_axis(target, np.asarray(tik), p / p.sum(-1, keepdims=True),
                          axis=-1)
        tv_dist = 0.5 * np.abs(emp - target).sum(axis=-1)
        assert tv_dist.max() < 0.05, (k, tv_dist.max())


def test_engine_policy_fused_backend_matches_policy():
    """Full batched rollouts through ASSIGN_FNS['policy-fused'] produce the
    same assignments as the materialized policy backend."""
    pcfg = PolicyConfig(d_model=32, ff_hidden=64, edge_layers=1,
                        request_layers=1)
    params, pstate = corais_init(jax.random.PRNGKey(0), pcfg)
    q, rounds, dt = 4, 4, 0.25
    arr = materialize_rounds(scenario("uniform_iid"), q, rounds, dt, seed=2)
    cfg = engine.EngineConfig(num_edges=q, num_rounds=rounds,
                              round_interval=dt,
                              max_per_round=arr["mask"].shape[-1])
    outs = {}
    for name in ("policy", "policy-fused"):
        fn = engine.resolve_assign_fn(
            name, params=params, policy_state=pstate, policy_cfg=pcfg,
            backend="pallas")
        run = engine.make_rollout(cfg, fn)
        _, infos = run(engine.init_state(cfg, 2), arr, jax.random.PRNGKey(0))
        outs[name] = jax.device_get(infos["assign"])
    np.testing.assert_array_equal(outs["policy-fused"], outs["policy"])


def test_make_decision_fn_fused_modes():
    """The compile-once serving entry with fused_decode: both modes return
    valid assignments and greedy matches the materialized decision fn."""
    params, state = corais_init(jax.random.PRNGKey(0), CFG)
    batch = _batch(b=1)
    inst = jax.tree.map(lambda x: x[0], batch)
    host = make_decision_fn(params, state, CFG)
    for mode in ("greedy", "sample"):
        decide = make_decision_fn(params, state, CFG, mode=mode,
                                  num_samples=8, fused_decode=True,
                                  normalize=mode != "greedy")
        a = np.asarray(decide(inst, jax.random.PRNGKey(0)))
        assert a.shape == (12,) and a.dtype == np.int32 and a.max() < 5
        if mode == "greedy":
            np.testing.assert_array_equal(
                a, np.asarray(host(inst, jax.random.PRNGKey(0))))
