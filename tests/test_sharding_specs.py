"""Sharding rules: every spec must divide its dim on the production mesh —
for all 10 archs, params + optimizer states + inputs + caches.

Uses AbstractMesh so no 256 real devices are needed in unit tests.
"""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.data.synthetic import input_specs
from repro.launch.steps import TrainKnobs, param_and_opt_shapes
from repro.sharding import specs as S

def _abstract_mesh(*axes):
    try:  # jax >= 0.5: AbstractMesh(axis_sizes, axis_names)
        return AbstractMesh(tuple(s for _, s in axes),
                            tuple(n for n, _ in axes))
    except TypeError:  # jax 0.4.x: AbstractMesh(shape_tuple)
        return AbstractMesh(tuple(axes))


MESHES = {
    "single": _abstract_mesh(("data", 16), ("model", 16)),
    "multi": _abstract_mesh(("pod", 2), ("data", 16), ("model", 16)),
}


def _check_divisibility(tree, spec_tree, mesh):
    leaves = jax.tree.leaves(tree)
    specs = jax.tree.leaves(spec_tree,
                            is_leaf=lambda x: hasattr(x, "spec"))
    assert len(leaves) == len(specs)
    for leaf, ns in zip(leaves, specs):
        spec = ns.spec
        for dim, entry in zip(leaf.shape, spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            k = 1
            for a in axes:
                k *= mesh.shape[a]
            assert dim % k == 0, (leaf.shape, spec)


@pytest.mark.parametrize("mesh_name", list(MESHES))
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_and_opt_specs_divide(arch, mesh_name):
    mesh = MESHES[mesh_name]
    cfg = get_config(arch)
    params, opt = param_and_opt_shapes(cfg, TrainKnobs())
    pspecs = S.param_specs(params, cfg, mesh)
    _check_divisibility(params, pspecs, mesh)
    ospecs = S.opt_state_specs(opt, pspecs, cfg, mesh)
    _check_divisibility(opt, ospecs, mesh)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_and_cache_specs_divide(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, _ = shape_applicable(cfg, shape)
    if not ok:
        pytest.skip("documented long_500k skip")
    mesh = MESHES["single"]
    io = input_specs(cfg, shape)
    bspecs = S.batch_specs(io["batch"], cfg, shape, mesh)
    _check_divisibility(io["batch"], bspecs, mesh)
    if "cache" in io:
        cspecs = S.cache_specs(io["cache"], cfg, shape, mesh)
        _check_divisibility(io["cache"], cspecs, mesh)


def test_nonsharded_heads_for_odd_archs():
    mesh = MESHES["single"]
    cfg = get_config("hymba-1.5b")
    params, _ = param_and_opt_shapes(cfg, TrainKnobs())
    pspecs = S.param_specs(params, cfg, mesh)
    wq_spec = pspecs["layers"]["attn"]["wq"].spec
    assert "model" not in str(wq_spec)  # attention replicated over TP
    # but the MLP is still TP-sharded
    wg_spec = pspecs["layers"]["mlp"]["wg"].spec
    assert "model" in str(wg_spec)


def test_dryrun_results_if_present():
    """Integration gate: after the sweep, every non-skipped cell must be ok."""
    import json
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun.json")
    if not os.path.exists(path):
        pytest.skip("run python -m repro.launch.dryrun --all first")
    with open(path) as f:
        results = json.load(f)
    bad = [r for r in results if r["status"] == "failed"]
    assert not bad, [(r["arch"], r["shape"], r["mesh"]) for r in bad]
