"""Child process for tests/test_fleet_multidevice.py — needs 8 host
devices, which must be forced before jax initializes (subprocess, same
pattern as multidevice_child.py).

Pins the fleet-sharded rollout to the single-device vmap engine: the same
(states, arrivals, keys) batch through ``make_rollout(batch=True)`` +
``summarize_partials`` on one device and through ``make_fleet_rollout``
over an 8-shard ("fleet",) mesh must produce the same summary — counts
and histograms exactly, float reductions to 1e-5."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import numpy as np

from repro.launch.mesh import make_fleet_mesh
from repro.serving import (EngineConfig, apply_partition, init_batch,
                           make_fleet_rollout, make_rollout,
                           partials_to_summary, summarize,
                           summarize_partials, zipf_partition)
from repro.serving.engine import greedy_assign
from repro.workloads import materialize_round_batch, scenario

Q, ROUNDS, DT, B, SHARDS = 5, 8, 0.25, 16, 8


def check_fleet_matches_vmap_engine():
    assert len(jax.devices()) == 8, jax.devices()
    arr = materialize_round_batch(scenario("uniform_iid"), Q, ROUNDS, DT, B,
                                  base_seed=0)
    cfg = EngineConfig(num_edges=Q, num_rounds=ROUNDS, round_interval=DT,
                      max_per_round=arr["mask"].shape[-1])
    states = init_batch(cfg, range(B))
    keys = np.asarray(jax.random.split(jax.random.PRNGKey(0), B))
    part = zipf_partition(B, SHARDS, skew=0.9, seed=1)
    displaced = part.placed_displaced

    # single-device vmap reference (device 0), same placement order so the
    # cross-shard accounting matches too
    run = make_rollout(cfg, greedy_assign, batch=True)
    final, _ = run(apply_partition(part, states), apply_partition(part, arr),
                   apply_partition(part, keys))
    ref = partials_to_summary(summarize_partials(final, displaced=displaced))
    exact = summarize(final)  # classic full-slot-table path, count cross-check

    mesh = make_fleet_mesh()
    assert dict(mesh.shape) == {"fleet": SHARDS}, mesh
    frun = make_fleet_rollout(cfg, greedy_assign, mesh)
    got = partials_to_summary(
        frun(apply_partition(part, states), apply_partition(part, arr),
             apply_partition(part, keys), displaced))

    assert got["completed"] == ref["completed"] == exact["completed"] > 0
    assert got["submitted"] == ref["submitted"] == exact["submitted"]
    for k in ("stranded_requests", "retried_requests", "displaced_instances",
              "cross_shard_transferred", "intra_fleet_transferred",
              "cross_shard_completed", "per_edge_completed"):
        assert got[k] == ref[k], (k, got[k], ref[k])
    assert got["per_edge_completed"] == {
        e: c for e, c in exact["per_edge_completed"].items() if c}
    for k in ("mean_response", "max_response", "makespan",
              "transferred_frac", "p50_response", "p95_response"):
        np.testing.assert_allclose(got[k], ref[k], rtol=1e-5, atol=1e-7,
                                   err_msg=k)
    # the skewed partition really displaced someone, so the cross-shard
    # split is exercised, not vacuously zero
    assert got["displaced_instances"] > 0
    assert got["cross_shard_transferred"] > 0
    print("fleet==vmap summaries ok", got["completed"], got["mean_response"])


def check_subset_mesh_scaling_shards():
    """2-shard subset mesh on the same 8-device host also agrees (the
    scaling-curve path in benchmarks/rollout_throughput.py --fleet)."""
    arr = materialize_round_batch(scenario("uniform_iid"), Q, ROUNDS, DT, B,
                                  base_seed=3)
    cfg = EngineConfig(num_edges=Q, num_rounds=ROUNDS, round_interval=DT,
                      max_per_round=arr["mask"].shape[-1])
    states = init_batch(cfg, range(B))
    keys = np.asarray(jax.random.split(jax.random.PRNGKey(3), B))
    run = make_rollout(cfg, greedy_assign, batch=True)
    final, _ = run(states, arr, keys)
    ref = partials_to_summary(summarize_partials(final))

    mesh2 = make_fleet_mesh(2)
    got = partials_to_summary(
        make_fleet_rollout(cfg, greedy_assign, mesh2)(states, arr, keys))
    assert got["completed"] == ref["completed"]
    np.testing.assert_allclose(got["mean_response"], ref["mean_response"],
                               rtol=1e-5)
    assert got["p95_response"] == ref["p95_response"]
    print("2-shard subset mesh ok", got["completed"])


if __name__ == "__main__":
    check_fleet_matches_vmap_engine()
    check_subset_mesh_scaling_shards()
    print("FLEET_MULTIDEVICE_OK")
