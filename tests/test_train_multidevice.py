"""Data-parallel temporal training equivalence on a host-forced 8-device
mesh:

the scanned-epoch REINFORCE step (device-generated episodes, per-element
PRNG keys) run on one device and shard_map'd over an 8-shard ("fleet",)
mesh with pmean-averaged grads must produce the same params / opt state
to 1e-5 (and metrics to 1e-4), for layer norm, warmed batch norm, and a
faulted chaos scenario; the full ``temporal_train(mesh=...)`` loop must
match the meshless epoch loop batch-for-batch too.

Runs in a subprocess because the device count must be forced before jax
initializes (the main test process keeps the real single-device view)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_train_multidevice_equivalence():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["TF_CPP_MIN_LOG_LEVEL"] = "3"
    proc = subprocess.run(
        [sys.executable, os.path.join(os.path.dirname(__file__),
                                      "train_child.py")],
        capture_output=True, text=True, timeout=600, env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "TRAIN_MULTIDEVICE_OK" in proc.stdout, proc.stdout
