"""Roofline machinery: XLA FLOP convention calibration, HLO collective
parsing, term arithmetic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import CellReport
from repro.roofline.hlo_parse import collective_wire_bytes, count_ops
from repro.roofline.hw import HW


def test_xla_flop_convention_is_2mnk():
    f = jax.jit(lambda a, b: a @ b)
    low = f.lower(jax.ShapeDtypeStruct((256, 512), jnp.float32),
                  jax.ShapeDtypeStruct((512, 128), jnp.float32))
    ca = low.compile().cost_analysis()
    if isinstance(ca, list):  # pre-0.5 jax returns one dict per computation
        ca = ca[0]
    assert ca["flops"] == pytest.approx(2 * 256 * 512 * 128, rel=0.01)


HLO = """\
ENTRY %main {
  %p0 = f32[16,128]{1,0} parameter(0)
  %all-reduce.1 = f32[16,128]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %all-gather.2 = bf16[64,128]{1,0} all-gather(%x), replica_groups=[4,16]<=[64], dimensions={0}
  %reduce-scatter.3 = f32[4,128]{1,0} reduce-scatter(%y), replica_groups={{0,1},{2,3}}, dimensions={0}
  %cp = f32[8]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %all-reduce-start.9 = f32[10]{0} all-reduce-start(%w), replica_groups={{0,1,2,3,4}}
}
"""


def test_collective_parse_counts():
    counts = count_ops(HLO)
    assert counts == {"all-reduce": 2, "all-gather": 1,
                      "reduce-scatter": 1, "collective-permute": 1}


def test_collective_wire_bytes():
    wire = collective_wire_bytes(HLO)
    # all-reduce.1: 16*128*4 = 8192 bytes, n=4 -> 2*(3/4)*8192 = 12288
    assert wire["all-reduce"] == pytest.approx(
        12288 + 10 * 4 * 2 * (4 / 5), rel=1e-6)
    # all-gather: 64*128*2 = 16384, n=16 -> *(15/16)
    assert wire["all-gather"] == pytest.approx(16384 * 15 / 16, rel=1e-6)
    # reduce-scatter: result 4*128*4=2048, n=2 -> *(n-1) = 2048
    assert wire["reduce-scatter"] == pytest.approx(2048, rel=1e-6)
    assert wire["collective-permute"] == pytest.approx(32, rel=1e-6)
    assert wire["_total"] == pytest.approx(
        sum(v for k, v in wire.items() if not k.startswith("_")), rel=1e-9)


def test_cell_report_terms():
    r = CellReport(
        arch="x", shape="train_4k", mesh="single", chips=256,
        hlo_flops_per_device=HW.peak_flops_bf16,      # exactly 1s of compute
        hlo_bytes_per_device=HW.hbm_bw / 2,           # 0.5s of memory
        wire_bytes_per_device=HW.ici_link_bw / 4,     # 0.25s of collective
        collective_ops={}, collective_breakdown={},
        temp_bytes_per_device=0, arg_bytes_per_device=0, out_bytes_per_device=0,
        model_flops=HW.peak_flops_bf16 * 256 * 0.8,
        params_total=1e9, params_active=1e9, compile_seconds=1.0)
    t = r.terms()
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(0.5)
    assert t["collective_s"] == pytest.approx(0.25)
    assert t["dominant"] == "compute"
    assert t["useful_flop_ratio"] == pytest.approx(0.8)
    assert t["roofline_fraction"] == pytest.approx(1.0)
