"""Serving runtime: completion, fault tolerance, straggler diversion, phi
fitting, snapshot faithfulness to the live queue state."""
import numpy as np
import pytest

from repro.core.state import PhiEstimator, QueuedRequest, snapshot_instance
from repro.serving import CentralController, MultiEdgeSim, SimConfig


def _workload(sim, n=100, seed=0, window=2.0, edge=None):
    rng = np.random.default_rng(seed)
    for _ in range(n):
        src = edge if edge is not None else int(rng.integers(0, sim.cfg.num_edges))
        sim.submit(src, float(rng.uniform(0.1, 1.0)),
                   t=float(rng.uniform(0, window)))


def test_all_requests_complete():
    sim = MultiEdgeSim(SimConfig(num_edges=5, seed=0),
                       CentralController(scheduler="greedy"))
    _workload(sim, 120)
    m = sim.run(until=120.0)
    assert m["completed"] == 120
    assert m["mean_response"] > 0


def test_scheduler_beats_local():
    results = {}
    for sched in ("local", "greedy"):
        sim = MultiEdgeSim(SimConfig(num_edges=5, seed=3),
                           CentralController(scheduler=sched))
        _workload(sim, 100, seed=3, edge=0)  # hotspot at edge 0
        results[sched] = sim.run(until=300.0)
    assert results["greedy"]["completed"] == 100
    assert results["local"]["completed"] == 100
    assert results["greedy"]["mean_response"] < results["local"]["mean_response"]


def test_edge_failure_requeues_everything():
    sim = MultiEdgeSim(SimConfig(num_edges=5, seed=0),
                       CentralController(scheduler="greedy"))
    _workload(sim, 120)
    sim.fail_edge(0, t=1.0)
    m = sim.run(until=240.0)
    assert m["completed"] == 120  # nothing lost


def test_straggler_diversion():
    """Workload perception (paper §V-B3 WP): a 10x-slowed edge should
    receive a small share even though all requests arrive there."""
    sim = MultiEdgeSim(SimConfig(num_edges=5, seed=1),
                       CentralController(scheduler="greedy"))
    sim.set_straggler(1, 10.0, t=0.0)
    _workload(sim, 100, seed=1, edge=1)
    m = sim.run(until=300.0)
    assert m["completed"] == 100
    assert m["per_edge_completed"][1] < 50


def test_phi_estimator_recovers_coefficients():
    est = PhiEstimator()
    rng = np.random.default_rng(0)
    for _ in range(64):
        x = rng.uniform(0.1, 2.0)
        est.observe(x, 0.7 * x + 0.3 + rng.normal(0, 0.005))
    a, b = est.coefficients
    assert a == pytest.approx(0.7, abs=0.05)
    assert b == pytest.approx(0.3, abs=0.05)


def test_phi_estimator_running_sums_match_polyfit():
    """The O(1) running-sum fit must equal np.polyfit over the window at
    every step, including after the window starts evicting samples."""
    est = PhiEstimator(min_samples=4, window=64)
    rng = np.random.default_rng(1)
    xs, ys = [], []
    for i in range(200):
        x = float(rng.uniform(0.1, 2.0))
        y = float(0.6 * x + 0.2 + rng.normal(0, 0.01))
        xs.append(x)
        ys.append(y)
        est.observe(x, y)
        if i + 1 >= est.min_samples:
            a, b = np.polyfit(xs[-est.window:], ys[-est.window:], 1)
            assert est.a == pytest.approx(float(a), rel=1e-6, abs=1e-9)
            assert est.b == pytest.approx(float(max(b, 0.0)), rel=1e-6,
                                          abs=1e-9)


def test_phi_estimator_frozen():
    est = PhiEstimator(a=0.4, b=0.1, frozen=True)
    for _ in range(32):
        est.observe(1.0, 5.0)
    assert est.coefficients == (0.4, 0.1)


def test_phi_estimator_degenerate_history():
    est = PhiEstimator(a=2.0, b=0.5)
    for _ in range(20):
        est.observe(1.0, 2.5)  # constant sizes: fit would be singular
    assert est.coefficients == (2.0, 0.5)  # unchanged, no warnings


def test_snapshot_matches_queue_contents():
    from repro.serving.edge import SimEdge
    e = SimEdge(edge_id=0, coords=(0.0, 0.0), true_a=1.0, true_b=0.0,
                replicas=2, rng=np.random.default_rng(0))
    e.state.phi.a, e.state.phi.b = 1.0, 0.0
    e.state.q_le = [QueuedRequest(rid=1, data_size=2.0, source_edge=0)]
    e.state.q_in = [QueuedRequest(rid=2, data_size=1.0, source_edge=1)]
    w = np.array([[0.0, 3.0], [3.0, 0.0]], np.float32)
    inst = snapshot_instance([e.state], [], w[:1, :1], ct=1.0,
                             w_global=w, z_pad=1)
    # eq (1): c_le = phi(2.0)/2 = 1.0 ; eq (3): c_in = phi(1.0)/2 = 0.5
    # eq (2): t_in = ct * 1.0 * w[1,0] = 3.0
    np.testing.assert_allclose(inst["workload"][0], [1.0, 0.5, 3.0], rtol=1e-6)


def test_corais_policy_controller_runs():
    """Untrained policy through the full serving loop (correct plumbing)."""
    import jax
    from repro.core.policy import PolicyConfig, corais_init
    pcfg = PolicyConfig(d_model=32, ff_hidden=64, edge_layers=1,
                        request_layers=1)
    params, state = corais_init(jax.random.PRNGKey(0), pcfg)
    cc = CentralController(scheduler="corais", policy_params=params,
                           policy_state=state, policy_cfg=pcfg, z_pad=32)
    sim = MultiEdgeSim(SimConfig(num_edges=4, seed=0), cc)
    _workload(sim, 40)
    m = sim.run(until=240.0)
    assert m["completed"] == 40
    assert cc.last_decision_time < 1.0
