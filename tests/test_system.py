"""End-to-end behaviour of the paper's system: train a (miniature) CoRaiS
scheduler, drive the full multi-edge serving loop with it, and check the
paper's headline claims at small scale: real-time decisions, quality above
the non-learning baselines, resilience to failure."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import InstanceConfig, PolicyConfig, generate_instance
from repro.core.decode import sampling_decode
from repro.core.heuristics import solve_local, solve_random
from repro.core.objective import makespan_np
from repro.core.policy import corais_apply
from repro.core.train import RLConfig, train
from repro.serving import CentralController, MultiEdgeSim, SimConfig

_CFG = RLConfig(
    policy=PolicyConfig(d_model=32, ff_hidden=64, edge_layers=2,
                        request_layers=1),
    instance=InstanceConfig(num_edges=4, num_requests=12, backlog_high=8),
    batch_size=32, num_samples=16, lr=1e-3, num_batches=50, seed=0)

_TRAINED = {}


def _trained():
    if not _TRAINED:
        params, state, _, hist = train(_CFG)
        _TRAINED.update(params=params, state=state, hist=hist)
    return _TRAINED


def test_end_to_end_scheduling_quality():
    """CoRaiS(sampling) beats Local and Random(1) on held-out instances
    (the qualitative Table-II ordering)."""
    t = _trained()
    rng = np.random.default_rng(42)
    key = jax.random.PRNGKey(0)
    wins_local, wins_rand = 0, 0
    n = 16
    for i in range(n):
        inst = generate_instance(rng, _CFG.instance)
        jinst = jax.tree.map(jnp.asarray, inst)
        lp, _ = corais_apply(t["params"], t["state"], jinst, _CFG.policy,
                             training=False)
        key, sub = jax.random.split(key)
        assign, cost = sampling_decode(sub, jinst, lp, 64)
        cost = makespan_np(inst, np.asarray(assign))
        wins_local += cost <= makespan_np(inst, solve_local(inst)) + 1e-9
        wins_rand += cost <= makespan_np(inst, solve_random(inst, 1, seed=i)) + 1e-9
    assert wins_local >= 0.75 * n, wins_local
    assert wins_rand >= 0.75 * n, wins_rand


def test_end_to_end_serving_with_trained_policy_and_failure():
    """The trained policy drives the live serving loop through an edge
    failure without losing requests."""
    t = _trained()
    cc = CentralController(scheduler="corais", policy_params=t["params"],
                           policy_state=t["state"], policy_cfg=_CFG.policy,
                           z_pad=32)
    sim = MultiEdgeSim(SimConfig(num_edges=4, seed=0), cc)
    rng = np.random.default_rng(0)
    for _ in range(60):
        sim.submit(int(rng.integers(0, 4)), float(rng.uniform(0.1, 1.0)),
                   t=float(rng.uniform(0, 2.0)))
    sim.fail_edge(0, t=1.0)
    m = sim.run(until=300.0)
    assert m["completed"] == 60
    assert cc.last_decision_time < 1.0  # real-time even on one CPU core
