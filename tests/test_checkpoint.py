"""Checkpointer: atomicity, keep-K, resume extras, elastic-style restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer, restore_pytree, save_pytree


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 4)),
            "nested": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
            "scalar": jnp.asarray(3)}


def test_roundtrip(tmp_path):
    tree = _tree()
    d = str(tmp_path / "ckpt")
    save_pytree(tree, d, extras={"step": 7})
    restored, extras = restore_pytree(jax.eval_shape(lambda: tree), d)
    assert extras == {"step": 7}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not os.path.exists(d + ".tmp")  # atomic rename cleaned up


def test_keep_k_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), every=1, keep=2, async_save=False)
    for step in (1, 2, 3, 4):
        ck.save(step, _tree(step))
    assert ck.latest_step() == 4
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(dirs) == 2  # keep-K retention
    out = ck.restore_latest(jax.eval_shape(lambda: _tree()))
    assert out["step"] == 4


def test_async_save_then_restore(tmp_path):
    ck = Checkpointer(str(tmp_path), every=1, keep=3, async_save=True)
    tree = _tree(5)
    ck.save(10, tree, extras={"pipeline": {"seed": 1, "step": 42}})
    ck.wait()
    out = ck.restore_latest(jax.eval_shape(lambda: tree))
    assert out["extras"]["pipeline"]["step"] == 42
    np.testing.assert_array_equal(np.asarray(out["tree"]["a"]),
                                  np.asarray(tree["a"]))


def test_restore_rejects_shape_mismatch(tmp_path):
    d = str(tmp_path / "ckpt")
    save_pytree(_tree(), d)
    bad = jax.eval_shape(lambda: {"a": jnp.zeros((9, 4)),
                                  "nested": {"b": jnp.zeros((2, 3))},
                                  "scalar": jnp.asarray(0)})
    try:
        restore_pytree(bad, d)
        raise AssertionError("expected ValueError")
    except ValueError:
        pass


def test_missing_returns_none(tmp_path):
    ck = Checkpointer(str(tmp_path / "nope"), every=1)
    assert ck.restore_latest(jax.eval_shape(lambda: _tree())) is None
