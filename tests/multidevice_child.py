"""Child process for tests/test_multidevice.py — needs 8 host devices,
which must be forced before jax initializes (hence the subprocess)."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

try:
    shard_map = jax.shard_map
except AttributeError:  # pre-0.5 jax keeps it in jax.experimental
    from jax.experimental.shard_map import shard_map

from repro.configs import get_reduced_config
from repro.configs.base import ShapeConfig
from repro.data.synthetic import SyntheticTokens
from repro.launch.steps import TrainKnobs, build_train_step
from repro.models import lm
from repro.optim import AdamConfig, adam_init, adam_update, clip_by_global_norm
from repro.optim.grad_utils import compressed_psum


def check_compressed_psum():
    mesh = jax.make_mesh((8,), ("data",))
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64)) * 3.0

    def body(xs):
        return compressed_psum(xs, "data", 8)

    out = jax.jit(shard_map(body, mesh=mesh, in_specs=P("data", None),
                            out_specs=P("data", None)))(x)
    expected = jnp.broadcast_to(jnp.sum(x, axis=0, keepdims=True), x.shape)
    err = float(jnp.abs(out - expected).max())
    # int8 absmax quantization: per-element error <= shards * scale/2
    scale = float(jnp.max(jnp.abs(x)) / 127.0)
    assert err <= 8 * scale / 2 + 1e-6, (err, scale)
    print("compressed_psum ok", err)


def check_sharded_train_equivalence():
    cfg = get_reduced_config("olmo-1b")
    shape = ShapeConfig("tiny_train", seq_len=32, global_batch=8, kind="train")
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    knobs = TrainKnobs(lr=1e-2, donate=False)
    step, _, _ = build_train_step(cfg, mesh, shape, knobs)

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    adam = AdamConfig(lr=knobs.lr)
    opt = adam_init(params, adam)
    pipe = SyntheticTokens(cfg.vocab_size, 8, 32, seed=3)
    batch = jax.tree.map(jnp.asarray, next(pipe))

    with mesh:
        p1, o1, metrics = step(params, opt, batch)
    sharded_loss = float(metrics["loss_total"])

    # plain single-device reference step
    def ref_step(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: lm.train_loss(p, batch, cfg, 1), has_aux=True)(params)
        grads, _ = clip_by_global_norm(grads, knobs.grad_clip)
        params, opt_state = adam_update(params, grads, opt_state, adam)
        return params, opt_state, loss

    p2, o2, ref_loss = jax.jit(ref_step)(params, opt, batch)
    assert abs(sharded_loss - float(ref_loss)) < 1e-3, (sharded_loss, float(ref_loss))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-4)
    print("sharded==single train step ok", sharded_loss)


if __name__ == "__main__":
    check_compressed_psum()
    check_sharded_train_equivalence()
    print("MULTIDEVICE_OK")
