"""Child process for tests/test_train_multidevice.py — needs 8 host
devices, which must be forced before jax initializes (subprocess, same
pattern as fleet_child.py).

Pins data-parallel temporal training to single-device training: the same
scanned-epoch step (device-generated episodes, per-element PRNG keys) run
on one device and shard_map'd over an 8-shard ("fleet",) mesh with
pmean-averaged grads must produce the same updated params / opt state /
metrics to 1e-5 (float reassociation across the psum is the only
difference), and the full ``temporal_train(mesh=...)`` loop must match the
meshless epoch loop on its history too.

Normalization caveat this test pins around: with ``norm="batch"`` and a
never-trained norm state, eval-mode batchnorm falls back to statistics of
the *local* batch (nn.layers.batchnorm_apply), which couples elements —
per-shard stats differ from global-batch stats, so exact shard parity
holds only for decoupled normalization: ``norm="layer"``, or batch norm
with populated running stats (count > 0), which is what warm-started
training (get_resilient_policy / get_cloud_policy) uses.

Tolerance note: per-element REINFORCE grads nearly cancel, so the
batch-mean grad can be small relative to its summands and the
single-reduce vs psum reassociation noise is then a sizable *fraction*
of it; Adam's ``g / (sqrt(v) + eps)`` normalization amplifies that
fraction to O(lr) parameter noise (a sign flip of a near-zero gradient
moves the update by 2*lr).  The test adam uses ``eps=1e-3`` so
near-zero gradients update ~linearly in g instead of sign-like, putting
the noise floor orders of magnitude under the 1e-5 pin without
weakening the structural property being checked (identical episodes,
pmean'd grads, identical update rule)."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses

import jax
import numpy as np

from repro.core import PolicyConfig
from repro.core.train import (TemporalRLConfig, make_temporal_epoch_step,
                              temporal_train)
from repro.launch.mesh import make_fleet_mesh
from repro.optim import AdamConfig, adam_init
from repro.serving.engine import EngineConfig

B, K = 8, 2


def base_cfg(scenario: str, norm: str = "layer") -> TemporalRLConfig:
    return TemporalRLConfig(
        policy=PolicyConfig(d_model=32, ff_hidden=64, edge_layers=1,
                            request_layers=1, norm=norm),
        engine=EngineConfig(num_edges=3, num_rounds=4, max_per_round=8),
        scenario=scenario,
        batch_size=B, lr=2e-5, num_batches=2 * K, seed=0,
        device_episodes=True, epoch_len=K)


def tree_close(a, b, tol):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float64),
                                   np.asarray(y, np.float64),
                                   rtol=tol, atol=tol)


def warm_norm_state(state):
    """Mark every batchnorm layer as trained (count=1, mean 0 / var 1) so
    eval-mode BN uses the stored per-element statistics."""
    from jax.tree_util import tree_map_with_path

    def bump(path, x):
        return np.ones_like(x) if str(path[-1]) == "['count']" else x

    return tree_map_with_path(bump, state)


def check_sharded_step_matches_single(scenario: str, norm: str = "layer"):
    assert len(jax.devices()) == 8, jax.devices()
    cfg = base_cfg(scenario, norm)
    mesh = make_fleet_mesh()
    assert dict(mesh.shape) == {"fleet": 8}, mesh

    from repro.core.policy import corais_init
    from repro.serving import engine as engine_lib
    from repro.core.train import _cluster_seeds, _element_keys

    params, state = corais_init(jax.random.PRNGKey(0), cfg.policy)
    if norm == "batch":
        state = warm_norm_state(state)
    adam = AdamConfig(lr=cfg.lr, eps=1e-3)
    opt = adam_init(params, adam)
    ecfg = cfg.engine
    stacks = [engine_lib.init_batch(ecfg, _cluster_seeds(cfg, bi))
              for bi in range(K)]
    sim0 = {k: np.stack([s[k] for s in stacks]) for k in stacks[0]}
    key = jax.random.PRNGKey(cfg.seed)
    ekeys = np.stack([np.asarray(_element_keys(key, bi, B))
                      for bi in range(K)])

    single, _ = make_temporal_epoch_step(cfg, adam)
    sharded, _ = make_temporal_epoch_step(cfg, adam, mesh=mesh)
    p1, o1, m1 = single(params, state, opt, sim0, ekeys)
    p2, o2, m2 = sharded(params, state, opt, sim0, ekeys)
    tree_close(p1, p2, 1e-5)
    tree_close(o1, o2, 1e-5)
    for k in m1:
        np.testing.assert_allclose(np.asarray(m1[k]), np.asarray(m2[k]),
                                   rtol=1e-4, atol=1e-5, err_msg=k)
    print(f"sharded step == single step ({scenario}, norm={norm}): "
          f"loss {np.asarray(m1['loss'])} vs {np.asarray(m2['loss'])}")


def check_sharded_train_loop_matches(scenario: str):
    cfg = base_cfg(scenario)
    adam = AdamConfig(lr=cfg.lr, eps=1e-3)
    p1, _, o1, h1 = temporal_train(cfg, adam_cfg=adam)
    p2, _, o2, h2 = temporal_train(cfg, mesh=make_fleet_mesh(), adam_cfg=adam)
    tree_close(p1, p2, 1e-5)
    assert [h["batch"] for h in h1] == [h["batch"] for h in h2]
    for a, b in zip(h1, h2):
        np.testing.assert_allclose(a["cost_mean"], b["cost_mean"],
                                   rtol=1e-4, atol=1e-5)
    print(f"temporal_train(mesh) == temporal_train() ({scenario}): "
          f"final cost {h1[-1]['cost_mean']:.6f}")


if __name__ == "__main__":
    check_sharded_step_matches_single("uniform_iid")
    check_sharded_step_matches_single("uniform_iid", norm="batch")
    check_sharded_step_matches_single("chaos-straggler-storm")
    check_sharded_train_loop_matches("uniform_iid")
    print("TRAIN_MULTIDEVICE_OK")
