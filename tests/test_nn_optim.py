"""nn substrate + optimizers."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import (batchnorm_apply, batchnorm_init, layernorm_apply,
                      layernorm_init, linear_apply, linear_init, mha_apply,
                      mha_init, nonparametric_layernorm, rmsnorm_apply,
                      rmsnorm_init)
from repro.optim import (AdafactorConfig, AdamConfig, adafactor_init,
                         adafactor_update, adam_init, adam_update,
                         clip_by_global_norm, dequantize_int8, global_norm,
                         quantize_int8, warmup_cosine)


def test_linear_init_bounds():
    p = linear_init(jax.random.PRNGKey(0), 64, 32)
    bound = 1 / np.sqrt(64)
    assert np.abs(np.asarray(p["w"])).max() <= bound
    assert p["w"].shape == (64, 32)


def test_mha_masking():
    p = mha_init(jax.random.PRNGKey(0), 16, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 16))
    mask = jnp.ones((2, 1, 5, 5), bool).at[:, :, :, 3:].set(False)
    out = mha_apply(p, x, mask=mask, num_heads=4)
    # perturbing masked-out tokens must not change outputs of attended ones
    x2 = x.at[:, 3:].add(10.0)
    out2 = mha_apply(p, x2, mask=mask, num_heads=4)
    np.testing.assert_allclose(out[:, :3], out2[:, :3], rtol=1e-4, atol=1e-5)


def test_batchnorm_running_stats():
    params, state = batchnorm_init(4)
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 7, 4)) * 3 + 1
    y, state = batchnorm_apply(params, state, x, training=True)
    assert float(state["count"]) == 1
    np.testing.assert_allclose(np.asarray(y).mean(axis=(0, 1)), 0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y).std(axis=(0, 1)), 1, atol=1e-2)
    # eval mode uses running stats, not batch stats
    y2, _ = batchnorm_apply(params, state, x[:1], training=False)
    assert np.isfinite(np.asarray(y2)).all()


def test_norms_basic():
    p = layernorm_init(8)
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 8)) * 5
    np.testing.assert_allclose(np.asarray(layernorm_apply(p, x)).mean(-1), 0,
                               atol=1e-4)
    r = rmsnorm_init(8)
    y = rmsnorm_apply(r, x)
    np.testing.assert_allclose(
        np.sqrt((np.asarray(y, np.float64) ** 2).mean(-1)), 1, atol=1e-2)
    z = nonparametric_layernorm(x)
    np.testing.assert_allclose(np.asarray(z).mean(-1), 0, atol=1e-4)


def test_adam_single_step_analytic():
    cfg = AdamConfig(lr=0.1, b1=0.9, b2=0.999, eps=1e-8)
    params = {"w": jnp.asarray([2.0])}
    grads = {"w": jnp.asarray([0.5])}
    opt = adam_init(params, cfg)
    new, opt = adam_update(params, grads, opt, cfg)
    # step 1: mhat = g, vhat = g^2 -> update = lr * g/(|g|+eps) = lr*sign(g)
    assert float(new["w"][0]) == pytest.approx(2.0 - 0.1, rel=1e-5)


def test_adafactor_converges_quadratic():
    cfg = AdafactorConfig(lr=0.3)
    target = jnp.ones((256, 256))
    params = {"w": jnp.zeros((256, 256))}
    opt = adafactor_init(params, cfg)

    @jax.jit
    def step(params, opt):
        loss, g = jax.value_and_grad(
            lambda p: jnp.mean((p["w"] - target) ** 2))(params)
        params, opt = adafactor_update(params, g, opt, cfg)
        return params, opt, loss

    losses = []
    for _ in range(40):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < 0.25 * losses[0]
    # factored slots only (no full second moment for a 256x256 matrix)
    assert set(opt["v"]["w"].keys()) == {"vr", "vc"}


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((10,)) * 3.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(90), rel=1e-5)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


def test_clip_by_global_norm_zero_norm_is_noop():
    tree = {"a": jnp.zeros((4,)), "b": jnp.zeros((2, 3))}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == 0.0
    for x in jax.tree.leaves(clipped):
        assert np.all(np.asarray(x) == 0.0) and np.all(np.isfinite(x))


@pytest.mark.parametrize("bad", [np.inf, -np.inf, np.nan])
def test_clip_by_global_norm_nonfinite_grad_drops_step(bad):
    """An inf/nan gradient leaf must zero the whole update (a naive
    max_norm/norm scale gives inf * 0 = nan) while still reporting the
    blown-up raw norm, so training skips the step instead of dying."""
    tree = {"a": jnp.asarray([1.0, float(bad)]), "b": jnp.ones((3,))}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert not np.isfinite(float(norm)) or np.isnan(float(norm))
    for x in jax.tree.leaves(clipped):
        assert np.all(np.asarray(x) == 0.0)


@pytest.mark.parametrize("moment_dtype", [jnp.float32, jnp.bfloat16])
def test_adam_zeroed_grads_keep_params_and_dtype(moment_dtype):
    """freeze_dispatch-style all-zero gradient trees: params must stay
    bitwise put (no eps-driven drift) and every dtype must survive the
    update, including bf16 moment storage."""
    params = {"w": jnp.ones((4, 2), jnp.float32) * 0.5,
              "b": jnp.zeros((2,), jnp.float32)}
    cfg = AdamConfig(lr=1e-2, moment_dtype=moment_dtype)
    opt = adam_init(params, cfg)
    grads = jax.tree.map(jnp.zeros_like, params)
    p2, o2 = adam_update(params, grads, opt, cfg)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for m in jax.tree.leaves(o2["m"]) + jax.tree.leaves(o2["v"]):
        assert m.dtype == moment_dtype
        assert np.all(np.asarray(m, np.float32) == 0.0)
    assert int(o2["step"]) == 1
    # a later real gradient still moves params finitely
    grads["w"] = jnp.ones_like(grads["w"])
    p3, o3 = adam_update(p2, grads, o2, cfg)
    assert np.all(np.isfinite(np.asarray(p3["w"], np.float32)))
    assert not np.array_equal(np.asarray(p3["w"], np.float32),
                              np.asarray(p2["w"], np.float32))


def test_adam_after_nonfinite_clip_recovers():
    """clip -> adam composition under a gradient blow-up: the clipped
    (all-zero) update leaves params finite and the very next clean step
    trains normally."""
    params = {"w": jnp.full((3,), 0.25)}
    cfg = AdamConfig(lr=1e-2)
    opt = adam_init(params, cfg)
    bad = {"w": jnp.asarray([np.inf, 1.0, -2.0])}
    clipped, _ = clip_by_global_norm(bad, 1.0)
    p2, o2 = adam_update(params, clipped, opt, cfg)
    np.testing.assert_array_equal(np.asarray(p2["w"]), np.asarray(params["w"]))
    good, _ = clip_by_global_norm({"w": jnp.ones((3,))}, 1.0)
    p3, _ = adam_update(p2, good, o2, cfg)
    assert np.all(np.isfinite(np.asarray(p3["w"])))


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(seed=st.integers(0, 1000))
def test_int8_quantization_error_bound(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (128,)) * 7
    q, scale = quantize_int8(x)
    back = dequantize_int8(q, scale)
    assert float(jnp.abs(back - x).max()) <= float(scale) / 2 + 1e-6


def test_warmup_cosine_schedule():
    f = warmup_cosine(1.0, 10, 100)
    assert float(f(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(f(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-2)
    assert float(f(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-2)
