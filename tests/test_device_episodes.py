"""Distributional equivalence of the device-resident episode sampler.

``materialize_round_batch_device`` draws with jax.random inside the trace,
so it can never be draw-for-draw identical to the host sampler — these
tests pin it to the same *laws* instead: count moments, size-distribution
KS statistics, edge/service/priority marginals, within-round time order
statistics, the overflow="clip" rid/dropped contract, and (slow) the
rollout-level cost a fixed policy sees on device vs host episodes.

KS thresholds are hand-rolled (no scipy in the container): the two-sample
acceptance band is c(alpha) * sqrt((n+m)/(n*m)) with c = 1.95 (alpha ~
1e-3), one-sample is c / sqrt(n)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.workloads import (DEADLINE_INF, Merged, MMPPArrivals,
                             PoissonArrivals, FlashCrowdArrivals, ServiceMix,
                             SizeSpec, edge_weights,
                             materialize_round_batch,
                             materialize_round_batch_device, scenario)

DT = 0.25


def device_batch(wl, num_edges, num_rounds, batch, width, seed=0):
    out = materialize_round_batch_device(
        wl, num_edges, num_rounds, DT, batch,
        key=jax.random.PRNGKey(seed), max_per_round=width)
    return {k: np.asarray(v) for k, v in out.items()}


def host_batch(wl, num_edges, num_rounds, batch, width, seed=0):
    return materialize_round_batch(
        wl, num_edges, num_rounds, DT, batch, base_seed=seed,
        max_per_round=width, overflow="clip")


def ks_two_sample(a, b):
    a, b = np.sort(a), np.sort(b)
    grid = np.concatenate([a, b])
    fa = np.searchsorted(a, grid, side="right") / a.size
    fb = np.searchsorted(b, grid, side="right") / b.size
    return float(np.max(np.abs(fa - fb)))


def ks_uniform(u):
    u = np.sort(u)
    n = u.size
    emp = np.arange(1, n + 1) / n
    return float(max(np.max(np.abs(emp - u)),
                     np.max(np.abs(emp - 1.0 / n - u))))


def test_poisson_count_moments():
    rate, R, B = 30.0, 8, 384
    d = device_batch(PoissonArrivals(rate=rate), 4, R, B, width=64)
    counts = d["mask"].sum(-1)          # (B, R)
    lam = rate * DT
    assert counts.mean() == pytest.approx(lam, rel=0.05)
    assert counts.var() == pytest.approx(lam, rel=0.15)
    assert d["dropped"].sum() == 0


def test_edge_marginal_matches_zipf_weights():
    Q = 5
    wl = PoissonArrivals(rate=40.0, edge_skew=1.5, hot_edge=1)
    d = device_batch(wl, Q, 8, 256, width=64)
    src = d["src"][d["mask"]]
    hist = np.bincount(src, minlength=Q) / src.size
    np.testing.assert_allclose(hist, edge_weights(Q, 1.5, 1), atol=0.02)


@pytest.mark.parametrize("spec", [
    SizeSpec("pareto", (1.5, 0.05)),
    SizeSpec("lognormal", (-1.5, 0.8)),
    SizeSpec("uniform", (0.2, 0.9)),
    SizeSpec("fixed", (0.37,)),
])
def test_size_law_matches_host(spec):
    d = device_batch(PoissonArrivals(rate=40.0, sizes=spec), 3, 8, 128,
                     width=64)
    dev = d["size"][d["mask"]].astype(np.float64)
    host = spec.sample(np.random.default_rng(7), dev.size)
    if spec.dist == "fixed":
        np.testing.assert_allclose(dev, 0.37, atol=1e-6)
        return
    dstat = ks_two_sample(dev, host)
    n, m = dev.size, host.size
    assert dstat < 1.95 * np.sqrt((n + m) / (n * m)), (spec, dstat)


def test_within_round_times_are_uniform_order_statistics():
    R = 6
    d = device_batch(PoissonArrivals(rate=30.0), 4, R, 256, width=64)
    t, mask = d["t"], d["mask"]
    rounds = np.arange(R)[None, :, None]
    lo, hi = rounds * DT, (rounds + 1) * DT
    assert np.all(t[mask] > (np.broadcast_to(lo, t.shape))[mask])
    assert np.all(t[mask] <= (np.broadcast_to(hi, t.shape))[mask] + 1e-6)
    # sorted within each round (masked prefix)
    diffs = np.diff(t, axis=-1)
    both = mask[..., 1:] & mask[..., :-1]
    assert np.all(diffs[both] >= 0)
    u = (t / DT - np.broadcast_to(rounds, t.shape))[mask]
    assert ks_uniform(np.clip(u, 0.0, 1.0)) < 1.95 / np.sqrt(u.size)


def test_clip_contract_rids_and_dropped():
    R, A, B = 6, 8, 64
    d = device_batch(PoissonArrivals(rate=120.0), 4, R, B, width=A)
    counts = d["mask"].sum(-1)                      # kept = min(n, A)
    assert (d["dropped"] > 0).any()
    assert np.all(counts[d["dropped"] > 0] == A)
    # clipped rounds keep the *earliest* A of n arrivals: the last kept one
    # sits at the A-th order statistic of n uniforms, Beta(A, n-A+1) * dt
    clipped = d["dropped"] > 0
    u_last = (d["t"][..., A - 1] / DT - np.arange(R))[clipped]
    n = (counts + d["dropped"])[clipped]
    expect = A / (n + 1.0)
    assert np.all((u_last > 0) & (u_last <= 1.0 + 1e-6))
    assert u_last.mean() == pytest.approx(expect.mean(), rel=0.05)
    # rids count *all* arrivals in time order: the gap between consecutive
    # rounds' ids equals the dropped tail of the earlier round
    for b in range(B):
        for r in range(R - 1):
            k = counts[b, r]
            if k == 0 or counts[b, r + 1] == 0:
                continue
            last_kept = d["rid"][b, r, k - 1]
            next_first = d["rid"][b, r + 1, 0]
            assert next_first - (last_kept + 1) == d["dropped"][b, r], (b, r)
    flat = d["rid"][d["mask"]]
    per_elem = d["mask"].reshape(B, -1)
    for b in range(B):
        ids = d["rid"].reshape(B, -1)[b][per_elem[b]]
        assert np.all(np.diff(ids) > 0)


def test_mmpp_round_profile_matches_host():
    wl = scenario("mmpp_bursty")
    R, B = 12, 256
    d = device_batch(wl, 4, R, B, width=64)
    h = host_batch(wl, 4, R, B, width=64, seed=11)
    cd, ch = d["mask"].sum(-1), h["mask"].sum(-1)
    tol = 5.0 * np.sqrt(cd.var(0) / B + ch.var(0) / B) + 1e-9
    np.testing.assert_array_less(np.abs(cd.mean(0) - ch.mean(0)), tol)
    assert cd.mean() == pytest.approx(ch.mean(), rel=0.1)


def test_flash_crowd_spike_rounds_and_edge():
    wl = FlashCrowdArrivals(base_rate=10.0, multiplier=10.0,
                            spike_start=1.0, spike_duration=0.5,
                            spike_edge=2)
    R, Q, B = 8, 4, 256
    d = device_batch(wl, Q, R, B, width=64)
    counts = d["mask"].sum(-1).mean(0)              # per-round mean
    spike, base = counts[[4, 5]], counts[[0, 1, 2, 3, 6, 7]]
    assert spike.min() > 3.0 * base.max()
    in_spike = d["mask"][:, 4:6, :]
    frac_hot = (d["src"][:, 4:6, :][in_spike] == 2).mean()
    h = host_batch(wl, Q, R, B, width=64, seed=3)
    h_in = h["mask"][:, 4:6, :]
    h_hot = (h["src"][:, 4:6, :][h_in] == 2).mean()
    assert frac_hot == pytest.approx(h_hot, abs=0.05)


def test_service_mix_laws():
    wl = ServiceMix(PoissonArrivals(rate=40.0), num_services=6, skew=1.2,
                    deadline=(0.5, 2.0), deadline_frac=0.5,
                    priorities=(3.0, 1.0))
    d = device_batch(wl, 3, 8, 256, width=64)
    m = d["mask"]
    svc = d["service"][m]
    ranks = np.arange(6, dtype=np.float64)
    probs = (ranks + 1.0) ** -1.2
    probs /= probs.sum()
    np.testing.assert_allclose(np.bincount(svc, minlength=6) / svc.size,
                               probs, atol=0.02)
    prio = d["priority"][m]
    np.testing.assert_allclose(np.bincount(prio.astype(int), minlength=2)
                               / prio.size, [0.75, 0.25], atol=0.02)
    dl, t = d["deadline"][m], d["t"][m]
    finite = dl < DEADLINE_INF / 2
    assert finite.mean() == pytest.approx(0.5, abs=0.03)
    rel = (dl - t)[finite]
    assert np.all((rel >= 0.5 - 1e-5) & (rel <= 2.0 + 1e-5))
    u = np.clip((rel - 0.5) / 1.5, 0.0, 1.0)
    assert ks_uniform(u) < 1.95 / np.sqrt(u.size)


def test_unsupported_workloads_and_options_raise():
    mm = MMPPArrivals()
    with pytest.raises(ValueError, match="MMPP"):
        materialize_round_batch_device(Merged((mm, mm)), 3, 4, DT, 8,
                                       key=jax.random.PRNGKey(0),
                                       max_per_round=8)
    with pytest.raises(ValueError, match="clip"):
        materialize_round_batch_device(PoissonArrivals(), 3, 4, DT, 8,
                                       key=jax.random.PRNGKey(0),
                                       max_per_round=8, overflow="error")
    mixed = Merged((PoissonArrivals(sizes=SizeSpec("uniform")),
                    PoissonArrivals(sizes=SizeSpec("pareto", (1.5, 0.05)))))
    with pytest.raises(ValueError, match="[Ss]ize"):
        materialize_round_batch_device(mixed, 3, 4, DT, 8,
                                       key=jax.random.PRNGKey(0),
                                       max_per_round=8)


@pytest.mark.parametrize("name", ["uniform_iid", "hotspot_skew",
                                  "heavy_tail_pareto", "diurnal",
                                  "chaos-rolling-failure"])
def test_scenario_moment_parity_with_host(name):
    wl = scenario(name)
    R, Q, B = 8, 5, 192
    width = 64 if name != "chaos-rolling-failure" else 96
    d = device_batch(wl, Q, R, B, width=width)
    h = host_batch(wl, Q, R, B, width=width, seed=5)
    assert d["mask"].sum(-1).mean() == pytest.approx(
        h["mask"].sum(-1).mean(), rel=0.1)
    assert d["size"][d["mask"]].mean() == pytest.approx(
        h["size"][h["mask"]].mean(), rel=0.1)


@pytest.mark.slow
def test_rollout_cost_parity_device_vs_host():
    """A fixed (fresh) policy must see the same expected episode cost on
    device-sampled episodes as on host-sampled ones — the rollout-level
    check that the sampler feeds the engine the same workload law."""
    from repro.core import PolicyConfig
    from repro.core.policy import corais_init
    from repro.core.train import (TemporalRLConfig, _cluster_seeds,
                                  _element_keys, resolve_temporal_config,
                                  temporal_rl_loss)
    from repro.serving import engine as engine_lib
    from repro.serving.engine import EngineConfig

    B = 64
    cfg = TemporalRLConfig(
        policy=PolicyConfig(d_model=32, ff_hidden=64, edge_layers=1,
                            request_layers=1, norm="layer"),
        engine=EngineConfig(num_edges=4, num_rounds=6, max_per_round=16),
        scenario="uniform_iid", batch_size=B, seed=0)
    cfg, _ = resolve_temporal_config(cfg)
    ecfg = cfg.engine
    params, state = corais_init(jax.random.PRNGKey(0), cfg.policy)
    wl = scenario(cfg.scenario)

    @jax.jit
    def cost_of(sim0, arrivals, skeys):
        _, aux = temporal_rl_loss(params, state, sim0, arrivals, skeys, cfg)
        return aux["cost_mean"]

    key = jax.random.PRNGKey(cfg.seed)
    dev_costs, host_costs = [], []
    for b in range(3):
        sim0 = jax.tree.map(jnp.asarray,
                            engine_lib.init_batch(ecfg, _cluster_seeds(cfg, b)))
        skeys = _element_keys(key, b, B)
        ekeys = _element_keys(key, 100 + b, B)
        arr_keys = jax.vmap(lambda k: jax.random.fold_in(k, 1))(ekeys)
        dev = materialize_round_batch_device(
            wl, ecfg.num_edges, ecfg.num_rounds, ecfg.round_interval,
            keys=arr_keys, max_per_round=ecfg.max_per_round)
        host = jax.tree.map(jnp.asarray, materialize_round_batch(
            wl, ecfg.num_edges, ecfg.num_rounds, ecfg.round_interval, B,
            base_seed=1000 + b, max_per_round=ecfg.max_per_round,
            overflow="clip"))
        dev_costs.append(float(cost_of(sim0, dev, skeys)))
        host_costs.append(float(cost_of(sim0, host, skeys)))
    assert np.mean(dev_costs) == pytest.approx(np.mean(host_costs), rel=0.1)
