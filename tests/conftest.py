"""Shared fixtures. NOTE: no XLA_FLAGS here by design — tests must see the
real single-device view; only the dry-run subprocess forces 512 devices."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
