"""Quickstart: the paper's pipeline end to end in ~2 minutes on CPU.

1. Sample a multi-edge scheduling instance (paper §V-A rules).
2. Solve it with the baselines (Local / Random / greedy / ILS / exact B&B).
3. Train a miniature CoRaiS policy with S-sample REINFORCE (paper §IV-B).
4. Compare the learned scheduler's makespan and decision latency.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (InstanceConfig, PolicyConfig, RLConfig,
                        generate_instance, makespan_np)
from repro.core.decode import greedy_decode, sampling_decode
from repro.core.heuristics import solve_greedy, solve_ils, solve_local, solve_random
from repro.core.ilp import solve_branch_and_bound, write_lp
from repro.core.policy import corais_apply
from repro.core.train import train


def main():
    rng = np.random.default_rng(0)
    icfg = InstanceConfig(num_edges=4, num_requests=12, backlog_high=10)
    inst = generate_instance(rng, icfg)

    print("== one scheduling round, classical solvers ==")
    for name, solver in [
        ("Local", solve_local),
        ("Random(100)", lambda i: solve_random(i, 100)),
        ("Greedy", solve_greedy),
        ("ILS(0.5s)", lambda i: solve_ils(i, budget_s=0.5)),
        ("BranchAndBound*", solve_branch_and_bound),
    ]:
        t0 = time.perf_counter()
        assign = solver(inst)
        print(f"  {name:16s} makespan={makespan_np(inst, assign):8.3f} "
              f"({(time.perf_counter()-t0)*1e3:7.1f} ms)")
    write_lp(inst, "/tmp/quickstart.lp")
    print("  (exact ILP exported to /tmp/quickstart.lp)")

    print("== train a miniature CoRaiS (paper §IV-B) ==")
    cfg = RLConfig(
        policy=PolicyConfig(d_model=32, ff_hidden=64, edge_layers=2,
                            request_layers=1),
        instance=icfg, batch_size=16, num_samples=16, lr=1e-3,
        num_batches=60, seed=0)
    t0 = time.time()
    params, state, _, hist = train(cfg)
    print(f"  cost {hist[0]['cost_mean']:.3f} -> {hist[-1]['cost_mean']:.3f} "
          f"in {time.time()-t0:.0f}s")

    print("== schedule with the learned policy ==")
    jinst = jax.tree.map(jnp.asarray, inst)

    @jax.jit
    def forward(i):
        lp, _ = corais_apply(params, state, i, cfg.policy, training=False)
        return lp

    lp = jax.block_until_ready(forward(jinst))  # compile once
    t0 = time.perf_counter()
    lp = forward(jinst)
    g = np.asarray(greedy_decode(lp))
    dt = time.perf_counter() - t0
    print(f"  CoRaiS(greedy)   makespan={makespan_np(inst, g):8.3f} "
          f"({dt*1e3:7.2f} ms real-time decision)")
    a, cost = sampling_decode(jax.random.PRNGKey(0), jinst, lp, 256)
    print(f"  CoRaiS(256)      makespan={float(cost):8.3f}")


if __name__ == "__main__":
    main()
