"""Elastic-scaling example: train on an 8-device mesh, lose half the
devices, resume on a 4-device mesh from the same checkpoint (resharded).

Run:  PYTHONPATH=src python examples/elastic_restart.py
(thin wrapper over repro.launch.elastic, which must own process start-up
because device count is locked at first jax import)."""
import subprocess
import sys

if __name__ == "__main__":
    raise SystemExit(subprocess.run(
        [sys.executable, "-m", "repro.launch.elastic", "--steps", "4"],
    ).returncode)
