"""LM pretraining example on the framework substrate.

Trains a reduced-config assigned architecture for a few hundred steps on
the deterministic synthetic pipeline with async checkpointing, then kills
and resumes mid-run to demonstrate preemption safety. (Full-size cells are
exercised via the multi-pod dry-run; a 100M+ run does not fit one CPU core
— see DESIGN.md §3.)

Run:  PYTHONPATH=src python examples/train_lm.py --arch qwen3-4b --steps 60
"""
import argparse
import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_reduced_config
from repro.data.synthetic import SyntheticTokens
from repro.models import lm
from repro.optim import AdamConfig, adam_init, adam_update, clip_by_global_norm


def build(cfg, lr):
    adam = AdamConfig(lr=lr)

    @jax.jit
    def step(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: lm.train_loss(p, batch, cfg, 1), has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = adam_update(params, grads, opt_state, adam)
        return params, opt_state, loss

    return step, adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--interrupt-at", type=int, default=None,
                    help="simulate preemption at this step (default: steps//2)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    interrupt = args.interrupt_at or args.steps // 2

    cfg = get_reduced_config(args.arch)
    shutil.rmtree(args.ckpt, ignore_errors=True)
    ckpt = Checkpointer(args.ckpt, every=10, keep=2)
    step, adam = build(cfg, args.lr)

    def fresh():
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        return params, adam_init(params, adam), \
            SyntheticTokens(cfg.vocab_size, args.batch, args.seq)

    params, opt_state, pipe = fresh()
    losses = []
    print(f"== phase 1: train {args.arch} (reduced) to step {interrupt}, "
          f"then 'crash' ==")
    for i in range(interrupt):
        batch = jax.tree.map(jnp.asarray, next(pipe))
        params, opt_state, loss = step(params, opt_state, batch)
        losses.append(float(loss))
        if ckpt.should_save(i):
            ckpt.save(i, {"params": params, "opt": opt_state},
                      extras={"pipeline": pipe.state_dict()})
        if i % 20 == 0:
            print(f"  step {i:4d} loss {float(loss):.4f}")
    ckpt.wait()
    del params, opt_state, pipe  # the "crash"

    print("== phase 2: restore latest checkpoint and continue ==")
    p0, o0, pipe = fresh()
    restored = ckpt.restore_latest(
        {"params": jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg)),
         "opt": jax.eval_shape(lambda: adam_init(
             lm.init_params(jax.random.PRNGKey(0), cfg), adam))})
    params, opt_state = restored["tree"]["params"], restored["tree"]["opt"]
    pipe.load_state_dict(restored["extras"]["pipeline"])
    start = restored["step"] + 1
    print(f"  resumed at step {start} (pipeline step {pipe.step})")
    for i in range(start, args.steps):
        batch = jax.tree.map(jnp.asarray, next(pipe))
        params, opt_state, loss = step(params, opt_state, batch)
        losses.append(float(loss))
        if i % 20 == 0:
            print(f"  step {i:4d} loss {float(loss):.4f}")
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    print(f"== loss {first:.4f} -> {last:.4f} across the preemption ==")
    assert last < first, "training did not improve"
    print("OK: checkpoint/restart training converged")


if __name__ == "__main__":
    main()
