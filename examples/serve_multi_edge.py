"""End-to-end driver (the paper's kind: multi-edge cooperative serving).

Three heterogeneous edges each run a REAL reduced LM (`--arch`, default
olmo-1b family) through the continuous-batching backend; phi(x) is fitted
from measured prefill latencies (the paper's §III-C1 observation that LM
serving is an *ideal service*), and the central controller dispatches a
burst of prompt requests with the greedy scheduler (or a trained CoRaiS via
--policy-ckpt). Requests batch into decode lanes and run to completion.

Run:  PYTHONPATH=src python examples/serve_multi_edge.py
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.core.state import QueuedRequest, snapshot_instance
from repro.core.heuristics import solve_greedy
from repro.models import init_params
from repro.serving.batching import LMEdgeBackend
from repro.core.state import EdgeServiceState, PhiEstimator


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--requests", type=int, default=18)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    rng = np.random.default_rng(args.seed)

    # Three edges: same model, heterogeneous capability (lane counts) —
    # the paper's zeta replicas. Distinct params per edge (independent replicas).
    lanes = [1, 2, 4]
    print(f"== spinning up 3 edges serving {args.arch} (reduced), "
          f"lanes={lanes} ==")
    edges = []
    for i, ln in enumerate(lanes):
        params = init_params(jax.random.PRNGKey(i), cfg)
        be = LMEdgeBackend(cfg, params, lanes=ln, max_seq=96, seed=i)
        edges.append(be)

    # Warm each edge's phi with a few measured prefills (paper Fig. 4 fit)
    WARM = 100_000  # rid offset so warmups never collide with real requests
    print("== fitting phi(x) from measured prefill latencies ==")
    for i, be in enumerate(edges):
        for rid, plen in enumerate((8, 16, 32, 48, 64, 80, 24, 40)):
            be.submit(WARM + 1000 * i + rid, plen, 1)
        be.drain()
        a, b = be.phi.coefficients
        print(f"  edge {i}: phi(x) = {a:.5f}*x + {b:.5f}  "
              f"(affine fit over {len(be.phi._xs)} measurements)")

    # A burst of requests arrives (prompt length = the paper's data size)
    reqs = []
    for rid in range(args.requests):
        plen = int(rng.integers(8, 80))
        reqs.append(QueuedRequest(rid=rid, data_size=float(plen),
                                  source_edge=int(rng.integers(0, 3))))

    # Central controller: evaluate edge states, schedule with eq (4)-(9)
    states = []
    for i, be in enumerate(edges):
        st = EdgeServiceState(edge_id=i, coords=(float(i), 0.0),
                              phi=be.phi, replicas=be.lanes)
        states.append(st)
    w = np.abs(np.arange(3)[:, None] - np.arange(3)[None]).astype(np.float32) \
        * 1e-4  # fast interconnect; transfer cost per token
    inst = snapshot_instance(states, reqs, w, ct=1.0)
    assign = solve_greedy(inst)
    share = {i: int(np.sum(assign[:len(reqs)] == i)) for i in range(3)}
    print(f"== controller dispatch (greedy over fitted phi): {share} ==")

    t0 = time.time()
    for r, target in zip(reqs, assign):
        edges[int(target)].submit(r.rid, int(r.data_size), gen_len=4)

    def real_done():
        return sum(len([r for r in be.finished if r < WARM]) for be in edges)

    while real_done() < len(reqs):
        for be in edges:
            be.step()
    wall = time.time() - t0
    print(f"== all {len(reqs)} requests served in {wall:.1f}s wall ==")
    for i, be in enumerate(edges):
        mine = [r for r in be.finished if r < WARM]
        print(f"  edge {i} (lanes={be.lanes}): served {len(mine)} requests")
    assert real_done() == len(reqs)
    # capability-aware: the 4-lane edge should serve the most
    assert share[2] >= share[0], share
    print("OK: more capable edges absorbed more load (heterogeneity awareness)")


if __name__ == "__main__":
    main()
