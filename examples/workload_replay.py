"""Workload scenarios + trace record/replay through the multi-edge sim.

Demonstrates the workload subsystem end to end:
  1. pick a named scenario from the registry (here: a 10x flash crowd),
  2. drive the event-driven simulator with it (live synthetic run),
  3. record the exact same arrival stream to a JSONL trace,
  4. replay the trace through a fresh simulator and verify the completion
     metrics are bit-identical — the property that makes A/B scheduler
     comparisons on captured traffic trustworthy.

Run:  PYTHONPATH=src python examples/workload_replay.py
      PYTHONPATH=src python examples/workload_replay.py \\
          --scenario mmpp_bursty --backend local
"""
import argparse
import os
import tempfile

from repro.serving import CentralController, MultiEdgeSim, SimConfig
from repro.workloads import list_scenarios, read_trace, record_trace, scenario

TIMING_KEYS = ("scheduler_decision_s", "decision_mean_s", "decision_p95_s",
               "decision_max_s", "wall_s")


def completion_metrics(m: dict) -> dict:
    """Drop host-timing fields (nondeterministic wall clock)."""
    return {k: v for k, v in m.items() if k not in TIMING_KEYS}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="flash_crowd_10x",
                    choices=sorted(list_scenarios()))
    ap.add_argument("--backend", default="greedy")
    ap.add_argument("--edges", type=int, default=5)
    ap.add_argument("--until", type=float, default=3.0)
    ap.add_argument("--horizon", type=float, default=400.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print(f"== registered scenarios ==")
    for name, desc in list_scenarios().items():
        print(f"  {name:20s} {desc}")

    wl = scenario(args.scenario)
    print(f"\n== live run: {args.scenario} via {args.backend} ==")
    sim = MultiEdgeSim(SimConfig(num_edges=args.edges, seed=args.seed),
                       CentralController(scheduler=args.backend))
    live = sim.drive(wl, until=args.until, run_until=args.horizon)
    print(f"  completed {live['completed']}/{live['submitted']}, "
          f"mean response {live['mean_response']:.3f}, "
          f"p95 {live['p95_response']:.3f}, "
          f"decision mean {live['decision_mean_s'] * 1e3:.2f} ms "
          f"over {live['decision_rounds']} rounds")

    path = os.path.join(tempfile.gettempdir(),
                        f"corais_{args.scenario}.jsonl")
    n = record_trace(path, wl, num_edges=args.edges, until=args.until,
                     seed=args.seed)
    print(f"\n== recorded {n} arrivals to {path} ==")

    sim2 = MultiEdgeSim(SimConfig(num_edges=args.edges, seed=args.seed),
                        CentralController(scheduler=args.backend))
    replay = sim2.drive(read_trace(path), until=args.until,
                        run_until=args.horizon)
    assert completion_metrics(live) == completion_metrics(replay), \
        "replay diverged from live run"
    print("== replay reproduced the live run's completion metrics exactly ==")
    print("OK")


if __name__ == "__main__":
    main()
